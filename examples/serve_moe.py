"""Serving example: batched greedy decoding of a (briefly trained) MoEBlaze
model through the continuous-batching engine.

    PYTHONPATH=src python examples/serve_moe.py
"""

import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import train


def main():
    cfg = get_config("mixtral_8x7b").reduced().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        num_experts=4, top_k=2, moe_d_ff=256, vocab_size=256,
        sliding_window=64, attn_chunk=64)
    print("== brief training so generations aren't pure noise ==")
    params, _, _ = train(cfg, TrainConfig(total_steps=40, batch_size=8,
                                          seq_len=128, learning_rate=2e-3,
                                          log_every=20))

    print("\n== batched serving (4 slots, paged KV cache) ==")
    eng = ServeEngine(cfg, params, batch_slots=4, capacity=256)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(3, cfg.vocab_size, size=n,
                                        dtype=np.int32).astype(np.int32),
                    max_new_tokens=16)
            for n in (5, 9, 3, 7)]
    for i, r in enumerate(eng.generate(reqs)):
        print(f"request[{i}] prompt={r.prompt.tolist()} -> "
              f"generated={r.out_tokens}")
    print(f"\nscheduler stats: {eng.stats}")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-parameter MoEBlaze model for a few
hundred steps on the synthetic packed-document pipeline, with periodic
checkpointing and a final loss report.

    PYTHONPATH=src python examples/train_100m.py --steps 300

On this CPU container a step takes O(seconds); pass --steps 5 for a smoke
run.  The model is a qwen3-moe-family layout (qk-norm + top-2-of-8 experts)
sized to ~100M parameters.
"""

import argparse

import jax

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.models import transformer as T
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = get_config("qwen3_moe_30b_a3b").replace(
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        num_experts=8, top_k=2, moe_d_ff=1024, vocab_size=32000,
        dtype="float32", attn_chunk=128)
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0),
                                                 cfg))))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model} E={cfg.num_experts} "
          f"top-{cfg.top_k}, MoEBlaze dispatch)")

    tcfg = TrainConfig(total_steps=args.steps, batch_size=args.batch,
                       seq_len=args.seq, learning_rate=6e-4,
                       warmup_steps=min(50, args.steps // 4),
                       log_every=max(1, args.steps // 30),
                       checkpoint_every=max(0, args.steps // 3),
                       checkpoint_dir=args.ckpt_dir)
    params, _, hist = train(cfg, tcfg)
    s_per_step = hist[-1]["wall_s"] / max(args.steps, 1)
    print(f"\nfinal: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"over {args.steps} steps ({s_per_step:.2f} s/step)")


if __name__ == "__main__":
    main()

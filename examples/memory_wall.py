"""Reproduce the paper's memory-wall quantitative study (§2.1/§2.2 examples)
and Figure 3/5 analogues at full Table-1 sizes — no execution, pure
saved-residual accounting — then sweep *checkpoint plans* (not just the
named policies) over the paper configs and print the budget-fit decision
table (``CheckpointPlan.fit``).

    PYTHONPATH=src python examples/memory_wall.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.bench.paper_tables import IMPLS, residual_bytes
from repro.configs.paper_tables import PAPER_CONFS, PAPER_TABLE1
from repro.core import memsim
from repro.core.checkpoint import (CheckpointPlan, estimate_saved_bytes,
                                   get_plan, parse_size)

# Plans swept below: the registry's tag plans plus specs no string enum
# could express.  NB the static estimator covers the checkpoint-name tags of
# the scanned stack; the MoE expert FFN's custom-VJP residuals (A/B/Y_swi,
# the first table above) live *inside* the remat replay and are plan-driven
# separately (moe:-scoped overrides -> residual modes ab_yswi/ab/x).
PLAN_SWEEP = ("none", "paper_min", "paper", "save=qkv",
              "save=qkv,attn_out,moe_gates")

#: activation-peak budgets for the fit table below (``base="acts"``: the
#: activation timeline alone, params/optimizer excluded — the axis the
#: paper's memory-wall study varies).
FIT_BUDGETS = ("16GiB", "32GiB", "48GiB")


def plan_tables():
    print("\n== checkpoint-plan sweep: est. saved residual bytes "
          "(per layer, full Table-1 token counts; plans beyond the named "
          "registry are specs) ==")
    print(f"{'conf':12s}" + "".join(
        f"{p[:28]:>30s}" for p in PLAN_SWEEP))
    for name, conf in PAPER_TABLE1.items():
        cfg = PAPER_CONFS[name]
        _, _, _, b, s = conf
        row = "".join(
            f"{estimate_saved_bytes(cfg, p, b * s) / 1e6:28.1f}MB"
            for p in PLAN_SWEEP)
        print(f"{name:12s}" + row)

    print("\n== budget-fit decision table (CheckpointPlan.fit ranks by "
          "SIMULATED PEAK — core.memsim phase timeline, activation base) ==")
    print(f"{'conf':12s}" + "".join(f"{b:>34s}" for b in FIT_BUDGETS))
    for name, conf in PAPER_TABLE1.items():
        cfg = PAPER_CONFS[name]
        _, _, _, b, s = conf
        row = "".join(
            f"{CheckpointPlan.fit(cfg, b * s, parse_size(bud), batch=b, base='acts').plan.spec():>34s}"
            for bud in FIT_BUDGETS)
        print(f"{name:12s}" + row)

    # Full table for one cell, with a custom spec as the preferred candidate
    # (what `dryrun --remat-policy <spec> --hbm-budget <b>` runs per arch).
    # Each row carries the simulated peak and the phase responsible — the
    # transient-aware verdict residual accounting cannot give.
    prefer = get_plan(PLAN_SWEEP[-2])
    name, conf = next(iter(PAPER_TABLE1.items()))
    fit = CheckpointPlan.fit(PAPER_CONFS[name], conf[3] * conf[4],
                             parse_size(FIT_BUDGETS[1]), batch=conf[3],
                             prefer=prefer, base="acts")
    print(f"\nfull decision table for {name} @ {FIT_BUDGETS[1]} "
          f"(prefer={PLAN_SWEEP[-2]!r}):")
    for r in fit.table:
        mark = "*" if r.chosen else (" " if r.fits else "x")
        print(f"  [{mark}] sim_peak={r.sim_peak_bytes / 2**30:6.1f}GiB "
              f"@{r.peak_phase:18s} fits={str(r.fits):5s} {r.spec}")

    # The phase timeline behind the chosen cell: where the peak actually
    # sits (bwd recompute spike vs loss logits vs a2a buffers).
    tl = memsim.simulate(PAPER_CONFS[name], conf[3] * conf[4], batch=conf[3],
                         plan=fit.plan, base="acts")
    print(f"\nsimulated phase timeline for {name} under "
          f"{fit.plan.spec()!r} (highest-live phases):")
    print(tl.table(limit=6))


def main():
    # §2.1 example: DeepSeek-scale routed-token buffer
    L, k, d = 2_000_000, 4, 6144
    print(f"paper §2.1: routed-token buffer L={L:.0e} k={k} d={d} bf16 -> "
          f"{L*d*k*2/1e9:.0f} GB (eliminated by index-based dispatch: "
          f"{L*k*4*2/1e9:.2f} GB of int32 indices instead)")
    h = 4 * 6144
    print(f"paper §2.2: FFN intermediates 2·L·h bf16 -> {2*L*h*2/1e9:.0f} GB "
          f"(halved by save-A,B + recompute-SiLU)\n")

    print(f"{'conf':12s} {'act':7s}" + "".join(f"{i:>14s}" for i in IMPLS)
          + f"{'ratio':>8s}")
    for name, conf in PAPER_TABLE1.items():
        for act in ("silu", "swiglu"):
            vals = {i: residual_bytes(conf, i, act) for i in IMPLS}
            ratio = vals["megablocks"] / vals["blaze"]
            print(f"{name:12s} {act:7s}" +
                  "".join(f"{vals[i]/1e6:12.0f}MB" for i in IMPLS) +
                  f"{ratio:7.2f}x")

    plan_tables()


if __name__ == "__main__":
    main()

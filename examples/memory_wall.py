"""Reproduce the paper's memory-wall quantitative study (§2.1/§2.2 examples)
and Figure 3/5 analogues at full Table-1 sizes — no execution, pure
saved-residual accounting — then sweep *checkpoint plans* (not just the
named policies) over the paper configs and print the budget-fit decision
table (``CheckpointPlan.fit``).

    PYTHONPATH=src python examples/memory_wall.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.bench.paper_tables import IMPLS, residual_bytes
from repro.configs.paper_tables import PAPER_CONFS, PAPER_TABLE1
from repro.core.checkpoint import (CheckpointPlan, estimate_saved_bytes,
                                   get_plan, parse_size)

# Plans swept below: the registry's tag plans plus specs no string enum
# could express.  NB the static estimator covers the checkpoint-name tags of
# the scanned stack; the MoE expert FFN's custom-VJP residuals (A/B/Y_swi,
# the first table above) live *inside* the remat replay and are plan-driven
# separately (moe:-scoped overrides -> residual modes ab_yswi/ab/x).
PLAN_SWEEP = ("none", "paper_min", "paper", "save=qkv",
              "save=qkv,attn_out,moe_gates")

FIT_BUDGETS = ("128MiB", "300MiB", "1GiB")


def plan_tables():
    print("\n== checkpoint-plan sweep: est. saved residual bytes "
          "(per layer, full Table-1 token counts; plans beyond the named "
          "registry are specs) ==")
    print(f"{'conf':12s}" + "".join(
        f"{p[:28]:>30s}" for p in PLAN_SWEEP))
    for name, conf in PAPER_TABLE1.items():
        cfg = PAPER_CONFS[name]
        _, _, _, b, s = conf
        row = "".join(
            f"{estimate_saved_bytes(cfg, p, b * s) / 1e6:28.1f}MB"
            for p in PLAN_SWEEP)
        print(f"{name:12s}" + row)

    print("\n== budget-fit decision table (CheckpointPlan.fit over the "
          "registry candidates) ==")
    print(f"{'conf':12s}" + "".join(f"{b:>14s}" for b in FIT_BUDGETS))
    for name, conf in PAPER_TABLE1.items():
        cfg = PAPER_CONFS[name]
        _, _, _, b, s = conf
        row = "".join(
            f"{CheckpointPlan.fit(cfg, b * s, parse_size(bud)).plan.spec():>14s}"
            for bud in FIT_BUDGETS)
        print(f"{name:12s}" + row)

    # Full table for one cell, with a custom spec as the preferred candidate
    # (what `dryrun --remat-policy <spec> --hbm-budget <b>` runs per arch).
    prefer = get_plan(PLAN_SWEEP[-2])
    name, conf = next(iter(PAPER_TABLE1.items()))
    fit = CheckpointPlan.fit(PAPER_CONFS[name], conf[3] * conf[4],
                             parse_size(FIT_BUDGETS[1]), prefer=prefer)
    print(f"\nfull decision table for {name} @ {FIT_BUDGETS[1]} "
          f"(prefer={PLAN_SWEEP[-2]!r}):")
    for r in fit.table:
        mark = "*" if r.chosen else (" " if r.fits else "x")
        print(f"  [{mark}] est={r.est_saved_bytes / 1e6:9.1f}MB "
              f"fits={str(r.fits):5s} {r.spec}")


def main():
    # §2.1 example: DeepSeek-scale routed-token buffer
    L, k, d = 2_000_000, 4, 6144
    print(f"paper §2.1: routed-token buffer L={L:.0e} k={k} d={d} bf16 -> "
          f"{L*d*k*2/1e9:.0f} GB (eliminated by index-based dispatch: "
          f"{L*k*4*2/1e9:.2f} GB of int32 indices instead)")
    h = 4 * 6144
    print(f"paper §2.2: FFN intermediates 2·L·h bf16 -> {2*L*h*2/1e9:.0f} GB "
          f"(halved by save-A,B + recompute-SiLU)\n")

    print(f"{'conf':12s} {'act':7s}" + "".join(f"{i:>14s}" for i in IMPLS)
          + f"{'ratio':>8s}")
    for name, conf in PAPER_TABLE1.items():
        for act in ("silu", "swiglu"):
            vals = {i: residual_bytes(conf, i, act) for i in IMPLS}
            ratio = vals["megablocks"] / vals["blaze"]
            print(f"{name:12s} {act:7s}" +
                  "".join(f"{vals[i]/1e6:12.0f}MB" for i in IMPLS) +
                  f"{ratio:7.2f}x")

    plan_tables()


if __name__ == "__main__":
    main()

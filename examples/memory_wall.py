"""Reproduce the paper's memory-wall quantitative study (§2.1/§2.2 examples)
and Figure 3/5 analogues at full Table-1 sizes — no execution, pure
saved-residual accounting.

    PYTHONPATH=src python examples/memory_wall.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.bench.paper_tables import IMPLS, residual_bytes
from repro.configs.paper_tables import PAPER_TABLE1


def main():
    # §2.1 example: DeepSeek-scale routed-token buffer
    L, k, d = 2_000_000, 4, 6144
    print(f"paper §2.1: routed-token buffer L={L:.0e} k={k} d={d} bf16 -> "
          f"{L*d*k*2/1e9:.0f} GB (eliminated by index-based dispatch: "
          f"{L*k*4*2/1e9:.2f} GB of int32 indices instead)")
    h = 4 * 6144
    print(f"paper §2.2: FFN intermediates 2·L·h bf16 -> {2*L*h*2/1e9:.0f} GB "
          f"(halved by save-A,B + recompute-SiLU)\n")

    print(f"{'conf':12s} {'act':7s}" + "".join(f"{i:>14s}" for i in IMPLS)
          + f"{'ratio':>8s}")
    for name, conf in PAPER_TABLE1.items():
        for act in ("silu", "swiglu"):
            vals = {i: residual_bytes(conf, i, act) for i in IMPLS}
            ratio = vals["megablocks"] / vals["blaze"]
            print(f"{name:12s} {act:7s}" +
                  "".join(f"{vals[i]/1e6:12.0f}MB" for i in IMPLS) +
                  f"{ratio:7.2f}x")


if __name__ == "__main__":
    main()

"""Quickstart: train a small MoEBlaze mixture-of-experts LM on the synthetic
pipeline, then compare activation memory against the MegaBlocks-style
materialized baseline.

    PYTHONPATH=src python examples/quickstart.py [--steps 100]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs import get_config
from repro.configs.base import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    # A reduced Mixtral-family config: 4 experts, top-2, SWA, MoEBlaze path.
    cfg = get_config("mixtral_8x7b").reduced().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        num_experts=4, top_k=2, moe_d_ff=256, vocab_size=512,
        sliding_window=64, attn_chunk=64, moe_impl="blaze")
    tcfg = TrainConfig(total_steps=args.steps, batch_size=8, seq_len=128,
                       learning_rate=1e-3, log_every=10)

    print("== training (MoEBlaze dispatch + fused-checkpoint experts) ==")
    from repro.train.loop import train
    params, _, hist = train(cfg, tcfg)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    print("\n== activation memory: MoEBlaze vs MegaBlocks-style ==")
    from benchmarks.paper_tables import residual_bytes
    conf = (cfg.d_model, cfg.num_experts, cfg.top_k, tcfg.batch_size,
            tcfg.seq_len)
    for act in ("silu", "swiglu"):
        bl = residual_bytes(conf, "blaze", act)
        mg = residual_bytes(conf, "megablocks", act)
        print(f"  {act:7s}: blaze={bl/1e6:7.2f}MB megablocks={mg/1e6:7.2f}MB "
              f"-> {mg/bl:.2f}x saving")


if __name__ == "__main__":
    main()

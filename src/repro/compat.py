"""Version-compat shims over JAX APIs that moved between the pinned floor
(0.4.37) and current JAX.

The repo must run on both ends of the CI matrix (see
``.github/workflows/ci.yml``), so every usage of an API that was renamed or
grew new arguments funnels through here — the same pattern as the grouped-
GEMM backend registry (``repro.core.gmm_backend``), just thin enough that a
plain function per symbol suffices.
"""

from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer JAX;
    Auto is the implicit behaviour of the older API, so omitting the kwarg
    there is semantically identical.
    """
    if hasattr(jax.sharding, "AxisType"):
        types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=types)
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (0.4.x).

    ``check`` maps to ``check_vma`` on new JAX and ``check_rep`` on old —
    the same replication/varying-manual-axes validation under both names.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)

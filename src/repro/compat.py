"""Version-compat shims over JAX APIs that moved between the pinned floor
(0.4.37) and current JAX.

The repo must run on both ends of the CI matrix (see
``.github/workflows/ci.yml``), so every usage of an API that was renamed or
grew new arguments funnels through here — the same pattern as the grouped-
GEMM backend registry (``repro.core.gmm_backend``), just thin enough that a
plain function per symbol suffices.
"""

from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer JAX;
    Auto is the implicit behaviour of the older API, so omitting the kwarg
    there is semantically identical.
    """
    if hasattr(jax.sharding, "AxisType"):
        types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=types)
    return jax.make_mesh(axis_shapes, axis_names)


def saved_residuals(f, *args, **kwargs):
    """``saved_residuals`` — the JAX analogue of PyTorch saved-tensor hooks.

    Lists every (aval, source) pair autodiff would save for backward.  Public
    exposure has moved around across JAX releases, so resolve it lazily.
    """
    try:
        from jax.ad_checkpoint import saved_residuals as _sr
    except ImportError:  # 0.4.x: private module only
        from jax._src.ad_checkpoint import saved_residuals as _sr
    return _sr(f, *args, **kwargs)


def saved_residual_nbytes(f, *args, **kwargs) -> int:
    """Total bytes of the *activation* residuals autodiff saves for ``f``:
    arguments/parameters excluded, as in the paper's saved-tensor accounting.

    The argument filter keys on the source description string, whose wording
    is a JAX internal — keep the heuristic in this one place.
    """
    import math
    total = 0
    for aval, src in saved_residuals(f, *args, **kwargs):
        if not hasattr(aval, "shape"):
            continue
        if "from the argument" in str(src):
            continue
        total += math.prod(aval.shape) * aval.dtype.itemsize
    return total


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (0.4.x).

    ``check`` maps to ``check_vma`` on new JAX and ``check_rep`` on old —
    the same replication/varying-manual-axes validation under both names.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)

"""Activation-memory accounting — the harness behind the paper's >50%
activation-saving claim (§5.2), made regression-testable.

Three independent accountants per (model config x checkpoint policy x
grouped-GEMM backend), all on abstract shapes (no arrays allocated):

  * **measured** — ``jax.jit(grad(loss)).lower(...).compile()
    .memory_analysis()``: XLA's temp/argument/output buffer sizes for the
    compiled fwd+bwd;
  * **autodiff residuals** — ``saved_residuals`` (the JAX analogue of the
    paper's PyTorch saved-tensor hooks), parameters excluded — what autodiff
    *saves* under the policy;
  * **static estimate** — ``CheckpointPlan.estimate_saved_bytes``, computed
    from the plan's scoped tag decisions and the config's shapes alone.
    Exact for the tag-based plans and completely version-independent, so it
    is the tightest regression gate.

Every entry stamps the resolved plan's canonical spec in its meta
(``remat_plan``) — BENCH records are self-describing about which checkpoint
plan produced each number.

``memory_suite`` flattens the reports into ``repro.bench.record`` entries and
couples in the roofline model (``roofline.analyze_compiled`` on the same
compiled step), so the tracked ``BENCH_memory.json`` is the single report
both measured and modeled numbers live in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.record import entry
from repro.compat import saved_residual_nbytes
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import checkpoint as CK
from repro.core import gmm_backend as GB
from repro.core import memsim
from repro.models import transformer as T

#: relative tolerance of the simulated-vs-measured peak parity gate (and of
#: the ``peak_sim/*`` entries' own baseline drift) — the deterministic-entry
#: tolerance the acceptance bar names.
SIM_PARITY_TOLERANCE_PCT = 20.0

#: policy order used by suites and by the ordering assertions in tests —
#: derived from the CheckpointPlan registry (tag plans by ascending save
#: set, then the specials), never hand-maintained in parallel again.
POLICY_ORDER = CK.plan_order()


def bench_config():
    """The small MoE config every tracked bench number is measured on (CPU
    container scale; the same harness takes any ``ModelConfig``)."""
    return get_config("qwen3_moe_30b_a3b").reduced().replace(
        name="tiny_moe", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, num_experts=4, top_k=2, moe_d_ff=128,
        vocab_size=128, dtype="float32", scan_layers=True)


def bench_dense_config():
    """Dense SwiGLU companion config: its FFN carries the full A/B/Y_swi tag
    set, so it is where the strict ``none < paper_min < paper < full``
    residual ordering is measurable (the MoE expert FFN manages its own
    residuals inside the custom VJP)."""
    return get_config("yi_6b").reduced().replace(
        name="tiny_dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=128,
        dtype="float32", scan_layers=True)


def bench_ep_config():
    """MoE config for the expert-parallel residual entries.  ``E_loc = E /
    n_model`` must exceed ``top_k`` for the comparison to be meaningful: the
    dense-EP formulation materializes (L, E_loc, h) intermediates while the
    dispatch path's scale with L·k rows."""
    return bench_config().replace(
        name="tiny_moe_ep", num_experts=8, top_k=2, moe_d_ff=128,
        gmm_backend="segment")


def _dense_ep_sublayer(x, p, cfg, mesh):
    """The pre-refactor dense-EP shard_map body — (L, E_loc, h) einsums
    against a dense (L, E) combine-weight matrix.  Deleted from
    ``models/moe_block.py`` (the Dispatch-driven path replaced it); kept
    HERE, next to the other measured baselines, so the dispatch-EP residual
    numbers are gated against the formulation they displaced."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import routing
    from repro.core.moe_layer import _silu
    B, S, d = x.shape
    E = cfg.num_experts
    E_loc = E // mesh.shape["model"]
    p_specs = {"wg": P(None, None), "w1": P("model", None, None),
               "w2": P("model", None, None), "w3": P("model", None, None)}
    p_specs = {k: v for k, v in p_specs.items() if k in p}

    def body(xl, pl):
        xf = xl.reshape(B * S, d)
        g = routing.top_k_gating(xf, pl["wg"].astype(xf.dtype), cfg.top_k)
        idx = jax.lax.axis_index("model")
        L = xf.shape[0]
        cw = jnp.zeros((L, E), g.topk_weights.dtype)
        cw = cw.at[jnp.arange(L)[:, None], g.topk_experts].set(g.topk_weights)
        cw_loc = jax.lax.dynamic_slice_in_dim(cw, idx * E_loc, E_loc, axis=1)
        a = jnp.einsum("ld,edh->leh", xf, pl["w1"].astype(xf.dtype))
        y_act = _silu(a) * jnp.einsum("ld,edh->leh", xf,
                                      pl["w2"].astype(xf.dtype))
        p_out = jnp.einsum("leh,ehd->led", y_act, pl["w3"].astype(xf.dtype))
        y = jnp.einsum("le,led->ld", cw_loc.astype(p_out.dtype), p_out)
        return jax.lax.psum(y, "model").reshape(B, S, d)

    return shard_map(body, mesh=mesh, in_specs=(P(None, None, None), p_specs),
                     out_specs=P(None, None, None), check=False)(x, p)


def ep_saved_residual_entries(*, small: bool = False) -> list:
    """Dense-EP vs dispatch-EP activation residuals under an expert-sharded
    mesh, measured in the same run: the refactor's memory claim as tracked
    numbers.  The dispatch entry is the regression gate; the dense entry
    documents the baseline it must stay strictly below."""
    from repro.launch.mesh import make_debug_mesh
    from repro.models.moe_block import init_moe_params, moe_sublayer
    if len(jax.devices()) < 2:
        # Degrade loudly, not fatally: the rest of the memory suite is
        # device-count independent and must keep running.  A --check against
        # the committed baseline will then report the EP pair as missing —
        # an explicit gate signal, not a crash.
        import sys
        print("# skipping EP residual entries: need >= 2 host devices "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "before jax initializes; `python -m repro.bench` does this "
              "automatically)", file=sys.stderr)
        return []
    cfg = bench_ep_config()
    mesh = make_debug_mesh(1, 2)
    batch, seq = (2, 32) if small else (4, 64)
    params = jax.eval_shape(
        lambda k: init_moe_params(k, cfg, cfg.d_model), jax.random.PRNGKey(0))
    x = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.float32)

    # Both functions return y only, so the router-aux branch is dead code in
    # both traces and the residual accounting compares like with like.
    def dispatch_fn(x, p):
        return moe_sublayer(x, p, cfg.replace(moe_parallel="ep"),
                            mesh=mesh)[0]

    def dense_fn(x, p):
        return _dense_ep_sublayer(x, p, cfg, mesh)

    dense_b = saved_residual_nbytes(dense_fn, x, params)
    disp_b = saved_residual_nbytes(dispatch_fn, x, params)
    meta = {"batch": batch, "seq": seq, "mesh": "1x2",
            "num_experts": cfg.num_experts, "top_k": cfg.top_k}
    prefix = f"memory/{cfg.name}"
    return [
        entry(f"{prefix}/ep_dense/residual_bytes", dense_b,
              kind="residual_bytes", unit="bytes", tolerance_pct=20.0, **meta),
        entry(f"{prefix}/ep_dispatch/residual_bytes", disp_b,
              kind="residual_bytes", unit="bytes", tolerance_pct=20.0, **meta),
    ]


def _loss_fn(cfg):
    def loss(params, tokens):
        batch = {"tokens": tokens, "labels": tokens}
        return T.train_loss(params, batch, cfg)[0]
    return loss


def _abstract_args(cfg, batch: int, seq: int):
    params = jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return params, tokens


def residual_bytes(cfg, policy, *, batch: int = 2, seq: int = 32) -> int:
    """Activation bytes autodiff saves for backward under ``policy`` (a plan
    name, spec, or object; arguments/parameters excluded)."""
    cfg = cfg.replace(remat_policy=CK.resolve_plan(policy).spec)
    return saved_residual_nbytes(_loss_fn(cfg), *_abstract_args(cfg, batch, seq))


def activation_memory_report(cfg, policy, *, backend: str | None = None,
                             batch: int = 2, seq: int = 32,
                             with_roofline: bool = False,
                             with_residuals: bool = True) -> dict:
    """Compile fwd+bwd of the train loss under (plan, backend) and account
    its memory three ways.  ``policy`` is a plan name, spec, or
    ``CheckpointPlan``; the resolved canonical spec is stamped into the
    report (``remat_plan``/``plan_source``).  Returns a flat dict of numbers
    (plus the roofline analysis dict when requested).
    ``with_residuals=False`` skips the saved-residuals trace and the static
    estimate (they are backend-independent — callers sweeping the backend
    axis need them only once)."""
    rb = GB.resolve(backend, config=cfg.gmm_backend)
    plan_r = CK.resolve_plan(policy)
    cfg = cfg.replace(remat_policy=plan_r.spec, gmm_backend=rb.name)
    args = _abstract_args(cfg, batch, seq)
    grad = jax.grad(_loss_fn(cfg))
    with GB.use_backend(rb.name):   # pin the trace to the stamped backend
        compiled = jax.jit(grad).lower(*args).compile()
    mem = compiled.memory_analysis()
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    report = {
        "config": cfg.name, "policy": str(policy), "backend": rb.name,
        "backend_source": rb.source,
        "remat_plan": plan_r.spec, "plan_source": plan_r.source,
        "batch": batch, "seq": seq,
        "arg_bytes": arg_b, "out_bytes": out_b, "temp_bytes": tmp_b,
        "peak_bytes": arg_b + out_b + tmp_b - alias_b,
        "residual_bytes": (residual_bytes(cfg, plan_r, batch=batch, seq=seq)
                           if with_residuals else None),
        "est_saved_bytes": (plan_r.plan.estimate_saved_bytes(
            cfg, batch * seq, batch=batch) if with_residuals else None),
    }
    if with_roofline:
        from repro.roofline import analyze_compiled
        shape = InputShape("bench", seq, batch, "train")
        report["roofline"] = analyze_compiled(compiled, cfg, shape, n_chips=1)
    return report


def train_step_memory_entries(cfg, *, batch: int = 2, seq: int = 32) -> list:
    """Whole-train-step (loss + grads + AdamW) memory via the train loop's
    ``compiled_step_memory`` hook."""
    from repro.configs.base import TrainConfig
    from repro.train.loop import compiled_step_memory
    tcfg = TrainConfig(batch_size=batch, seq_len=seq)
    mem = compiled_step_memory(cfg, tcfg)
    prefix = f"memory/{cfg.name}/train_step"
    # The step's resolved backend and checkpoint plan ride in the meta —
    # stamped from the resolutions the compiled step actually used, not
    # re-read from the env/config.
    meta = {"batch": batch, "seq": seq, "gmm_backend": mem["gmm_backend"],
            "remat_plan": mem["remat_plan"]}
    return [
        entry(f"{prefix}/temp_bytes", mem["temp_bytes"],
              kind="temp_bytes", unit="bytes", tolerance_pct=100.0, **meta),
        entry(f"{prefix}/arg_bytes", mem["arg_bytes"],
              kind="arg_bytes", unit="bytes", tolerance_pct=20.0, **meta),
    ]


def memory_suite(*, small: bool = False) -> list:
    """All memory-axis entries: (config x policy x backend) reports, the
    roofline coupling, and the train-step axis.  The MoE config sweeps the
    grouped-GEMM backend axis; the dense config carries the full FFN tag set
    (and therefore the strict policy ordering)."""
    auto = GB.resolve(None).name
    # Entry names embed the backend, so the committed baseline must only
    # contain names every CI leg reproduces: the portable `segment` is always
    # swept (and is the dense config's only axis — it has no grouped GEMM);
    # the auto-resolved backend adds entries on JAX versions that have it,
    # which enter the gate once committed from such a version.
    plan = [(bench_config(), list(dict.fromkeys(["segment", auto]))),
            (bench_dense_config(), ["segment"])]
    batch, seq = (2, 32) if small else (4, 64)
    out = []
    for cfg, backends in plan:
        for policy in POLICY_ORDER:
            for i, backend in enumerate(backends):
                with_roofline = policy == "paper" and i == 0
                r = activation_memory_report(cfg, policy, backend=backend,
                                             batch=batch, seq=seq,
                                             with_roofline=with_roofline,
                                             with_residuals=(i == 0))
                prefix = f"memory/{cfg.name}/{policy}/{backend}"
                meta = {"batch": batch, "seq": seq,
                        "remat_plan": r["remat_plan"]}
                out.append(entry(f"{prefix}/temp_bytes", r["temp_bytes"],
                                 kind="temp_bytes", unit="bytes",
                                 tolerance_pct=100.0, **meta))
                out.append(entry(f"{prefix}/peak_bytes", r["peak_bytes"],
                                 kind="peak_bytes", unit="bytes",
                                 tolerance_pct=100.0, **meta))
                if i == 0:  # backend-independent accountants: record once
                    sim = memsim.simulate_peak(cfg, batch * seq, batch=batch,
                                               plan=policy, mode="single",
                                               base="grad")
                    out.append(entry(
                        f"peak_sim/{cfg.name}/{policy}/single", sim,
                        kind="peak_sim_bytes", unit="bytes",
                        tolerance_pct=SIM_PARITY_TOLERANCE_PCT, **meta))
                    out.append(entry(
                        f"memory/{cfg.name}/{policy}/residual_bytes",
                        r["residual_bytes"], kind="residual_bytes",
                        unit="bytes", tolerance_pct=20.0, **meta))
                    if r["est_saved_bytes"] is not None:
                        out.append(entry(
                            f"memory/{cfg.name}/{policy}/est_saved_bytes",
                            r["est_saved_bytes"], kind="est_saved_bytes",
                            unit="bytes", tolerance_pct=20.0, **meta))
                if with_roofline:
                    from repro.roofline import bench_entries
                    out += bench_entries(r["roofline"],
                                         f"memory/{cfg.name}/roofline")
    out += train_step_memory_entries(bench_config(), batch=batch, seq=seq)
    out += ep_saved_residual_entries(small=small)
    out += ep_peak_entries(small=small)
    return out


def ep_peak_entries(*, small: bool = False) -> list:
    """Measured XLA peaks AND simulated peaks of fwd+bwd under the
    expert-sharded modes (``ep`` and ``ep_a2a`` on a 1x2 debug mesh), one
    pair per registry plan — the distributed half of the simulator-parity
    matrix (the single-device half lives in :func:`memory_suite`'s
    ``peak_sim/*/single`` entries).  Pairs are emitted atomically so
    :func:`sim_parity_failures` never sees an unmatched sim entry."""
    from repro.launch.mesh import make_debug_mesh
    if len(jax.devices()) < 2:
        import sys
        print("# skipping EP peak entries: need >= 2 host devices "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "before jax initializes; `python -m repro.bench` does this "
              "automatically)", file=sys.stderr)
        return []
    mesh = make_debug_mesh(1, 2)
    n_model = mesh.shape["model"]
    batch, seq = (2, 32) if small else (4, 64)
    out = []
    for mode in ("ep", "ep_a2a"):
        cfg = bench_config().replace(moe_parallel=mode,
                                     gmm_backend="segment")
        for policy in POLICY_ORDER:
            c = cfg.replace(remat_policy=CK.resolve_plan(policy).spec)

            def loss(params, tokens):
                b = {"tokens": tokens, "labels": tokens}
                return T.train_loss(params, b, c, mesh=mesh)[0]

            args = _abstract_args(c, batch, seq)
            with mesh:
                compiled = jax.jit(jax.grad(loss)).lower(*args).compile()
            mem = compiled.memory_analysis()
            peak = (getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)
                    - getattr(mem, "alias_size_in_bytes", 0))
            sim = memsim.simulate_peak(c, batch * seq, batch=batch,
                                       plan=policy, mode=mode,
                                       n_model=n_model, base="grad")
            meta = {"batch": batch, "seq": seq, "mesh": "1x2",
                    "remat_plan": CK.resolve_plan(policy).spec}
            out.append(entry(f"memory/{cfg.name}/{policy}/{mode}/peak_bytes",
                             peak, kind="peak_bytes", unit="bytes",
                             tolerance_pct=100.0, **meta))
            out.append(entry(f"peak_sim/{cfg.name}/{policy}/{mode}", sim,
                             kind="peak_sim_bytes", unit="bytes",
                             tolerance_pct=SIM_PARITY_TOLERANCE_PCT, **meta))
    return out


def sim_parity_failures(entries: list) -> list:
    """The simulated-vs-measured peak gate: every ``peak_sim/<cfg>/<plan>/
    <mode>`` entry must agree with its measured counterpart — the
    ``memory/<cfg>/<plan>/segment/peak_bytes`` entry for ``single`` (the
    simulator models the portable segment backend's buffers; other backends'
    peaks are tracked but not parity-gated) or ``memory/<cfg>/<plan>/<mode>/
    peak_bytes`` for the sharded modes — within the sim entry's tolerance.
    Returns human-readable failure lines (empty == parity holds)."""
    by_name = {e["name"]: e for e in entries}
    fails = []
    for e in entries:
        if not e["name"].startswith("peak_sim/"):
            continue
        _, cfg_name, plan, sim_mode = e["name"].split("/")
        backend = "segment" if sim_mode == "single" else sim_mode
        want = f"memory/{cfg_name}/{plan}/{backend}/peak_bytes"
        measured = by_name.get(want)
        if measured is None:
            fails.append(f"PARITY {e['name']}: measured counterpart "
                         f"{want} missing from this run")
            continue
        tol = e["tolerance_pct"] or SIM_PARITY_TOLERANCE_PCT
        err = (e["value"] - measured["value"]) / max(measured["value"], 1.0)
        if abs(err) * 100.0 > tol:
            fails.append(
                f"PARITY {e['name']}: sim {int(e['value']):,} vs measured "
                f"{int(measured['value']):,} ({err * 100.0:+.1f}% "
                f"> +/-{tol:.0f}%)")
    return fails

"""Activation-memory accounting — the harness behind the paper's >50%
activation-saving claim (§5.2), made regression-testable.

Three independent accountants per (model config x checkpoint policy x
grouped-GEMM backend), all on abstract shapes (no arrays allocated):

  * **measured** — ``jax.jit(grad(loss)).lower(...).compile()
    .memory_analysis()``: XLA's temp/argument/output buffer sizes for the
    compiled fwd+bwd;
  * **autodiff residuals** — ``saved_residuals`` (the JAX analogue of the
    paper's PyTorch saved-tensor hooks), parameters excluded — what autodiff
    *saves* under the policy;
  * **static estimate** — ``core.checkpoint.estimate_saved_bytes``, computed
    from the policy's tag set and the config's shapes alone.  Exact for the
    name-based policies and completely version-independent, so it is the
    tightest regression gate.

``memory_suite`` flattens the reports into ``repro.bench.record`` entries and
couples in the roofline model (``roofline.analyze_compiled`` on the same
compiled step), so the tracked ``BENCH_memory.json`` is the single report
both measured and modeled numbers live in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.record import entry
from repro.compat import saved_residual_nbytes
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import gmm_backend as GB
from repro.core.checkpoint import estimate_saved_bytes
from repro.models import transformer as T

#: policy order used by suites and by the ordering assertions in tests.
POLICY_ORDER = ("none", "paper_min", "paper", "dots", "full")


def bench_config():
    """The small MoE config every tracked bench number is measured on (CPU
    container scale; the same harness takes any ``ModelConfig``)."""
    return get_config("qwen3_moe_30b_a3b").reduced().replace(
        name="tiny_moe", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, num_experts=4, top_k=2, moe_d_ff=128,
        vocab_size=128, dtype="float32", scan_layers=True)


def bench_dense_config():
    """Dense SwiGLU companion config: its FFN carries the full A/B/Y_swi tag
    set, so it is where the strict ``none < paper_min < paper < full``
    residual ordering is measurable (the MoE expert FFN manages its own
    residuals inside the custom VJP)."""
    return get_config("yi_6b").reduced().replace(
        name="tiny_dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=128,
        dtype="float32", scan_layers=True)


def _loss_fn(cfg):
    def loss(params, tokens):
        batch = {"tokens": tokens, "labels": tokens}
        return T.train_loss(params, batch, cfg)[0]
    return loss


def _abstract_args(cfg, batch: int, seq: int):
    params = jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return params, tokens


def residual_bytes(cfg, policy: str, *, batch: int = 2, seq: int = 32) -> int:
    """Activation bytes autodiff saves for backward under ``policy``
    (arguments/parameters excluded)."""
    cfg = cfg.replace(remat_policy=policy)
    return saved_residual_nbytes(_loss_fn(cfg), *_abstract_args(cfg, batch, seq))


def activation_memory_report(cfg, policy: str, *, backend: str | None = None,
                             batch: int = 2, seq: int = 32,
                             with_roofline: bool = False,
                             with_residuals: bool = True) -> dict:
    """Compile fwd+bwd of the train loss under (policy, backend) and account
    its memory three ways.  Returns a flat dict of numbers (plus the roofline
    analysis dict when requested).  ``with_residuals=False`` skips the
    saved-residuals trace and the static estimate (they are backend-
    independent — callers sweeping the backend axis need them only once)."""
    rb = GB.resolve(backend, config=cfg.gmm_backend)
    cfg = cfg.replace(remat_policy=policy, gmm_backend=rb.name)
    args = _abstract_args(cfg, batch, seq)
    grad = jax.grad(_loss_fn(cfg))
    with GB.use_backend(rb.name):   # pin the trace to the stamped backend
        compiled = jax.jit(grad).lower(*args).compile()
    mem = compiled.memory_analysis()
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    report = {
        "config": cfg.name, "policy": policy, "backend": rb.name,
        "backend_source": rb.source,
        "batch": batch, "seq": seq,
        "arg_bytes": arg_b, "out_bytes": out_b, "temp_bytes": tmp_b,
        "peak_bytes": arg_b + out_b + tmp_b - alias_b,
        "residual_bytes": (residual_bytes(cfg, policy, batch=batch, seq=seq)
                           if with_residuals else None),
        "est_saved_bytes": (estimate_saved_bytes(cfg, policy, batch * seq)
                            if with_residuals else None),
    }
    if with_roofline:
        from repro.roofline import analyze_compiled
        shape = InputShape("bench", seq, batch, "train")
        report["roofline"] = analyze_compiled(compiled, cfg, shape, n_chips=1)
    return report


def train_step_memory_entries(cfg, *, batch: int = 2, seq: int = 32) -> list:
    """Whole-train-step (loss + grads + AdamW) memory via the train loop's
    ``compiled_step_memory`` hook."""
    from repro.configs.base import TrainConfig
    from repro.train.loop import compiled_step_memory
    tcfg = TrainConfig(batch_size=batch, seq_len=seq)
    mem = compiled_step_memory(cfg, tcfg)
    prefix = f"memory/{cfg.name}/train_step"
    # The step's resolved backend rides in the meta — stamped from the
    # resolution the compiled step actually used, not from the env var.
    meta = {"batch": batch, "seq": seq, "gmm_backend": mem["gmm_backend"]}
    return [
        entry(f"{prefix}/temp_bytes", mem["temp_bytes"],
              kind="temp_bytes", unit="bytes", tolerance_pct=100.0, **meta),
        entry(f"{prefix}/arg_bytes", mem["arg_bytes"],
              kind="arg_bytes", unit="bytes", tolerance_pct=20.0, **meta),
    ]


def memory_suite(*, small: bool = False) -> list:
    """All memory-axis entries: (config x policy x backend) reports, the
    roofline coupling, and the train-step axis.  The MoE config sweeps the
    grouped-GEMM backend axis; the dense config carries the full FFN tag set
    (and therefore the strict policy ordering)."""
    auto = GB.resolve(None).name
    # Entry names embed the backend, so the committed baseline must only
    # contain names every CI leg reproduces: the portable `segment` is always
    # swept (and is the dense config's only axis — it has no grouped GEMM);
    # the auto-resolved backend adds entries on JAX versions that have it,
    # which enter the gate once committed from such a version.
    plan = [(bench_config(), list(dict.fromkeys(["segment", auto]))),
            (bench_dense_config(), ["segment"])]
    batch, seq = (2, 32) if small else (4, 64)
    out = []
    for cfg, backends in plan:
        for policy in POLICY_ORDER:
            for i, backend in enumerate(backends):
                with_roofline = policy == "paper" and i == 0
                r = activation_memory_report(cfg, policy, backend=backend,
                                             batch=batch, seq=seq,
                                             with_roofline=with_roofline,
                                             with_residuals=(i == 0))
                prefix = f"memory/{cfg.name}/{policy}/{backend}"
                meta = {"batch": batch, "seq": seq}
                out.append(entry(f"{prefix}/temp_bytes", r["temp_bytes"],
                                 kind="temp_bytes", unit="bytes",
                                 tolerance_pct=100.0, **meta))
                out.append(entry(f"{prefix}/peak_bytes", r["peak_bytes"],
                                 kind="peak_bytes", unit="bytes",
                                 tolerance_pct=100.0, **meta))
                if i == 0:  # backend-independent accountants: record once
                    out.append(entry(
                        f"memory/{cfg.name}/{policy}/residual_bytes",
                        r["residual_bytes"], kind="residual_bytes",
                        unit="bytes", tolerance_pct=20.0, **meta))
                    if r["est_saved_bytes"] is not None:
                        out.append(entry(
                            f"memory/{cfg.name}/{policy}/est_saved_bytes",
                            r["est_saved_bytes"], kind="est_saved_bytes",
                            unit="bytes", tolerance_pct=20.0, **meta))
                if with_roofline:
                    from repro.roofline import bench_entries
                    out += bench_entries(r["roofline"],
                                         f"memory/{cfg.name}/roofline")
    out += train_step_memory_entries(bench_config(), batch=batch, seq=seq)
    return out

"""Memory/perf regression harness (``python -m repro.bench``).

Unifies the loose ``benchmarks/*.py`` scripts into an importable, tested
subsystem: ``memory`` (activation-memory accounting), ``timing``
(kernel/backend wall time + HLO traffic), ``record`` (the tracked
``BENCH_*.json`` schema and the ``--check`` regression gate), and
``paper_tables`` (Figures 3-6 analogues).  See README §Benchmark harness.
"""

from repro.bench.record import (DEFAULT_TOLERANCE_PCT, SCHEMA_VERSION,
                                check_records, compare_records, entry,
                                load_record, make_record, write_record)

__all__ = [
    "DEFAULT_TOLERANCE_PCT", "SCHEMA_VERSION", "check_records",
    "compare_records", "entry", "load_record", "make_record", "write_record",
]

"""Serving-engine benchmark suite — the paged-KV decode story as tracked,
gated numbers.

Three entry families on the bench MoE config:

* ``serving/parity/*`` — the left-pad regression, run as a measurement:
  a mixed-prompt-length batch through the continuous scheduler vs each
  request solo, token-mismatch count (MUST be zero — batched output may not
  depend on batch-mates).
* ``serving/sched/*`` — continuous-batching accounting: decode slot-steps
  must equal ``sum(T_r - 1)`` exactly (finished requests burn no decode
  FLOPs, one prefill logit per request), plus blocked-admission and
  page-pool stats under a page budget.
* ``serving/kv/*`` — MEASURED cache bytes (``kv_quant.cache_bytes`` over
  the actual pytrees): the int8 paged pool vs the seed's dense bf16 slot
  cache, per cached token.  The same-run gate requires >= 1.8x fewer bytes
  per token, and throughput (tokens/s) rides along informationally.
* ``serving/prefix/*`` — copy-on-write prefix sharing: a page-aligned
  same-prompt pair through a ``prefix_cache=True`` engine vs the
  no-sharing cost.  The same-run gate requires the pair's measured
  ``prefill_tokens`` to undercut 2x solo by AT LEAST one full page, with
  exact token parity against the solo run (sharing may not change tokens).
* ``serving/pipeline/*`` — the async three-stage runtime
  (``serve.runtime.AsyncServeRuntime``) vs the synchronous engine on the
  same requests: token mismatches (same-run gate: MUST be zero — the
  pipelined scheduler is token-identical under a fixed seed) plus
  pipelined throughput informationally.

The deterministic entries (byte counts, scheduler counts, parity) are
baseline-gated at 0% tolerance; wall-clock entries are informational (CI
runners are noisy).  ``serving_gate_failures`` adds the baseline-independent
same-run pairings, like ``timing.fused_gate_failures`` and
``memory.sim_parity_failures``.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.bench.memory import bench_config
from repro.bench.record import entry
from repro.core import memsim
from repro.models import transformer as T
from repro.serve import kv_quant as KQ
from repro.serve.engine import Request, ServeEngine
from repro.serve.runtime import AsyncServeRuntime

#: required measured-bytes advantage of the int8 paged pool over bf16 dense
#: slots, per cached token (the acceptance bar's number).
INT8_KV_RATIO_MIN = 1.8

_SLOTS = 2
_CAPACITY = 64
_PAGE_SIZE = 8


def _prompts(cfg, n: int, *, seed: int = 0) -> list[np.ndarray]:
    """Mixed-length prompts (the shape that exposed the left-pad bug)."""
    rng = np.random.default_rng(seed)
    lens = [1 + (3 * i) % 8 for i in range(n)]
    return [rng.integers(1, cfg.vocab_size, size=L).astype(np.int32)
            for L in lens]


def _requests(prompts, cfg, max_new: int) -> list[Request]:
    # eos_id outside the vocab: every request runs to max_new_tokens, so the
    # scheduler counts below are exact and version-independent.
    return [Request(prompt=p, max_new_tokens=max_new, eos_id=cfg.vocab_size)
            for p in prompts]


def _engine(cfg, params, **kw) -> ServeEngine:
    return ServeEngine(cfg, params, batch_slots=_SLOTS, capacity=_CAPACITY,
                       page_size=_PAGE_SIZE, **kw)


def serving_suite(*, small: bool = False) -> list:
    cfg = bench_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n_req = 4 if small else 8
    max_new = 5 if small else 9
    prompts = _prompts(cfg, n_req)

    # -- batched-vs-solo parity (the left-pad bug, measured) ----------------
    batched = _engine(cfg, params)
    b_reqs = _requests(prompts, cfg, max_new)
    t0 = time.perf_counter()
    batched.generate(b_reqs)
    batched_s = time.perf_counter() - t0
    mismatches = 0
    for p, r in zip(prompts, b_reqs):
        solo = _engine(cfg, params)
        s_req = solo.generate(_requests([p], cfg, max_new))[0]
        mismatches += sum(a != b for a, b in
                          zip(r.out_tokens, s_req.out_tokens))
        mismatches += abs(len(r.out_tokens) - len(s_req.out_tokens))

    # -- continuous-scheduler accounting ------------------------------------
    st = batched.stats
    expected_slot_tokens = sum(len(r.out_tokens) - 1 for r in b_reqs)
    gen_tokens = sum(len(r.out_tokens) for r in b_reqs)

    # -- measured KV bytes: int8 paged pool vs dense bf16 slots -------------
    num_pages = batched.num_pages
    paged_int8 = T.init_paged_cache(cfg, num_pages, _PAGE_SIZE,
                                    quantized=True)
    dense_bf16 = T.init_cache(cfg.replace(dtype="bfloat16"), _SLOTS,
                              _CAPACITY)
    int8_per_tok = KQ.cache_bytes(paged_int8) / (num_pages * _PAGE_SIZE)
    bf16_per_tok = KQ.cache_bytes(dense_bf16) / (_SLOTS * _CAPACITY)

    # -- int8 engine: same requests, tokens/s + stats ------------------------
    int8_eng = _engine(cfg, params, kv_dtype="int8")
    i_reqs = _requests(prompts, cfg, max_new)
    t0 = time.perf_counter()
    int8_eng.generate(i_reqs)
    int8_s = time.perf_counter() - t0
    int8_gen = sum(len(r.out_tokens) for r in i_reqs)

    sim = memsim.simulate_serve(
        cfg, batch_slots=_SLOTS, num_pages=num_pages, page_size=_PAGE_SIZE,
        prefill_tokens=max(p.size for p in prompts), quantized=False)

    # -- prefix sharing: page-aligned pair vs no-sharing ---------------------
    # One prompt of exactly two full pages, served twice through a
    # prefix_cache engine (batch_slots=1 so the first finishes — and
    # donates its pages — before the second is admitted).  The second
    # request maps both pages read-only and re-feeds only its last prompt
    # token into a COW fork, so the pair's prefill_tokens undercuts 2x the
    # solo cost by a page-and-change.
    prng = np.random.default_rng(1)
    shared_prompt = prng.integers(1, cfg.vocab_size,
                                  size=2 * _PAGE_SIZE).astype(np.int32)
    solo_pre = ServeEngine(cfg, params, batch_slots=1, capacity=_CAPACITY,
                           page_size=_PAGE_SIZE)
    solo_req = solo_pre.generate(
        _requests([shared_prompt], cfg, max_new))[0]
    nosharing_pt = 2 * solo_pre.stats["prefill_tokens"]
    pre_eng = ServeEngine(cfg, params, batch_slots=1, capacity=_CAPACITY,
                          page_size=_PAGE_SIZE, prefix_cache=True)
    pre_reqs = _requests([shared_prompt, shared_prompt], cfg, max_new)
    for r in pre_reqs:
        pre_eng.enqueue(r)
    pre_eng.run()
    pst = pre_eng.stats
    prefix_mismatches = 0
    for r in pre_reqs:
        prefix_mismatches += sum(a != b for a, b in
                                 zip(r.out_tokens, solo_req.out_tokens))
        prefix_mismatches += abs(len(r.out_tokens)
                                 - len(solo_req.out_tokens))
    sim_noshare = memsim.simulate_serve(
        cfg, batch_slots=_SLOTS, num_pages=num_pages, page_size=_PAGE_SIZE,
        prefill_tokens=2 * _PAGE_SIZE, quantized=False)
    sim_shared = memsim.simulate_serve(
        cfg, batch_slots=_SLOTS, num_pages=num_pages, page_size=_PAGE_SIZE,
        prefill_tokens=2 * _PAGE_SIZE, shared_pages=2, quantized=False)

    # -- pipelined async runtime vs the synchronous engine -------------------
    async_eng = _engine(cfg, params)
    a_reqs = _requests(prompts, cfg, max_new)
    t0 = time.perf_counter()
    with AsyncServeRuntime(async_eng, queue_depth=2,
                           transfer_buffers=2) as rt:
        rt.run(a_reqs)
    async_s = time.perf_counter() - t0
    async_gen = sum(len(r.out_tokens) for r in a_reqs)
    async_mismatches = 0
    for sync_r, async_r in zip(b_reqs, a_reqs):
        async_mismatches += sum(a != b for a, b in
                                zip(sync_r.out_tokens, async_r.out_tokens))
        async_mismatches += abs(len(sync_r.out_tokens)
                                - len(async_r.out_tokens))

    det = dict(kind="serving", tolerance_pct=0.0)
    info = dict(kind="serving", tolerance_pct=None)
    return [
        entry("serving/parity/mismatched_tokens", mismatches, unit="tokens",
              n_requests=n_req, max_new=max_new, **det),
        entry("serving/sched/decode_slot_tokens", st["decode_slot_tokens"],
              unit="tokens", **det),
        entry("serving/sched/expected_slot_tokens", expected_slot_tokens,
              unit="tokens", **det),
        entry("serving/sched/decode_steps", st["decode_steps"],
              unit="steps", **det),
        entry("serving/sched/blocked_admissions", st["blocked_admissions"],
              unit="events", **info),
        entry("serving/sched/peak_pages_used", st["peak_pages_used"],
              unit="pages", num_pages=num_pages, **det),
        entry("serving/kv/int8_paged_bytes_per_token", int8_per_tok,
              unit="bytes", num_pages=num_pages, page_size=_PAGE_SIZE,
              **det),
        entry("serving/kv/bf16_dense_bytes_per_token", bf16_per_tok,
              unit="bytes", slots=_SLOTS, capacity=_CAPACITY, **det),
        entry("serving/kv/sim_serve_peak_bytes", sim.peak_bytes,
              unit="bytes", peak_phase=sim.peak_phase, **det),
        entry("serving/throughput/tokens_per_s",
              gen_tokens / max(batched_s, 1e-9), unit="tokens/s",
              generated=gen_tokens, **info),
        entry("serving/throughput/int8_tokens_per_s",
              int8_gen / max(int8_s, 1e-9), unit="tokens/s",
              generated=int8_gen, **info),
        entry("serving/prefix/prefill_tokens_nosharing", nosharing_pt,
              unit="tokens", prompt_pages=2, page_size=_PAGE_SIZE, **det),
        entry("serving/prefix/prefill_tokens_shared", pst["prefill_tokens"],
              unit="tokens", prompt_pages=2, page_size=_PAGE_SIZE, **det),
        entry("serving/prefix/hits", pst["prefix_hits"], unit="hits",
              misses=pst["prefix_misses"],
              shared_pages=pst["shared_pages_mapped"], **det),
        entry("serving/prefix/cow_forks", pst["cow_forks"], unit="forks",
              **det),
        entry("serving/prefix/mismatched_tokens", prefix_mismatches,
              unit="tokens", **det),
        entry("serving/kv/sim_shared_prefill_bytes",
              sim_shared.phases[0].held_bytes
              + sim_shared.phases[0].transient_bytes, unit="bytes",
              nosharing_prefill_bytes=sim_noshare.phases[0].held_bytes
              + sim_noshare.phases[0].transient_bytes, shared_pages=2,
              **det),
        entry("serving/pipeline/async_sync_mismatches", async_mismatches,
              unit="tokens", n_requests=n_req, **det),
        entry("serving/pipeline/async_tokens_per_s",
              async_gen / max(async_s, 1e-9), unit="tokens/s",
              generated=async_gen, **info),
    ]


def serving_gate_failures(entries: list) -> list:
    """Baseline-independent same-run gates for the serving leg:

    1. batched-vs-solo token parity must be EXACT (the left-pad bugfix);
    2. decode slot-steps must equal ``sum(T_r - 1)`` — finished requests may
       not burn decode FLOPs;
    3. the measured int8 paged pool must be >= ``INT8_KV_RATIO_MIN``x
       smaller per cached token than the seed's dense bf16 slot cache;
    4. a page-aligned shared-prefix pair must prefill STRICTLY fewer tokens
       than 2x solo — by at least one full page — with exact token parity
       (prefix sharing is a cost optimization, never a numerics change);
    5. the pipelined async runtime must be token-identical to the
       synchronous engine on the same requests under the fixed seed.

    Returns human-readable failure lines (empty == all gates hold)."""
    by_name = {e["name"]: e for e in entries}
    need = ("serving/parity/mismatched_tokens",
            "serving/sched/decode_slot_tokens",
            "serving/sched/expected_slot_tokens",
            "serving/kv/int8_paged_bytes_per_token",
            "serving/kv/bf16_dense_bytes_per_token",
            "serving/prefix/prefill_tokens_nosharing",
            "serving/prefix/prefill_tokens_shared",
            "serving/prefix/mismatched_tokens",
            "serving/pipeline/async_sync_mismatches")
    if not any(n in by_name for n in need):
        # No serving family at all (synthetic/legacy record): nothing to
        # pair.  Fresh runs always emit the family via ``serving_suite``.
        return []
    if not all(n in by_name for n in need):
        return ["SERVING serving/* family incomplete in this run "
                "(regenerate the record with the current suite)"]
    fails = []
    par = by_name["serving/parity/mismatched_tokens"]["value"]
    if par != 0:
        fails.append(f"SERVING parity: {int(par)} token(s) differ between "
                     "batched and solo runs; batched output must not depend "
                     "on batch-mates")
    got = by_name["serving/sched/decode_slot_tokens"]["value"]
    want = by_name["serving/sched/expected_slot_tokens"]["value"]
    if got != want:
        fails.append(f"SERVING scheduler: {int(got)} decode slot-tokens vs "
                     f"sum(T_r - 1) = {int(want)}; finished requests must "
                     "release their slots")
    int8 = by_name["serving/kv/int8_paged_bytes_per_token"]["value"]
    bf16 = by_name["serving/kv/bf16_dense_bytes_per_token"]["value"]
    ratio = bf16 / max(int8, 1e-9)
    if ratio < INT8_KV_RATIO_MIN:
        fails.append(f"SERVING kv bytes: int8 paged pool is only {ratio:.2f}x"
                     f" smaller per token than dense bf16 slots "
                     f"(need >= {INT8_KV_RATIO_MIN}x)")
    noshare = by_name["serving/prefix/prefill_tokens_nosharing"]["value"]
    shared = by_name["serving/prefix/prefill_tokens_shared"]["value"]
    page = by_name["serving/prefix/prefill_tokens_shared"]["meta"].get(
        "page_size", _PAGE_SIZE)
    if noshare - shared < page:
        fails.append(f"SERVING prefix: shared pair prefilled {int(shared)} "
                     f"tokens vs {int(noshare)} without sharing; must save "
                     f"at least one full page ({int(page)} tokens)")
    pmis = by_name["serving/prefix/mismatched_tokens"]["value"]
    if pmis != 0:
        fails.append(f"SERVING prefix: {int(pmis)} token(s) differ between "
                     "shared-prefix and solo runs; COW sharing must not "
                     "change tokens")
    amis = by_name["serving/pipeline/async_sync_mismatches"]["value"]
    if amis != 0:
        fails.append(f"SERVING pipeline: {int(amis)} token(s) differ "
                     "between the async runtime and the synchronous engine; "
                     "the pipelined scheduler must be token-identical under "
                     "a fixed seed")
    return fails

"""Serving-engine benchmark suite — the paged-KV decode story as tracked,
gated numbers.

Three entry families on the bench MoE config:

* ``serving/parity/*`` — the left-pad regression, run as a measurement:
  a mixed-prompt-length batch through the continuous scheduler vs each
  request solo, token-mismatch count (MUST be zero — batched output may not
  depend on batch-mates).
* ``serving/sched/*`` — continuous-batching accounting: decode slot-steps
  must equal ``sum(T_r - 1)`` exactly (finished requests burn no decode
  FLOPs, one prefill logit per request), plus blocked-admission and
  page-pool stats under a page budget.
* ``serving/kv/*`` — MEASURED cache bytes (``kv_quant.cache_bytes`` over
  the actual pytrees): the int8 paged pool vs the seed's dense bf16 slot
  cache, per cached token.  The same-run gate requires >= 1.8x fewer bytes
  per token, and throughput (tokens/s) rides along informationally.

The deterministic entries (byte counts, scheduler counts, parity) are
baseline-gated at 0% tolerance; wall-clock entries are informational (CI
runners are noisy).  ``serving_gate_failures`` adds the baseline-independent
same-run pairings, like ``timing.fused_gate_failures`` and
``memory.sim_parity_failures``.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.bench.memory import bench_config
from repro.bench.record import entry
from repro.core import memsim
from repro.models import transformer as T
from repro.serve import kv_quant as KQ
from repro.serve.engine import Request, ServeEngine

#: required measured-bytes advantage of the int8 paged pool over bf16 dense
#: slots, per cached token (the acceptance bar's number).
INT8_KV_RATIO_MIN = 1.8

_SLOTS = 2
_CAPACITY = 64
_PAGE_SIZE = 8


def _prompts(cfg, n: int, *, seed: int = 0) -> list[np.ndarray]:
    """Mixed-length prompts (the shape that exposed the left-pad bug)."""
    rng = np.random.default_rng(seed)
    lens = [1 + (3 * i) % 8 for i in range(n)]
    return [rng.integers(1, cfg.vocab_size, size=L).astype(np.int32)
            for L in lens]


def _requests(prompts, cfg, max_new: int) -> list[Request]:
    # eos_id outside the vocab: every request runs to max_new_tokens, so the
    # scheduler counts below are exact and version-independent.
    return [Request(prompt=p, max_new_tokens=max_new, eos_id=cfg.vocab_size)
            for p in prompts]


def _engine(cfg, params, **kw) -> ServeEngine:
    return ServeEngine(cfg, params, batch_slots=_SLOTS, capacity=_CAPACITY,
                       page_size=_PAGE_SIZE, **kw)


def serving_suite(*, small: bool = False) -> list:
    cfg = bench_config()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n_req = 4 if small else 8
    max_new = 5 if small else 9
    prompts = _prompts(cfg, n_req)

    # -- batched-vs-solo parity (the left-pad bug, measured) ----------------
    batched = _engine(cfg, params)
    b_reqs = _requests(prompts, cfg, max_new)
    t0 = time.perf_counter()
    batched.generate(b_reqs)
    batched_s = time.perf_counter() - t0
    mismatches = 0
    for p, r in zip(prompts, b_reqs):
        solo = _engine(cfg, params)
        s_req = solo.generate(_requests([p], cfg, max_new))[0]
        mismatches += sum(a != b for a, b in
                          zip(r.out_tokens, s_req.out_tokens))
        mismatches += abs(len(r.out_tokens) - len(s_req.out_tokens))

    # -- continuous-scheduler accounting ------------------------------------
    st = batched.stats
    expected_slot_tokens = sum(len(r.out_tokens) - 1 for r in b_reqs)
    gen_tokens = sum(len(r.out_tokens) for r in b_reqs)

    # -- measured KV bytes: int8 paged pool vs dense bf16 slots -------------
    num_pages = batched.num_pages
    paged_int8 = T.init_paged_cache(cfg, num_pages, _PAGE_SIZE,
                                    quantized=True)
    dense_bf16 = T.init_cache(cfg.replace(dtype="bfloat16"), _SLOTS,
                              _CAPACITY)
    int8_per_tok = KQ.cache_bytes(paged_int8) / (num_pages * _PAGE_SIZE)
    bf16_per_tok = KQ.cache_bytes(dense_bf16) / (_SLOTS * _CAPACITY)

    # -- int8 engine: same requests, tokens/s + stats ------------------------
    int8_eng = _engine(cfg, params, kv_dtype="int8")
    i_reqs = _requests(prompts, cfg, max_new)
    t0 = time.perf_counter()
    int8_eng.generate(i_reqs)
    int8_s = time.perf_counter() - t0
    int8_gen = sum(len(r.out_tokens) for r in i_reqs)

    sim = memsim.simulate_serve(
        cfg, batch_slots=_SLOTS, num_pages=num_pages, page_size=_PAGE_SIZE,
        prefill_tokens=max(p.size for p in prompts), quantized=False)

    det = dict(kind="serving", tolerance_pct=0.0)
    info = dict(kind="serving", tolerance_pct=None)
    return [
        entry("serving/parity/mismatched_tokens", mismatches, unit="tokens",
              n_requests=n_req, max_new=max_new, **det),
        entry("serving/sched/decode_slot_tokens", st["decode_slot_tokens"],
              unit="tokens", **det),
        entry("serving/sched/expected_slot_tokens", expected_slot_tokens,
              unit="tokens", **det),
        entry("serving/sched/decode_steps", st["decode_steps"],
              unit="steps", **det),
        entry("serving/sched/blocked_admissions", st["blocked_admissions"],
              unit="events", **info),
        entry("serving/sched/peak_pages_used", st["peak_pages_used"],
              unit="pages", num_pages=num_pages, **det),
        entry("serving/kv/int8_paged_bytes_per_token", int8_per_tok,
              unit="bytes", num_pages=num_pages, page_size=_PAGE_SIZE,
              **det),
        entry("serving/kv/bf16_dense_bytes_per_token", bf16_per_tok,
              unit="bytes", slots=_SLOTS, capacity=_CAPACITY, **det),
        entry("serving/kv/sim_serve_peak_bytes", sim.peak_bytes,
              unit="bytes", peak_phase=sim.peak_phase, **det),
        entry("serving/throughput/tokens_per_s",
              gen_tokens / max(batched_s, 1e-9), unit="tokens/s",
              generated=gen_tokens, **info),
        entry("serving/throughput/int8_tokens_per_s",
              int8_gen / max(int8_s, 1e-9), unit="tokens/s",
              generated=int8_gen, **info),
    ]


def serving_gate_failures(entries: list) -> list:
    """Baseline-independent same-run gates for the serving leg:

    1. batched-vs-solo token parity must be EXACT (the left-pad bugfix);
    2. decode slot-steps must equal ``sum(T_r - 1)`` — finished requests may
       not burn decode FLOPs;
    3. the measured int8 paged pool must be >= ``INT8_KV_RATIO_MIN``x
       smaller per cached token than the seed's dense bf16 slot cache.

    Returns human-readable failure lines (empty == all gates hold)."""
    by_name = {e["name"]: e for e in entries}
    need = ("serving/parity/mismatched_tokens",
            "serving/sched/decode_slot_tokens",
            "serving/sched/expected_slot_tokens",
            "serving/kv/int8_paged_bytes_per_token",
            "serving/kv/bf16_dense_bytes_per_token")
    if not any(n in by_name for n in need):
        # No serving family at all (synthetic/legacy record): nothing to
        # pair.  Fresh runs always emit the family via ``serving_suite``.
        return []
    if not all(n in by_name for n in need):
        return ["SERVING serving/* family incomplete in this run "
                "(regenerate the record with the current suite)"]
    fails = []
    par = by_name["serving/parity/mismatched_tokens"]["value"]
    if par != 0:
        fails.append(f"SERVING parity: {int(par)} token(s) differ between "
                     "batched and solo runs; batched output must not depend "
                     "on batch-mates")
    got = by_name["serving/sched/decode_slot_tokens"]["value"]
    want = by_name["serving/sched/expected_slot_tokens"]["value"]
    if got != want:
        fails.append(f"SERVING scheduler: {int(got)} decode slot-tokens vs "
                     f"sum(T_r - 1) = {int(want)}; finished requests must "
                     "release their slots")
    int8 = by_name["serving/kv/int8_paged_bytes_per_token"]["value"]
    bf16 = by_name["serving/kv/bf16_dense_bytes_per_token"]["value"]
    ratio = bf16 / max(int8, 1e-9)
    if ratio < INT8_KV_RATIO_MIN:
        fails.append(f"SERVING kv bytes: int8 paged pool is only {ratio:.2f}x"
                     f" smaller per token than dense bf16 slots "
                     f"(need >= {INT8_KV_RATIO_MIN}x)")
    return fails

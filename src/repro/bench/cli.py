"""``python -m repro.bench`` — run the tracked benchmark suites and/or gate
against the committed baselines.

    python -m repro.bench --small              # run + refresh BENCH_*.json
    python -m repro.bench --small --check      # run + fail on regression
    python -m repro.bench --check --record r.json   # gate a pre-built record

Default mode writes ``BENCH_kernels.json`` / ``BENCH_memory.json`` to
``--baseline-dir`` (the repo root — commit them; they ARE the baseline).
``--check`` never rewrites baselines: it runs the suites (or loads
``--record``), compares entry-by-entry against the committed files, prints a
report, and exits 1 on any gated regression.  ``--out-dir`` additionally
saves the freshly measured records (CI uploads these as artifacts).
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.bench import record as R

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def _run_suite(suite: str, small: bool) -> dict:
    if suite == "kernels":
        from repro.bench.timing import kernels_suite
        entries = kernels_suite(small=small)
    elif suite == "memory":
        from repro.bench.memory import memory_suite
        entries = memory_suite(small=small)
    elif suite == "serving":
        from repro.bench.serving import serving_suite
        entries = serving_suite(small=small)
    else:
        raise ValueError(suite)
    return R.make_record(suite, entries, config={"small": small})


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.bench",
                                 description=__doc__.split("\n\n")[0])
    ap.add_argument("--suite", default="all",
                    choices=["all", "kernels", "memory", "serving"])
    ap.add_argument("--small", action="store_true",
                    help="reduced sweep (CI / tests)")
    ap.add_argument("--check", action="store_true",
                    help="compare against committed baselines; exit 1 on "
                         "regression; never rewrite baselines")
    ap.add_argument("--record", default=None,
                    help="with --check: gate this pre-built record file "
                         "instead of running the suites")
    ap.add_argument("--baseline", default=None,
                    help="explicit baseline record file (default: the "
                         "committed BENCH_<suite>.json)")
    ap.add_argument("--baseline-dir", default=_REPO_ROOT,
                    help="where committed BENCH_*.json live / are written")
    ap.add_argument("--out-dir", default=None,
                    help="also write freshly measured records here "
                         "(artifacts; independent of the baselines)")
    args = ap.parse_args(argv)

    if args.record:
        if not args.check:
            ap.error("--record only makes sense with --check")
        records = [R.load_record(args.record)]
    else:
        suites = (["kernels", "memory", "serving"] if args.suite == "all"
                  else [args.suite])
        records = []
        for suite in suites:
            print(f"# running {suite} suite (small={args.small}) ...",
                  file=sys.stderr)
            records.append(_run_suite(suite, args.small))

    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)
        for rec in records:
            path = os.path.join(args.out_dir, R.BENCH_FILES[rec["suite"]])
            print(f"# wrote {R.write_record(rec, path)}", file=sys.stderr)

    if not args.check:
        for rec in records:
            path = os.path.join(args.baseline_dir, R.BENCH_FILES[rec["suite"]])
            print(f"# baseline updated: {R.write_record(rec, path)}")
        return 0

    ok = True
    for rec in records:
        base_path = args.baseline or os.path.join(
            args.baseline_dir, R.BENCH_FILES[rec["suite"]])
        if not os.path.exists(base_path):
            print(f"MISSING BASELINE {base_path} for suite {rec['suite']!r} "
                  "(run `python -m repro.bench` and commit the result)")
            ok = False
            continue
        rec_ok, lines = R.check_records(rec, R.load_record(base_path))
        print(f"== {rec['suite']} vs {base_path} ==")
        for line in lines:
            print(line)
        ok = ok and rec_ok

    # Simulated-vs-measured peak parity: gate the memsim model against the
    # XLA memory_analysis() peaks of THIS run (baseline-independent — the
    # simulator must track what the current jax pin actually allocates).
    from repro.bench.memory import sim_parity_failures
    for rec in records:
        if rec["suite"] != "memory":
            continue
        fails = sim_parity_failures(rec["entries"])
        n_sim = sum(e["name"].startswith("peak_sim/")
                    for e in rec["entries"])
        print(f"== memory sim-vs-measured parity ({n_sim} entries) ==")
        for line in fails:
            print(line)
        if not fails:
            print("OK: every peak_sim/* entry within tolerance of its "
                  "measured peak")
        ok = ok and not fails

    # Fused-path pairing gates: the fused MoE layer must save zero (L*k, .)
    # slot buffers and must not be slower than the unfused Pallas
    # composition measured in the SAME run (baseline-independent, like the
    # sim-parity gate — wall time only pairs against itself).
    from repro.bench.timing import fused_gate_failures
    for rec in records:
        if rec["suite"] != "kernels":
            continue
        fails = fused_gate_failures(rec["entries"])
        print("== fused-path same-run gates ==")
        for line in fails:
            print(line)
        if not fails:
            print("OK: fused path saves no slot buffers and is not slower "
                  "than the unfused pallas path")
        ok = ok and not fails

    # Parallel same-run gates: the roofline cost model's predicted ep vs
    # ep_a2a ranking must agree with the wall times measured in THIS run,
    # the chunked-overlap exchange must hold parity with the unchunked one,
    # and `auto` must have resolved to the predicted winner.
    from repro.bench.timing import parallel_gate_failures
    for rec in records:
        if rec["suite"] != "kernels":
            continue
        fails = parallel_gate_failures(rec["entries"])
        print("== parallel same-run gates ==")
        for line in fails:
            print(line)
        if not fails:
            print("OK: predicted mode ranking agrees with measured, "
                  "chunked exchange holds parity, auto picked the winner")
        ok = ok and not fails

    # Serving same-run gates: batched-vs-solo token parity (the left-pad
    # bugfix), decode slot-steps == sum(T_r - 1) (continuous slot release),
    # the int8 paged pool's measured bytes-per-token advantage over dense
    # bf16 slots, the shared-prefix pair's prefill-token saving (>= one
    # full page vs 2x solo, tokens unchanged), and async-pipeline ==
    # sync-engine token identity — all pairings within THIS run's record.
    from repro.bench.serving import serving_gate_failures
    for rec in records:
        if rec["suite"] != "serving":
            continue
        fails = serving_gate_failures(rec["entries"])
        print("== serving same-run gates ==")
        for line in fails:
            print(line)
        if not fails:
            print("OK: batched==solo tokens, slots released on finish, "
                  "int8 paged KV >= 1.8x smaller than dense bf16 slots, "
                  "prefix pair >= 1 page cheaper than 2x solo, "
                  "async pipeline == sync engine")
        ok = ok and not fails
    return 0 if ok else 1

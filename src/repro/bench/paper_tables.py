"""Paper-table benchmarks (Figures 3–6 analogues): activation memory and
training-step time, MoEBlaze vs the MegaBlocks-style materialized baseline,
for conf1..conf7 x {SiLU, SwiGLU}.

Activation memory is measured two ways, both at the paper's FULL tensor
sizes (no execution needed):
  * saved-residual bytes via ``compat.saved_residuals`` (the JAX analogue of
    the paper's PyTorch saved-tensor hooks), parameters excluded;
  * XLA ``temp_size_in_bytes`` of the compiled fwd+bwd step (corroboration).

Step time is wall-clock on this CPU container at a reduced sequence length
(full conf sizes are TFLOP-scale — infeasible on 1 CPU core); it is a
*directional* proxy, the TPU performance story lives in §Roofline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.timing import median_time_us
from repro.compat import saved_residual_nbytes
from repro.configs.paper_tables import PAPER_TABLE1
from repro.core.baseline import moe_ffn_megablocks
from repro.core.moe_layer import moe_ffn_blaze
from repro.core.routing import build_dispatch, build_dispatch_sort, top_k_gating

IMPLS = ("blaze", "blaze_min", "blaze_x", "megablocks")

#: custom-VJP residual mode per blaze impl (see core/moe_layer.py):
#: paper-faithful, recompute-Y_swi, and the deepest recompute-A/B point a
#: ``moe:recompute=ffn_a,ffn_b`` checkpoint plan selects.
_RESIDUALS = {"blaze": "ab_yswi", "blaze_min": "ab", "blaze_x": "x"}


def _layer_fn(impl: str, act: str, E: int, k: int):
    def f(x, w1, w2, w3, wg):
        g = top_k_gating(x, wg, k)
        disp = build_dispatch(g.topk_experts, E)
        gates = g.topk_weights.astype(x.dtype)
        w2_ = w2 if act == "swiglu" else None
        if impl == "megablocks":
            y = moe_ffn_megablocks(x, gates, disp, w1, w3, w2_,
                                   activation=act)
        else:
            y = moe_ffn_blaze(x, gates, disp, w1, w3, w2_, activation=act,
                              residuals=_RESIDUALS[impl])
        return (y.astype(jnp.float32) ** 2).sum()
    return f


def _args(conf, *, seq_scale: float = 1.0, dtype=jnp.float32,
          abstract: bool = True):
    d, E, k, B, S = conf
    h = 4 * d
    L = max(int(B * S * seq_scale), 64)
    sds = jax.ShapeDtypeStruct
    shapes = [sds((L, d), dtype), sds((E, d, h), dtype),
              sds((E, d, h), dtype), sds((E, h, d), dtype),
              sds((d, E), dtype)]
    if abstract:
        return shapes
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, len(shapes))
    return [jax.random.normal(kk, s.shape, s.dtype) * 0.05
            for kk, s in zip(ks, shapes)]


def residual_bytes(conf, impl: str, act: str) -> int:
    """Activation bytes saved for backward (params excluded), full size."""
    d, E, k, B, S = conf
    f = _layer_fn(impl, act, E, k)
    return saved_residual_nbytes(f, *_args(conf))


def temp_bytes(conf, impl: str, act: str) -> int:
    """XLA temp buffer bytes for the compiled fwd+bwd at full size."""
    d, E, k, B, S = conf
    f = _layer_fn(impl, act, E, k)
    grad_f = jax.grad(f, argnums=(0, 1, 2, 3, 4))
    compiled = jax.jit(grad_f).lower(*_args(conf)).compile()
    return compiled.memory_analysis().temp_size_in_bytes


def step_time_us(conf, impl: str, act: str, *, seq_scale: float,
                 iters: int = 3) -> float:
    d, E, k, B, S = conf
    f = _layer_fn(impl, act, E, k)
    grad_f = jax.jit(jax.grad(f, argnums=(0, 1, 2, 3, 4)))
    args = _args(conf, seq_scale=seq_scale, abstract=False)
    return median_time_us(grad_f, *args, warmup=1, iters=iters)


def dispatch_build_us(conf, method: str, iters: int = 10) -> float:
    """Dispatch-structure construction time at FULL L·k (paper §6.4 factor 2:
    the dispatch pipeline cost)."""
    d, E, k, B, S = conf
    L = B * S
    key = jax.random.PRNGKey(0)
    scores = jax.random.normal(key, (L, E))
    _, topk = jax.lax.top_k(scores, k)
    topk = topk.astype(jnp.int32)
    builders = {"sortfree": build_dispatch, "sort": build_dispatch_sort}
    if method == "pallas":
        from repro.kernels.dispatch import build_dispatch_pallas
        fn = jax.jit(lambda t: build_dispatch_pallas(t, E), static_argnums=())
    else:
        fn = jax.jit(lambda t: builders[method](t, E))
    return median_time_us(fn, topk, warmup=1, iters=iters)


def run(print_fn=print, *, quick: bool = False):
    """Emit CSV rows: name,us_per_call,derived."""
    rows = []
    confs = list(PAPER_TABLE1.items())
    if quick:
        confs = confs[:2]
    for name, conf in confs:
        for act in ("silu", "swiglu"):
            mems = {}
            for impl in IMPLS:
                mems[impl] = residual_bytes(conf, impl, act)
                rows.append((f"mem_{name}_{act}_{impl}", 0.0,
                             f"residual_MB={mems[impl]/1e6:.1f}"))
            ratio = mems["megablocks"] / max(mems["blaze"], 1)
            ratio_min = mems["megablocks"] / max(mems["blaze_min"], 1)
            rows.append((f"memratio_{name}_{act}", 0.0,
                         f"megablocks/blaze={ratio:.2f}x "
                         f"megablocks/blaze_min={ratio_min:.2f}x"))
            print_fn(f"{name} {act}: blaze={mems['blaze']/1e6:.0f}MB "
                     f"megablocks={mems['megablocks']/1e6:.0f}MB "
                     f"ratio={ratio:.2f}x (min-variant {ratio_min:.2f}x)")
        # step time at reduced scale: fixed 128-row slabs — the CPU backend
        # decomposes ragged_dot dense-per-group, so full-L steps are
        # TFLOP-scale on one core; this axis is directional only (see
        # EXPERIMENTS.md §Paper-validation).
        scale = 128 / (conf[3] * conf[4])
        for act in ("silu", "swiglu"):
            ts = {impl: step_time_us(conf, impl, act, seq_scale=scale,
                                     iters=1)
                  for impl in ("blaze", "megablocks")}
            sp = ts["megablocks"] / ts["blaze"]
            rows.append((f"steptime_{name}_{act}_blaze", ts["blaze"],
                         f"speedup_vs_megablocks={sp:.2f}x@scale={scale:.4f}"))
            print_fn(f"{name} {act}: step blaze={ts['blaze']:.0f}us "
                     f"mega={ts['megablocks']:.0f}us speedup={sp:.2f}x")
        # dispatch build at full L·k
        for method in ("sortfree", "sort"):
            us = dispatch_build_us(conf, method, iters=3 if not quick else 2)
            rows.append((f"dispatch_{name}_{method}", us, f"L={conf[3]*conf[4]}"))
            print_fn(f"{name}: dispatch[{method}] {us:.0f}us")
    return rows

"""Stable benchmark-record schema + the regression gate.

A *record* is one JSON document (``BENCH_kernels.json`` / ``BENCH_memory.json``
at the repo root) holding a flat list of named *entries* plus provenance
(git sha, jax version, device backend).  Entries carry their own gating
policy: ``tolerance_pct`` is the allowed relative increase vs the committed
baseline before ``--check`` fails, or ``None`` for informational metrics that
are recorded but never gated (wall-clock on shared CI runners is noise; HLO
byte counts are not).

All gated metrics are lower-is-better (seconds, bytes, flops), so the gate is
one-sided: improvements are reported, only increases beyond tolerance fail.

Schema (version 1)::

    {"schema_version": 1, "suite": "kernels",
     "provenance": {"git_sha": ..., "jax_version": ..., "backend": ...},
     "config": {...},                      # suite parameters (e.g. small=true)
     "entries": [{"name": ..., "kind": ..., "value": ..., "unit": ...,
                  "tolerance_pct": ... | null, "meta": {...}}, ...]}
"""

from __future__ import annotations

import json
import os
import subprocess

SCHEMA_VERSION = 1

#: default tolerance used by ``--check`` for entries that predate per-entry
#: tolerances (and by tests); the acceptance gate of the harness.
DEFAULT_TOLERANCE_PCT = 20.0

BENCH_FILES = {
    "kernels": "BENCH_kernels.json",
    "memory": "BENCH_memory.json",
    "serving": "BENCH_serving.json",
}


def entry(name: str, value: float, *, kind: str, unit: str = "",
          tolerance_pct: float | None = None, **meta) -> dict:
    """One benchmark data point.  ``tolerance_pct=None`` means informational
    (never gated)."""
    return {"name": name, "kind": kind, "value": float(value), "unit": unit,
            "tolerance_pct": tolerance_pct, "meta": meta}


def git_sha(repo_dir: str | None = None) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=repo_dir, capture_output=True,
            text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def provenance() -> dict:
    import platform

    import jax

    from repro.core import gmm_backend as GB
    # The grouped-GEMM backend this run resolves by default — stamped through
    # the resolver (context/env/auto precedence), never a raw env-var read.
    gmm_rb = GB.resolve(None)
    return {"git_sha": git_sha(os.path.dirname(os.path.dirname(
                os.path.dirname(os.path.dirname(os.path.abspath(__file__)))))),
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "gmm_backend": gmm_rb.name,
            "gmm_backend_source": gmm_rb.source,
            "python_version": platform.python_version()}


def make_record(suite: str, entries: list, config: dict | None = None) -> dict:
    names = [e["name"] for e in entries]
    dupes = {n for n in names if names.count(n) > 1}
    if dupes:
        raise ValueError(f"duplicate entry names in {suite!r} record: {dupes}")
    return {"schema_version": SCHEMA_VERSION, "suite": suite,
            "provenance": provenance(), "config": dict(config or {}),
            "entries": entries}


def write_record(record: dict, path: str) -> str:
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_record(path: str) -> dict:
    with open(path) as f:
        record = json.load(f)
    version = record.get("schema_version")
    if version != SCHEMA_VERSION:
        raise ValueError(
            f"{path}: schema_version {version!r} != {SCHEMA_VERSION} "
            "(regenerate the baseline with `python -m repro.bench --small` "
            "— keep the sweep size the baselines were committed with)")
    for key in ("suite", "provenance", "entries"):
        if key not in record:
            raise ValueError(f"{path}: missing record field {key!r}")
    return record


def compare_records(current: dict, baseline: dict,
                    default_tolerance_pct: float = DEFAULT_TOLERANCE_PCT
                    ) -> list[dict]:
    """Entry-by-entry comparison.  Returns one row per *gated* baseline entry
    (``tolerance_pct`` not null): ``regressed`` is True when the current value
    exceeds baseline by more than the tolerance, or when a gated baseline
    entry disappeared from the current record.  Current-only entries (e.g. a
    backend that exists only on newer JAX) are ignored — they enter the gate
    once committed to the baseline."""
    cur = {e["name"]: e for e in current["entries"]}
    rows = []
    for base in baseline["entries"]:
        tol = base.get("tolerance_pct", default_tolerance_pct)
        if tol is None:
            continue
        name = base["name"]
        c = cur.get(name)
        if c is None:
            rows.append({"name": name, "baseline": base["value"],
                         "current": None, "pct_change": None,
                         "tolerance_pct": tol, "regressed": True,
                         "reason": "missing from current record"})
            continue
        b = base["value"]
        pct = (c["value"] - b) / b * 100.0 if b else (
            0.0 if c["value"] == 0 else float("inf"))
        rows.append({"name": name, "baseline": b, "current": c["value"],
                     "pct_change": pct, "tolerance_pct": tol,
                     "regressed": pct > tol,
                     "reason": f"+{pct:.1f}% > {tol:.0f}%" if pct > tol else ""})
    return rows


def check_records(current: dict, baseline: dict,
                  default_tolerance_pct: float = DEFAULT_TOLERANCE_PCT
                  ) -> tuple[bool, list[str]]:
    """Regression gate.  Returns (ok, human-readable report lines)."""
    if current.get("suite") != baseline.get("suite"):
        return False, [f"suite mismatch: current={current.get('suite')!r} "
                       f"baseline={baseline.get('suite')!r}"]
    if current.get("config") != baseline.get("config"):
        # small vs full sweeps emit the same entry names with very different
        # values — comparing across them would gate nothing meaningful.
        return False, [f"config mismatch: current={current.get('config')!r} "
                       f"baseline={baseline.get('config')!r} "
                       "(run --check with the sweep the baseline was "
                       "committed with)"]
    rows = compare_records(current, baseline, default_tolerance_pct)
    lines = []
    ok = True
    for r in rows:
        if r["regressed"]:
            ok = False
            cur = "missing" if r["current"] is None else f"{r['current']:.4g}"
            lines.append(f"REGRESSION {r['name']}: baseline "
                         f"{r['baseline']:.4g} -> {cur} ({r['reason']})")
        elif r["pct_change"] is not None and abs(r["pct_change"]) > 1e-9:
            lines.append(f"ok {r['name']}: {r['baseline']:.4g} -> "
                         f"{r['current']:.4g} ({r['pct_change']:+.1f}%)")
    lines.append(f"checked {len(rows)} gated entries of "
                 f"{len(baseline['entries'])} in suite "
                 f"{baseline.get('suite')!r}: "
                 + ("OK" if ok else "REGRESSED"))
    return ok, lines

"""Kernel/backend timing axis of the bench harness (paper §5.2 analogues).

Refactored out of the old ``benchmarks/kernel_bench.py`` script into an
importable suite: fused vs unfused SwiGLU HLO traffic, Pallas interpret-mode
kernel wall time, the grouped-GEMM backend comparison, and one train-step
timing probe through ``train.loop``'s ``step_hook``.

Timing protocol: ``median_time_us`` — compile + ``warmup`` untimed calls,
then the median of ``iters`` individually ``jax.block_until_ready``-fenced
calls.  Medians, not means: a single GC pause or CI-runner hiccup must not
move the recorded number.  Wall-clock entries are informational
(``tolerance_pct=None``) — this container/CI measures CPU interpret paths —
while HLO flops/bytes are deterministic and gated.

Exception to "wall time is informational": the ``kernels/fused_path/*``
entries are pair-gated against each other in the SAME run by
:func:`fused_gate_failures` (wired into ``repro.bench --check``) — relative
ordering on one machine is meaningful even when absolute numbers are not.
"""

from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

from repro.bench.record import entry


def median_time_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time of ``fn(*args)`` in microseconds, each call fenced
    with ``block_until_ready`` so async dispatch cannot hide work."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def hlo_cost(fn, *args) -> tuple[float, float]:
    """(flops, bytes accessed) from XLA cost analysis of the jitted ``fn``."""
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return float(c.get("flops", 0)), float(c.get("bytes accessed", 0))


def swiglu_traffic_entries(L=4096, d=1024, h=4096, dtype=jnp.bfloat16) -> list:
    """HLO traffic of fwd+bwd SwiGLU: naive autodiff (saves every elementwise
    intermediate) vs the paper checkpoint policy (save A/B, recompute SiLU)."""
    sds = jax.ShapeDtypeStruct
    x, w1, w2 = sds((L, d), dtype), sds((d, h), dtype), sds((d, h), dtype)

    def naive(x, w1, w2):
        return (jax.nn.silu(x @ w1) * (x @ w2)).astype(jnp.float32).sum()

    from repro.core.checkpoint import FFN_A, FFN_B, POLICIES, tag

    def paper_ckpt(x, w1, w2):
        def inner(x):
            a = tag(x @ w1, FFN_A)
            b = tag(x @ w2, FFN_B)
            return jax.nn.silu(a) * b
        y = jax.checkpoint(inner, policy=POLICIES["paper_min"])(x)
        return y.astype(jnp.float32).sum()

    meta = {"L": L, "d": d, "h": h}
    out = []
    for name, f in (("naive", naive), ("paper_ckpt", paper_ckpt)):
        fl, by = hlo_cost(jax.grad(f, argnums=(0, 1, 2)), x, w1, w2)
        out.append(entry(f"kernels/swiglu_traffic/{name}/flops", fl,
                         kind="flops", unit="flop", tolerance_pct=20.0, **meta))
        out.append(entry(f"kernels/swiglu_traffic/{name}/bytes", by,
                         kind="bytes_accessed", unit="bytes",
                         tolerance_pct=100.0, **meta))
    return out


def pallas_kernel_entries(L=1024, d=256, h=512, iters=5) -> list:
    """Wall time of the Pallas fused-SwiGLU kernel in interpret mode
    (correctness-path cost only — not representative of TPU speed)."""
    from repro.kernels.fused_swiglu import fused_swiglu_fwd
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (L, d), jnp.float32)
    w1 = jax.random.normal(key, (d, h), jnp.float32) * 0.05
    w2 = jax.random.normal(key, (d, h), jnp.float32) * 0.05
    us = median_time_us(fused_swiglu_fwd, x, w1, w2, warmup=1, iters=iters)
    return [entry("kernels/pallas_fused_swiglu_interpret/time", us,
                  kind="time_us", unit="us", L=L, d=d, h=h)]


def gmm_backend_entries(S=2048, d=256, h=512, E=8, iters=5, *,
                        include_pallas=False) -> list:
    """Every available grouped-GEMM backend on one routed workload: median
    wall time of fwd + dw plus the jitted forward's HLO flops/bytes.

    ``pallas`` runs in interpret mode on CPU — wall time there measures the
    interpreter, not the kernel, so it is opt-in."""
    from repro.core import gmm_backend as GB
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    lhs = jax.random.normal(ks[0], (S, d), jnp.float32)
    rhs = jax.random.normal(ks[1], (E, d, h), jnp.float32) * 0.05
    dout = jax.random.normal(ks[2], (S, h), jnp.float32)
    base = S // E
    gs = jnp.asarray([base] * (E - 1) + [S - base * (E - 1)], jnp.int32)

    out = []
    meta = {"S": S, "d": d, "h": h, "E": E}
    for name in GB.available_backends():
        if name == "pallas" and not include_pallas:
            continue

        def fwd(lhs, rhs, gs, _name=name):
            return GB.gmm(lhs, rhs, gs, backend=_name)

        def dw(lhs, dout, gs, _name=name):
            return GB.gmm_dw(lhs, dout, gs, backend=_name)

        fl, by = hlo_cost(fwd, lhs, rhs, gs)
        jf, jd = jax.jit(fwd), jax.jit(dw)
        us = median_time_us(lambda: (jf(lhs, rhs, gs), jd(lhs, dout, gs)),
                            warmup=1, iters=iters)
        out.append(entry(f"kernels/gmm_backend/{name}/time", us,
                         kind="time_us", unit="us", **meta))
        out.append(entry(f"kernels/gmm_backend/{name}/flops", fl,
                         kind="flops", unit="flop", tolerance_pct=20.0, **meta))
        out.append(entry(f"kernels/gmm_backend/{name}/bytes", by,
                         kind="bytes_accessed", unit="bytes",
                         tolerance_pct=100.0, **meta))
    return out


def fused_path_entries(L=128, d=64, h=128, E=8, k=2, iters=3) -> list:
    """The fused dispatch→GEMM→combine layer vs the unfused Pallas kernel
    composition it replaces, on one routed MoE shape (interpret mode):
    median fwd+grad wall time plus the saved-residual accounting — how many
    ``(L·k, h)`` / ``(L·k, d)`` slot buffers autodiff saves, and their bytes.

    The time entries are informational against the *baseline* (CI wall time
    drifts) but load-bearing against *each other*:
    :func:`fused_gate_failures` pairs them in the same run — same machine,
    same interpreter — exactly like the memory suite's sim-parity gate."""
    from repro import compat
    from repro.core.moe_layer import moe_ffn_blaze
    from repro.core.routing import build_dispatch, top_k_gating
    from repro.kernels.ops import moe_ffn_blaze_pallas

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (L, d), jnp.float32)
    wg = jax.random.normal(ks[1], (d, E), jnp.float32) * 0.1
    w1 = jax.random.normal(ks[2], (E, d, h), jnp.float32) * 0.05
    w2 = jax.random.normal(ks[3], (E, d, h), jnp.float32) * 0.05
    w3 = jax.random.normal(ks[4], (E, h, d), jnp.float32) * 0.05
    g = top_k_gating(x, wg, k)
    disp = build_dispatch(g.topk_experts, E)
    gates = g.topk_weights
    S = L * k

    def layer(label):
        if label == "fused":
            def f(x, w1, w2, w3, gates):
                return moe_ffn_blaze(x, gates, disp, w1, w3, w2,
                                     backend="pallas_fused")
        else:
            def f(x, w1, w2, w3, gates):
                return moe_ffn_blaze_pallas(x, gates, disp, w1, w3, w2,
                                            backend="pallas")
        return f

    def slot_buffers(label):
        n, nbytes = 0, 0
        for aval, src in compat.saved_residuals(
                layer(label), x, w1, w2, w3, gates):
            if "from the argument" in str(src):
                continue
            if getattr(aval, "shape", None) in ((S, h), (S, d)):
                n += 1
                nbytes += aval.size * aval.dtype.itemsize
        return n, nbytes

    def grad_fn(label):
        f = layer(label)

        def loss(x, w1, w2, w3, gates):
            return (f(x, w1, w2, w3, gates).astype(jnp.float32) ** 2).sum()

        return jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3, 4)))

    meta = {"L": L, "d": d, "h": h, "E": E, "k": k}
    out = []
    for label in ("fused", "unfused_pallas"):
        us = median_time_us(grad_fn(label), x, w1, w2, w3, gates,
                            warmup=1, iters=iters)
        n, nbytes = slot_buffers(label)
        gated = 0.0 if label == "fused" else None   # fused counts must be 0
        out.append(entry(f"kernels/fused_path/{label}/time", us,
                         kind="time_us", unit="us", **meta))
        out.append(entry(f"kernels/fused_path/{label}/slot_buffers", n,
                         kind="count", unit="buffers", tolerance_pct=gated,
                         **meta))
        out.append(entry(f"kernels/fused_path/{label}/slot_residual_bytes",
                         nbytes, kind="bytes", unit="bytes",
                         tolerance_pct=gated, **meta))
    return out


def fused_gate_failures(entries: list) -> list:
    """Same-run pairing gates for the fused MoE path (the analogue of the
    memory suite's ``sim_parity_failures``): (1) the fused layer's autodiff
    must save ZERO ``(L·k, ·)`` slot buffers — the whole point of the
    fusion — and (2) its fwd+grad wall time must not exceed the unfused
    Pallas composition measured in the *same* run.  Returns human-readable
    failure lines (empty == both gates hold)."""
    by_name = {e["name"]: e for e in entries}
    pre = "kernels/fused_path"
    fused_n = by_name.get(f"{pre}/fused/slot_buffers")
    fused_t = by_name.get(f"{pre}/fused/time")
    ref_t = by_name.get(f"{pre}/unfused_pallas/time")
    if fused_n is None and fused_t is None and ref_t is None:
        # No fused_path family at all (synthetic/legacy record): nothing to
        # pair.  Fresh runs always emit the family via ``kernels_suite``,
        # and the CI workflow asserts its presence independently.
        return []
    if fused_n is None or fused_t is None or ref_t is None:
        return [f"FUSED {pre}/* family incomplete in this run "
                "(regenerate the record with the current suite)"]
    fails = []
    if fused_n["value"] != 0:
        fails.append(f"FUSED {pre}/fused/slot_buffers: "
                     f"{int(fused_n['value'])} (L*k, .) buffer(s) in the "
                     "saved-residual set; the fused path must save none")
    if fused_t["value"] > ref_t["value"]:
        fails.append(f"FUSED {pre}/fused/time: {fused_t['value']:.0f}us vs "
                     f"unfused pallas {ref_t['value']:.0f}us in the same "
                     "run; the fused kernels must not be slower")
    return fails


def parallel_bench_config():
    """The MoE shape the ``parallel/*`` family benches: h ≈ 3d with a tight
    exchange capacity — the region where the roofline cost model predicts
    the token exchange beats replicated EP outright (and where the measured
    CPU ranking agrees, with a wide margin on both sides).  h % 4 != 0
    keeps tp out of the ranking on the 4-way model axis, mirroring the
    awkward-ff paper configs."""
    from repro.configs import get_config
    return get_config("mixtral_8x7b").reduced().replace(
        num_layers=2, num_heads=4, num_kv_heads=2, head_dim=16,
        vocab_size=128, sliding_window=16, attn_chunk=16,
        num_experts=8, top_k=2, d_model=64, moe_d_ff=198,
        moe_a2a_capacity=1.0)


def parallel_entries(L: int = 2048, iters: int = 5) -> list:
    """MoE distribution modes timed on the 8-virtual-device (2 data x 4
    model) debug mesh, next to the roofline cost model's predictions for
    the SAME config x mesh x slab — the measurement half of the ``auto``
    optimizer's validation loop.

    Per mode: median fwd+grad wall time of one jitted ``moe_sublayer`` call
    (informational vs the baseline — CI wall time drifts) plus the
    predicted ``t_total`` entry.  :func:`parallel_gate_failures` pairs them
    in the same run: the predicted ep vs ep_a2a ranking must agree with the
    measured one, and the chunked-overlap path must not be slower than the
    unchunked exchange."""
    if len(jax.devices()) < 8:
        import sys
        print("# skipping parallel entries: need >= 8 host devices "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "before jax initializes; `python -m repro.bench` does this "
              "automatically)", file=sys.stderr)
        return []
    from repro import roofline
    from repro.launch.mesh import make_debug_mesh
    from repro.models.moe_block import init_moe_params, moe_sublayer

    cfg = parallel_bench_config()
    mesh = make_debug_mesh(2, 4)
    decision = roofline.select_moe_parallel(cfg, mesh, L)
    pred = {c.mode: c for c in decision.table}
    p = init_moe_params(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, L, cfg.d_model),
                          jnp.float32)
    meta = {"L": L, "d": cfg.d_model, "h": cfg.moe_d_ff,
            "E": cfg.num_experts, "k": cfg.top_k,
            "capacity": cfg.moe_a2a_capacity, "mesh": "2x4"}

    def timed(mode, chunks):
        c = cfg.replace(moe_parallel=mode, moe_a2a_chunks=chunks)

        def loss(x, p):
            y, _ = moe_sublayer(x, p, c, mesh=mesh, dp_axes=("data",))
            return (y.astype(jnp.float32) ** 2).mean()

        f = jax.jit(jax.value_and_grad(loss))
        with mesh:
            # warmup=2: the first post-compile call still carries allocator
            # warmup on the 8-virtual-device host mesh, and the chunked gate
            # pairs wall times at a few-percent resolution.
            return median_time_us(f, x, p, warmup=2, iters=iters)

    out = [entry("kernels/parallel/auto_mode",
                 float(decision.mode == "ep_a2a"), kind="count", unit="bool",
                 tolerance_pct=0.0, resolved=decision.mode,
                 source=decision.source, **meta)]
    for label, mode, chunks in (("ep", "ep", 1), ("ep_a2a", "ep_a2a", 1),
                                ("ep_a2a_chunked", "ep_a2a", 2)):
        us = timed(mode, chunks)
        out.append(entry(f"kernels/parallel/{label}/time", us,
                         kind="time_us", unit="us", chunks=chunks, **meta))
        pc = pred[mode]
        out.append(entry(f"kernels/parallel/{label}/predicted",
                         pc.t_total_s * 1e6 if chunks == 1 else
                         _chunked_predicted_us(cfg, mesh, L, chunks),
                         kind="time_us", unit="us", chunks=chunks,
                         feasible=pc.feasible, **meta))
    return out


def _chunked_predicted_us(cfg, mesh, L, chunks) -> float:
    """Predicted t_total of the chunked-overlap exchange (the cost model
    reads ``cfg.moe_a2a_chunks``)."""
    from repro import roofline
    d = roofline.select_moe_parallel(
        cfg.replace(moe_a2a_chunks=chunks), mesh, L)
    return next(c.t_total_s for c in d.table if c.mode == "ep_a2a") * 1e6


#: measured chunked/unchunked slack: XLA's async-collective overlap does not
#: exist on the CPU host backend, so the chunked path only has to hold
#: parity there, not win — and host-mesh wall clocks pair at ~±10% noise
#: (repeated solo runs of the same binary span 0.95-1.13x), so the gate
#: only catches gross regressions such as a serialized per-chunk sync.
PARALLEL_CHUNK_TOL = 1.25


def parallel_gate_failures(entries: list) -> list:
    """Same-run pairing gates for the ``parallel/*`` family: (1) the cost
    model's predicted ep vs ep_a2a ranking must agree with the measured
    ranking of the SAME run, (2) the chunked-overlap exchange must not be
    slower than the unchunked one (within :data:`PARALLEL_CHUNK_TOL` — CPU
    runners have no async-collective overlap to win with), and (3) ``auto``
    must have resolved to the predicted winner.  Returns human-readable
    failure lines (empty == all gates hold)."""
    by_name = {e["name"]: e for e in entries}
    pre = "kernels/parallel"
    names = (f"{pre}/ep/time", f"{pre}/ep_a2a/time",
             f"{pre}/ep/predicted", f"{pre}/ep_a2a/predicted",
             f"{pre}/ep_a2a_chunked/time", f"{pre}/auto_mode")
    got = [by_name.get(n) for n in names]
    if all(g is None for g in got):
        # No parallel family at all (device-starved/legacy record): nothing
        # to pair.  The CI workflow asserts the family's presence
        # independently on the 8-device legs.
        return []
    if any(g is None for g in got):
        return [f"PARALLEL {pre}/* family incomplete in this run "
                "(regenerate the record with the current suite)"]
    ep_t, a2a_t, ep_p, a2a_p, ch_t, auto = (g["value"] for g in got)
    fails = []
    if (ep_p < a2a_p) != (ep_t < a2a_t):
        fails.append(
            f"PARALLEL predicted ranking disagrees with measured: "
            f"predicted ep={ep_p:.0f}us vs ep_a2a={a2a_p:.0f}us, measured "
            f"ep={ep_t:.0f}us vs ep_a2a={a2a_t:.0f}us in the same run")
    if ch_t > a2a_t * PARALLEL_CHUNK_TOL:
        fails.append(
            f"PARALLEL {pre}/ep_a2a_chunked/time: {ch_t:.0f}us vs unchunked "
            f"{a2a_t:.0f}us in the same run; the chunked-overlap path must "
            f"not be slower (tol {PARALLEL_CHUNK_TOL:.2f}x)")
    want = "ep" if ep_p < a2a_p else "ep_a2a"
    resolved = by_name[f"{pre}/auto_mode"]["meta"].get("resolved")
    if resolved != want:
        fails.append(
            f"PARALLEL auto resolved to {resolved!r} but the cost model's "
            f"predicted winner in the same run is {want!r}")
    return fails


def train_step_entries(steps: int = 3) -> list:
    """Per-step wall time of the tiny-config train loop, collected through
    ``train.loop``'s ``step_hook`` (the hook the harness regresses against)."""
    from repro.bench.memory import bench_config
    from repro.configs.base import TrainConfig
    from repro.train.loop import train

    cfg = bench_config()
    tcfg = TrainConfig(total_steps=steps + 1, batch_size=2, seq_len=32,
                       log_every=10_000)
    times, backends = [], []

    def hook(step, m):
        times.append(m["step_s"])
        backends.append(m["gmm_backend"])   # resolved name, not the env var

    train(cfg, tcfg, log=lambda *_: None, step_hook=hook)
    # First step includes compile; report the median of the rest.
    us = statistics.median(times[1:]) * 1e6
    return [entry(f"kernels/train_step/{cfg.name}/time", us,
                  kind="time_us", unit="us", steps=steps,
                  compile_s=times[0], gmm_backend=backends[-1])]


def kernels_suite(*, small: bool = False) -> list:
    """All timing-axis entries.  ``small`` is the CI/test sweep."""
    out = []
    out += swiglu_traffic_entries(L=1024 if small else 4096)
    out += pallas_kernel_entries(L=256 if small else 1024,
                                 iters=3 if small else 5)
    out += gmm_backend_entries(S=512 if small else 2048,
                               iters=3 if small else 5,
                               include_pallas=small)
    out += fused_path_entries(L=64 if small else 128,
                              iters=3 if small else 5)
    # The parallel family keeps L=2048 even in the small sweep: at L=1024
    # the chunked exchange's per-hop fixed overhead (no async overlap on the
    # host backend) dominates the halved chunk and the parity gate turns
    # into a coin flip; at 2048 chunked holds parity or wins on CPU.
    out += parallel_entries(L=2048, iters=3 if small else 5)
    out += train_step_entries()
    return out


def legacy_rows(entries: list) -> list:
    """Project record entries onto the old ``(name, us, derived)`` CSV rows
    still emitted by ``benchmarks/run.py``."""
    rows = []
    for e in entries:
        us = e["value"] if e["kind"] == "time_us" else 0.0
        derived = ";".join(f"{k}={v}" for k, v in e["meta"].items())
        if e["kind"] != "time_us":
            derived = f"{e['kind']}={e['value']:.4g};{derived}"
        rows.append((e["name"].replace("/", "_"), us, derived.rstrip(";")))
    return rows

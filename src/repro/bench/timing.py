"""Kernel/backend timing axis of the bench harness (paper §5.2 analogues).

Refactored out of the old ``benchmarks/kernel_bench.py`` script into an
importable suite: fused vs unfused SwiGLU HLO traffic, Pallas interpret-mode
kernel wall time, the grouped-GEMM backend comparison, and one train-step
timing probe through ``train.loop``'s ``step_hook``.

Timing protocol: ``median_time_us`` — compile + ``warmup`` untimed calls,
then the median of ``iters`` individually ``jax.block_until_ready``-fenced
calls.  Medians, not means: a single GC pause or CI-runner hiccup must not
move the recorded number.  Wall-clock entries are informational
(``tolerance_pct=None``) — this container/CI measures CPU interpret paths —
while HLO flops/bytes are deterministic and gated.
"""

from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

from repro.bench.record import entry


def median_time_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time of ``fn(*args)`` in microseconds, each call fenced
    with ``block_until_ready`` so async dispatch cannot hide work."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def hlo_cost(fn, *args) -> tuple[float, float]:
    """(flops, bytes accessed) from XLA cost analysis of the jitted ``fn``."""
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return float(c.get("flops", 0)), float(c.get("bytes accessed", 0))


def swiglu_traffic_entries(L=4096, d=1024, h=4096, dtype=jnp.bfloat16) -> list:
    """HLO traffic of fwd+bwd SwiGLU: naive autodiff (saves every elementwise
    intermediate) vs the paper checkpoint policy (save A/B, recompute SiLU)."""
    sds = jax.ShapeDtypeStruct
    x, w1, w2 = sds((L, d), dtype), sds((d, h), dtype), sds((d, h), dtype)

    def naive(x, w1, w2):
        return (jax.nn.silu(x @ w1) * (x @ w2)).astype(jnp.float32).sum()

    from repro.core.checkpoint import FFN_A, FFN_B, POLICIES, tag

    def paper_ckpt(x, w1, w2):
        def inner(x):
            a = tag(x @ w1, FFN_A)
            b = tag(x @ w2, FFN_B)
            return jax.nn.silu(a) * b
        y = jax.checkpoint(inner, policy=POLICIES["paper_min"])(x)
        return y.astype(jnp.float32).sum()

    meta = {"L": L, "d": d, "h": h}
    out = []
    for name, f in (("naive", naive), ("paper_ckpt", paper_ckpt)):
        fl, by = hlo_cost(jax.grad(f, argnums=(0, 1, 2)), x, w1, w2)
        out.append(entry(f"kernels/swiglu_traffic/{name}/flops", fl,
                         kind="flops", unit="flop", tolerance_pct=20.0, **meta))
        out.append(entry(f"kernels/swiglu_traffic/{name}/bytes", by,
                         kind="bytes_accessed", unit="bytes",
                         tolerance_pct=100.0, **meta))
    return out


def pallas_kernel_entries(L=1024, d=256, h=512, iters=5) -> list:
    """Wall time of the Pallas fused-SwiGLU kernel in interpret mode
    (correctness-path cost only — not representative of TPU speed)."""
    from repro.kernels.fused_swiglu import fused_swiglu_fwd
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (L, d), jnp.float32)
    w1 = jax.random.normal(key, (d, h), jnp.float32) * 0.05
    w2 = jax.random.normal(key, (d, h), jnp.float32) * 0.05
    us = median_time_us(fused_swiglu_fwd, x, w1, w2, warmup=1, iters=iters)
    return [entry("kernels/pallas_fused_swiglu_interpret/time", us,
                  kind="time_us", unit="us", L=L, d=d, h=h)]


def gmm_backend_entries(S=2048, d=256, h=512, E=8, iters=5, *,
                        include_pallas=False) -> list:
    """Every available grouped-GEMM backend on one routed workload: median
    wall time of fwd + dw plus the jitted forward's HLO flops/bytes.

    ``pallas`` runs in interpret mode on CPU — wall time there measures the
    interpreter, not the kernel, so it is opt-in."""
    from repro.core import gmm_backend as GB
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    lhs = jax.random.normal(ks[0], (S, d), jnp.float32)
    rhs = jax.random.normal(ks[1], (E, d, h), jnp.float32) * 0.05
    dout = jax.random.normal(ks[2], (S, h), jnp.float32)
    base = S // E
    gs = jnp.asarray([base] * (E - 1) + [S - base * (E - 1)], jnp.int32)

    out = []
    meta = {"S": S, "d": d, "h": h, "E": E}
    for name in GB.available_backends():
        if name == "pallas" and not include_pallas:
            continue

        def fwd(lhs, rhs, gs, _name=name):
            return GB.gmm(lhs, rhs, gs, backend=_name)

        def dw(lhs, dout, gs, _name=name):
            return GB.gmm_dw(lhs, dout, gs, backend=_name)

        fl, by = hlo_cost(fwd, lhs, rhs, gs)
        jf, jd = jax.jit(fwd), jax.jit(dw)
        us = median_time_us(lambda: (jf(lhs, rhs, gs), jd(lhs, dout, gs)),
                            warmup=1, iters=iters)
        out.append(entry(f"kernels/gmm_backend/{name}/time", us,
                         kind="time_us", unit="us", **meta))
        out.append(entry(f"kernels/gmm_backend/{name}/flops", fl,
                         kind="flops", unit="flop", tolerance_pct=20.0, **meta))
        out.append(entry(f"kernels/gmm_backend/{name}/bytes", by,
                         kind="bytes_accessed", unit="bytes",
                         tolerance_pct=100.0, **meta))
    return out


def train_step_entries(steps: int = 3) -> list:
    """Per-step wall time of the tiny-config train loop, collected through
    ``train.loop``'s ``step_hook`` (the hook the harness regresses against)."""
    from repro.bench.memory import bench_config
    from repro.configs.base import TrainConfig
    from repro.train.loop import train

    cfg = bench_config()
    tcfg = TrainConfig(total_steps=steps + 1, batch_size=2, seq_len=32,
                       log_every=10_000)
    times, backends = [], []

    def hook(step, m):
        times.append(m["step_s"])
        backends.append(m["gmm_backend"])   # resolved name, not the env var

    train(cfg, tcfg, log=lambda *_: None, step_hook=hook)
    # First step includes compile; report the median of the rest.
    us = statistics.median(times[1:]) * 1e6
    return [entry(f"kernels/train_step/{cfg.name}/time", us,
                  kind="time_us", unit="us", steps=steps,
                  compile_s=times[0], gmm_backend=backends[-1])]


def kernels_suite(*, small: bool = False) -> list:
    """All timing-axis entries.  ``small`` is the CI/test sweep."""
    out = []
    out += swiglu_traffic_entries(L=1024 if small else 4096)
    out += pallas_kernel_entries(L=256 if small else 1024,
                                 iters=3 if small else 5)
    out += gmm_backend_entries(S=512 if small else 2048,
                               iters=3 if small else 5,
                               include_pallas=small)
    out += train_step_entries()
    return out


def legacy_rows(entries: list) -> list:
    """Project record entries onto the old ``(name, us, derived)`` CSV rows
    still emitted by ``benchmarks/run.py``."""
    rows = []
    for e in entries:
        us = e["value"] if e["kind"] == "time_us" else 0.0
        derived = ";".join(f"{k}={v}" for k, v in e["meta"].items())
        if e["kind"] != "time_us":
            derived = f"{e['kind']}={e['value']:.4g};{derived}"
        rows.append((e["name"].replace("/", "_"), us, derived.rstrip(";")))
    return rows

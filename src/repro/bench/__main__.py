import os
import sys

# The memory suite's expert-parallel entries build a debug mesh over host
# devices; the override must land before jax first initializes its backend
# (the device count locks at first device query, not at import — nothing on
# the ``python -m repro.bench`` import path touches devices before this
# runs).  No-op when the operator already set a count; if a future import
# does initialize jax early, ``ep_saved_residual_entries`` degrades to a
# loud stderr skip rather than crashing the suite.
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

from repro.bench.cli import main  # noqa: E402

sys.exit(main())

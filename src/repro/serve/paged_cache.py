"""Block-paged KV storage for the serving engine (SHARK-Engine direction).

The seed engine held one dense ``(B, capacity, Hkv, Dh)`` slab per slot —
decode memory scaled with ``slots x capacity`` whether or not a request ever
reached ``capacity`` tokens, and a short request pinned its whole slab until
the longest request in the batch finished.  This module replaces the slab
with a pool of fixed-size *pages* shared by every request:

* :class:`PagedKV` — one layer's page pool, ``(P, page_size, Hkv, Dh)`` in
  the model dtype, or int8 values + f16 per-(position, head) scales when
  quantized (the ``serve/kv_quant`` symmetric scheme, applied at write time);
* per-request *page tables* ``(B, pages_per_seq)`` map logical token
  positions to physical pages.  Unused table entries point at the reserved
  **trash page** (physical page 0): writes to padded positions land there and
  reads from it are always masked, so scatter/gather never needs bounds
  branches;
* :class:`PagePool` — the host-side free-list allocator.  Pages return to
  the pool the moment a request finishes, which is what lets the scheduler
  admit from ``pending`` without head-of-line blocking.

Masking is by per-request *prefix length*: a gathered slot at logical
position ``t`` is attended iff ``t <= pos_b`` (and inside the sliding window
when one applies).  Right-padded prompts therefore never leak pad keys into
another request's attention — the batched-vs-solo parity gate in
``bench/serving.py`` holds by construction.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.serve.kv_quant import quantize

NEG_INF = -1e30

#: physical page 0 is never allocated: page-table entries beyond a request's
#: reservation point here, so padded-position writes have a harmless target
#: and gathered trash is masked by the prefix-length test.
TRASH_PAGE = 0


class PagedKV(NamedTuple):
    """One attention layer's page pool.  ``k``/``v`` are ``(P, page_size,
    Hkv, Dh)`` in the storage dtype; int8 storage carries f16 per-vector
    scales ``(P, page_size, Hkv, 1)`` (``None`` otherwise — the pytree
    structure is the static quantization flag)."""

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None
    v_scale: jax.Array | None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def page_size(self) -> int:
        return self.k.shape[1]


def init_paged_kv(num_pages: int, page_size: int, n_kv: int, head_dim: int,
                  dtype, *, quantized: bool = False) -> PagedKV:
    shape = (num_pages, page_size, n_kv, head_dim)
    if quantized:
        return PagedKV(k=jnp.zeros(shape, jnp.int8),
                       v=jnp.zeros(shape, jnp.int8),
                       k_scale=jnp.zeros(shape[:-1] + (1,), jnp.float16),
                       v_scale=jnp.zeros(shape[:-1] + (1,), jnp.float16))
    return PagedKV(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   k_scale=None, v_scale=None)


# ---------------------------------------------------------------------------
# writes
# ---------------------------------------------------------------------------


def _store(x, quantized: bool, dtype):
    """(values, scales|None) in the pool's storage layout."""
    if quantized:
        return quantize(x)
    return x.astype(dtype), None


def write_prefill(pages: PagedKV, k: jax.Array, v: jax.Array,
                  page_table: jax.Array) -> PagedKV:
    """Scatter a whole right-padded prompt's k/v ``(B, S, Hkv, Dh)`` through
    ``page_table`` ``(B, pages_per_seq)``: logical position ``t`` of request
    ``b`` lands in ``page_table[b, t // page_size]`` at offset
    ``t % page_size``.  Positions past a request's reservation map to the
    trash page (never attended), so the padded tail needs no branch.

    ``S`` may exceed the table's logical width ``pages_per_seq * page_size``
    (callers bucket prompts to power-of-two lengths): columns past the table
    are routed to the trash page explicitly.  Without that routing, JAX's
    clamping gather would alias them onto the LAST table column and the pad
    tail would scatter over the request's own final page — silently
    corrupting valid prompt KV whenever the bucket overshoots the table."""
    B, S = k.shape[:2]
    ps = pages.page_size
    t = jnp.arange(S)
    col = t // ps
    ncols = page_table.shape[1]
    phys = jnp.where(col < ncols,
                     page_table[:, jnp.minimum(col, ncols - 1)],
                     TRASH_PAGE).reshape(-1)             # (B*S,)
    off = jnp.broadcast_to(t % ps, (B, S)).reshape(-1)
    kq, ks = _store(k, pages.quantized, pages.k.dtype)
    vq, vs = _store(v, pages.quantized, pages.v.dtype)
    flat = lambda x: x.reshape((B * S,) + x.shape[2:])
    return PagedKV(
        k=pages.k.at[phys, off].set(flat(kq)),
        v=pages.v.at[phys, off].set(flat(vq)),
        k_scale=None if ks is None else pages.k_scale.at[phys, off].set(flat(ks)),
        v_scale=None if vs is None else pages.v_scale.at[phys, off].set(flat(vs)),
    )


def write_decode(pages: PagedKV, k: jax.Array, v: jax.Array,
                 page_table: jax.Array, positions: jax.Array) -> PagedKV:
    """Scatter one token per request: ``k``/``v`` ``(B, 1, Hkv, Dh)`` at
    per-request absolute ``positions`` ``(B,)``."""
    B = k.shape[0]
    ps = pages.page_size
    phys = page_table[jnp.arange(B), positions // ps]     # (B,)
    off = positions % ps
    kq, ks = _store(k[:, 0], pages.quantized, pages.k.dtype)
    vq, vs = _store(v[:, 0], pages.quantized, pages.v.dtype)
    return PagedKV(
        k=pages.k.at[phys, off].set(kq),
        v=pages.v.at[phys, off].set(vq),
        k_scale=None if ks is None else pages.k_scale.at[phys, off].set(ks),
        v_scale=None if vs is None else pages.v_scale.at[phys, off].set(vs),
    )


# ---------------------------------------------------------------------------
# attend
# ---------------------------------------------------------------------------


def paged_attention(q: jax.Array, pages: PagedKV, page_table: jax.Array,
                    positions: jax.Array, *, window: int = 0,
                    cap: float = 0.0) -> jax.Array:
    """One-token attention against the paged cache.

    q: ``(B, 1, Hq, Dh)``; ``positions`` ``(B,)`` is each request's current
    (already written) token position.  The request's pages are gathered to a
    ``(B, pages_per_seq * page_size, Hkv, Dh)`` view and masked by logical
    position — ``t <= pos_b`` — so trash-page slots and not-yet-written tail
    slots never contribute.  For int8 pools the per-vector scales are applied
    to the score/value rows rather than to the storage: the RESIDENT pool is
    never dequantized, though the gathered per-step ``(B, T)`` view is upcast
    to f32 for the dots (transient, proportional to one step's working set,
    not to the pool)."""
    B, _, Hq, Dh = q.shape
    ps = pages.page_size
    T = page_table.shape[1] * ps
    Hkv = pages.k.shape[2]
    G = Hq // Hkv
    gather = lambda a: a[page_table].reshape((B, T) + a.shape[2:])
    kg, vg = gather(pages.k), gather(pages.v)
    qf = q.reshape(B, Hkv, G, Dh) * Dh**-0.5

    if pages.quantized:
        s = jnp.einsum("bhgd,bthd->bhgt", qf.astype(jnp.float32),
                       kg.astype(jnp.float32))
        s = s * gather(pages.k_scale)[..., 0].astype(jnp.float32).transpose(
            0, 2, 1)[:, :, None, :]
    else:
        s = jnp.einsum("bhgd,bthd->bhgt", qf.astype(kg.dtype), kg,
                       preferred_element_type=jnp.float32)
    if cap:
        s = cap * jnp.tanh(s / cap)
    t_ids = jnp.arange(T)
    valid = t_ids[None, :] <= positions[:, None]          # (B, T)
    if window:
        valid &= t_ids[None, :] > positions[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if pages.quantized:
        pv = p * gather(pages.v_scale)[..., 0].astype(jnp.float32).transpose(
            0, 2, 1)[:, :, None, :]
        out = jnp.einsum("bhgt,bthd->bhgd", pv, vg.astype(jnp.float32))
    else:
        out = jnp.einsum("bhgt,bthd->bhgd", p.astype(vg.dtype), vg,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# host-side page allocator
# ---------------------------------------------------------------------------


class PagePool:
    """Free-list page allocator (host side; page indices are plain ints).

    Page ``TRASH_PAGE`` is reserved at construction.  Frees push onto the
    list tail and allocs pop from it (LIFO), so a request admitted right
    after another finishes reuses the same physical pages — the property the
    page-table-reuse regression test pins down."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (one is the reserved trash page)")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, TRASH_PAGE, -1))
        self.min_free = len(self._free)       # low-water mark (stats)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` pages; raises if the pool cannot satisfy the request
        (callers check :attr:`free_pages` first — admission control)."""
        if n > len(self._free):
            raise RuntimeError(f"page pool exhausted: want {n}, "
                               f"have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self.min_free = min(self.min_free, len(self._free))
        return pages

    def free(self, pages: list[int]) -> None:
        for p in pages:
            if p == TRASH_PAGE or p >= self.num_pages:
                raise ValueError(f"freeing invalid page {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
        self._free.extend(reversed(pages))


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-max(n_tokens, 1) // page_size)

"""Block-paged KV storage for the serving engine (SHARK-Engine direction).

The seed engine held one dense ``(B, capacity, Hkv, Dh)`` slab per slot —
decode memory scaled with ``slots x capacity`` whether or not a request ever
reached ``capacity`` tokens, and a short request pinned its whole slab until
the longest request in the batch finished.  This module replaces the slab
with a pool of fixed-size *pages* shared by every request:

* :class:`PagedKV` — one layer's page pool, ``(P, page_size, Hkv, Dh)`` in
  the model dtype, or int8 values + f16 per-(position, head) scales when
  quantized (the ``serve/kv_quant`` symmetric scheme, applied at write time);
* per-request *page tables* ``(B, pages_per_seq)`` map logical token
  positions to physical pages.  Unused table entries point at the reserved
  **trash page** (physical page 0): writes to padded positions land there and
  reads from it are always masked, so scatter/gather never needs bounds
  branches;
* :class:`PagePool` — the host-side free-list allocator, now *refcounted*:
  a physical page may be mapped read-only into several requests' page tables
  (prefix sharing) and only returns to the free list when its last reference
  drops.  Guards are O(1) (a membership set rides alongside the LIFO list);
* :class:`PrefixCache` — a trie over full-page prompt chunks.  A finishing
  request donates its full prompt pages; a later request whose prompt shares
  a page-aligned prefix maps the cached pages read-only and prefills only
  the unshared suffix.  The first write into a shared page is forked by the
  engine into a private copy (copy-on-write) — the trash-page idiom already
  makes the page-table remap branch-free.

Masking is by per-request *prefix length*: a gathered slot at logical
position ``t`` is attended iff ``t <= pos_b`` (and inside the sliding window
when one applies).  Right-padded prompts therefore never leak pad keys into
another request's attention — the batched-vs-solo parity gate in
``bench/serving.py`` holds by construction.

Decode attention has two registered implementations (the
``core/gmm_backend`` capability-detection pattern): ``dense`` — the
jnp gather reference below — and ``pallas`` —
``kernels/paged_attention.py``, which walks the page table inside the
kernel via scalar prefetch and reads only pages up to each request's
position.  ``resolve_paged_attn`` applies the arg > env (``REPRO_PAGED_ATTN``)
> auto chain; ``pallas`` is never auto-selected (interpret mode on CPU).
"""

from __future__ import annotations

import contextlib
import os
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.serve.kv_quant import quantize

NEG_INF = -1e30

#: physical page 0 is never allocated: page-table entries beyond a request's
#: reservation point here, so padded-position writes have a harmless target
#: and gathered trash is masked by the prefix-length test.
TRASH_PAGE = 0


class PagedKV(NamedTuple):
    """One attention layer's page pool.  ``k``/``v`` are ``(P, page_size,
    Hkv, Dh)`` in the storage dtype; int8 storage carries f16 per-vector
    scales ``(P, page_size, Hkv, 1)`` (``None`` otherwise — the pytree
    structure is the static quantization flag)."""

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None
    v_scale: jax.Array | None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def page_size(self) -> int:
        return self.k.shape[1]


def init_paged_kv(num_pages: int, page_size: int, n_kv: int, head_dim: int,
                  dtype, *, quantized: bool = False) -> PagedKV:
    shape = (num_pages, page_size, n_kv, head_dim)
    if quantized:
        return PagedKV(k=jnp.zeros(shape, jnp.int8),
                       v=jnp.zeros(shape, jnp.int8),
                       k_scale=jnp.zeros(shape[:-1] + (1,), jnp.float16),
                       v_scale=jnp.zeros(shape[:-1] + (1,), jnp.float16))
    return PagedKV(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   k_scale=None, v_scale=None)


# ---------------------------------------------------------------------------
# writes
# ---------------------------------------------------------------------------


def _store(x, quantized: bool, dtype):
    """(values, scales|None) in the pool's storage layout."""
    if quantized:
        return quantize(x)
    return x.astype(dtype), None


def _scatter(pages: PagedKV, k, v, phys, off) -> PagedKV:
    """Write flattened k/v rows at ``(phys, off)`` page coordinates."""
    kq, ks = _store(k, pages.quantized, pages.k.dtype)
    vq, vs = _store(v, pages.quantized, pages.v.dtype)
    return PagedKV(
        k=pages.k.at[phys, off].set(kq),
        v=pages.v.at[phys, off].set(vq),
        k_scale=None if ks is None else pages.k_scale.at[phys, off].set(ks),
        v_scale=None if vs is None else pages.v_scale.at[phys, off].set(vs),
    )


def write_prefill(pages: PagedKV, k: jax.Array, v: jax.Array,
                  page_table: jax.Array) -> PagedKV:
    """Scatter a whole right-padded prompt's k/v ``(B, S, Hkv, Dh)`` through
    ``page_table`` ``(B, pages_per_seq)``: logical position ``t`` of request
    ``b`` lands in ``page_table[b, t // page_size]`` at offset
    ``t % page_size``.  Positions past a request's reservation map to the
    trash page (never attended), so the padded tail needs no branch.

    ``S`` may exceed the table's logical width ``pages_per_seq * page_size``
    (callers bucket prompts to power-of-two lengths): columns past the table
    are routed to the trash page explicitly.  Without that routing, JAX's
    clamping gather would alias them onto the LAST table column and the pad
    tail would scatter over the request's own final page — silently
    corrupting valid prompt KV whenever the bucket overshoots the table."""
    B, S = k.shape[:2]
    return write_prefill_offset(pages, k, v, page_table,
                                jnp.zeros((B,), jnp.int32))


def write_prefill_offset(pages: PagedKV, k: jax.Array, v: jax.Array,
                         page_table: jax.Array,
                         offsets: jax.Array) -> PagedKV:
    """:func:`write_prefill` generalized to per-request start positions:
    row ``t`` of request ``b`` lands at *absolute* position
    ``offsets[b] + t`` (prefix sharing prefills only the unshared suffix —
    the shared pages already hold the prefix KV).  Columns past the table
    width are routed to the trash page exactly like :func:`write_prefill`
    (the pow2 bucket may overshoot both the suffix and the table)."""
    B, S = k.shape[:2]
    ps = pages.page_size
    t_abs = offsets[:, None].astype(jnp.int32) + jnp.arange(S)     # (B, S)
    col = t_abs // ps
    ncols = page_table.shape[1]
    phys = jnp.where(
        col < ncols,
        jnp.take_along_axis(page_table, jnp.minimum(col, ncols - 1), axis=1),
        TRASH_PAGE).reshape(-1)                                    # (B*S,)
    off = (t_abs % ps).reshape(-1)
    flat = lambda x: x.reshape((B * S,) + x.shape[2:])
    return _scatter(pages, flat(k), flat(v), phys, off)


def write_decode(pages: PagedKV, k: jax.Array, v: jax.Array,
                 page_table: jax.Array, positions: jax.Array) -> PagedKV:
    """Scatter one token per request: ``k``/``v`` ``(B, 1, Hkv, Dh)`` at
    per-request absolute ``positions`` ``(B,)``."""
    B = k.shape[0]
    ps = pages.page_size
    phys = page_table[jnp.arange(B), positions // ps]     # (B,)
    off = positions % ps
    return _scatter(pages, k[:, 0], v[:, 0], phys, off)


def copy_page(pages: PagedKV, src: jax.Array, dst: jax.Array) -> PagedKV:
    """Device-side page fork: copy physical page ``src``'s contents into
    ``dst`` (the copy-on-write primitive — the writer's page table is then
    remapped host-side to ``dst`` and the shared ``src`` keeps serving its
    other readers untouched)."""
    cp = lambda a: None if a is None else a.at[dst].set(a[src])
    return PagedKV(k=cp(pages.k), v=cp(pages.v),
                   k_scale=cp(pages.k_scale), v_scale=cp(pages.v_scale))


# ---------------------------------------------------------------------------
# attend
# ---------------------------------------------------------------------------


def paged_gather_attention(q: jax.Array, pages: PagedKV,
                           page_table: jax.Array, pos_q: jax.Array, *,
                           window: int = 0, cap: float = 0.0) -> jax.Array:
    """Attention of ``Sq`` query tokens per request against that request's
    gathered pages (the dense reference path).

    q: ``(B, Sq, Hq, Dh)``; ``pos_q`` ``(B, Sq)`` is each query's absolute
    position — its k/v must already be written, and it attends every
    gathered slot ``t <= pos_q`` (window-restricted when one applies).
    ``Sq == 1`` is the decode step; ``Sq > 1`` is the prefix-sharing suffix
    prefill, where the shared prefix is read from cached pages instead of
    being recomputed.  For int8 pools the per-vector scales are applied to
    the score/value rows rather than to the storage: the RESIDENT pool is
    never dequantized, though the gathered per-step view is upcast to f32
    for the dots (transient, proportional to one step's working set, not to
    the pool)."""
    B, Sq, Hq, Dh = q.shape
    ps = pages.page_size
    T = page_table.shape[1] * ps
    Hkv = pages.k.shape[2]
    G = Hq // Hkv
    gather = lambda a: a[page_table].reshape((B, T) + a.shape[2:])
    kg, vg = gather(pages.k), gather(pages.v)
    qf = q.reshape(B, Sq, Hkv, G, Dh) * Dh**-0.5

    if pages.quantized:
        s = jnp.einsum("bqhgd,bthd->bqhgt", qf.astype(jnp.float32),
                       kg.astype(jnp.float32))
        s = s * gather(pages.k_scale)[..., 0].astype(jnp.float32).transpose(
            0, 2, 1)[:, None, :, None, :]
    else:
        s = jnp.einsum("bqhgd,bthd->bqhgt", qf.astype(kg.dtype), kg,
                       preferred_element_type=jnp.float32)
    if cap:
        s = cap * jnp.tanh(s / cap)
    t_ids = jnp.arange(T)
    valid = t_ids[None, None, :] <= pos_q[:, :, None]          # (B, Sq, T)
    if window:
        valid &= t_ids[None, None, :] > pos_q[:, :, None] - window
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if pages.quantized:
        pv = p * gather(pages.v_scale)[..., 0].astype(jnp.float32).transpose(
            0, 2, 1)[:, None, :, None, :]
        out = jnp.einsum("bqhgt,bthd->bqhgd", pv, vg.astype(jnp.float32))
    else:
        out = jnp.einsum("bqhgt,bthd->bqhgd", p.astype(vg.dtype), vg,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def paged_attention(q: jax.Array, pages: PagedKV, page_table: jax.Array,
                    positions: jax.Array, *, window: int = 0,
                    cap: float = 0.0, impl: str = "dense") -> jax.Array:
    """One-token attention against the paged cache.

    q: ``(B, 1, Hq, Dh)``; ``positions`` ``(B,)`` is each request's current
    (already written) token position.  ``impl`` selects the registered
    implementation: ``dense`` gathers the request's pages to a
    ``(B, pages_per_seq * page_size, Hkv, Dh)`` view; ``pallas`` walks the
    page table inside the kernel and reads only pages up to each request's
    position (no full-reservation copy materializes)."""
    if impl == "pallas":
        from repro.kernels.paged_attention import paged_attention_pallas
        return paged_attention_pallas(
            q, pages.k, pages.v, pages.k_scale, pages.v_scale,
            page_table, positions, window=window, cap=cap)
    if impl != "dense":
        raise ValueError(f"unknown paged-attention impl {impl!r}; "
                         f"known: {paged_attn_names()}")
    return paged_gather_attention(q, pages, page_table, positions[:, None],
                                  window=window, cap=cap)


# ---------------------------------------------------------------------------
# paged-attention implementation registry (the gmm_backend pattern)
# ---------------------------------------------------------------------------

PAGED_ATTN_ENV = "REPRO_PAGED_ATTN"


class DensePagedAttn:
    """The jnp gather reference above — available everywhere, and the
    numerical oracle the kernel parity tests compare against."""

    name = "dense"

    @staticmethod
    def available() -> bool:
        return True


class PallasPagedAttn:
    """``kernels/paged_attention.py``: page-table walk via scalar prefetch,
    online softmax across page steps, f32 accumulate, int8 scale-on-scores.
    ``interpret=True`` on CPU; never auto-selected (explicit opt-in)."""

    name = "pallas"

    @staticmethod
    def available() -> bool:
        try:
            import repro.kernels.paged_attention  # noqa: F401
        except Exception:  # pragma: no cover - import guard
            return False
        return True


_ATTN_REGISTRY: dict[str, object] = {
    b.name: b for b in (DensePagedAttn, PallasPagedAttn)
}
#: auto order: the XLA gather path only — ``pallas`` is interpret-mode slow
#: on CPU and exists as an explicitly requested kernel-validation target.
_ATTN_AUTO = ("dense",)


def paged_attn_names() -> list[str]:
    return list(_ATTN_REGISTRY)


def available_paged_attn() -> list[str]:
    return [n for n, b in _ATTN_REGISTRY.items() if b.available()]


@dataclass(frozen=True)
class ResolvedPagedAttn:
    """A validated paged-attention implementation choice with provenance
    (mirrors ``gmm_backend.ResolvedBackend``: ``source`` records which
    precedence slot won)."""

    name: str
    source: str
    jax_version: str

    def __str__(self) -> str:                   # pragma: no cover - trivial
        return self.name


def _validate_attn(name: str) -> str:
    if name not in _ATTN_REGISTRY:
        raise ValueError(f"unknown paged-attention impl {name!r}; "
                         f"known: {paged_attn_names()}")
    if not _ATTN_REGISTRY[name].available():
        raise RuntimeError(
            f"paged-attention impl {name!r} is not available on jax "
            f"{jax.__version__}; available: {available_paged_attn()}")
    return name


def resolve_paged_attn(impl: str | ResolvedPagedAttn | None = None, *,
                       config: str | None = None) -> ResolvedPagedAttn:
    """arg > config > ``REPRO_PAGED_ATTN`` env > auto (``dense``)."""
    if isinstance(impl, ResolvedPagedAttn):
        return impl
    chain = (("arg", impl), ("config", config),
             ("env", os.environ.get(PAGED_ATTN_ENV, "").strip() or None))
    for source, cand in chain:
        if cand not in (None, "", "auto"):
            return ResolvedPagedAttn(_validate_attn(cand), source,
                                     jax.__version__)
    for cand in _ATTN_AUTO:
        if _ATTN_REGISTRY[cand].available():
            return ResolvedPagedAttn(cand, "auto", jax.__version__)
    raise RuntimeError("no paged-attention impl available")  # pragma: no cover


# ---------------------------------------------------------------------------
# host-side page allocator (refcounted)
# ---------------------------------------------------------------------------


class PagePool:
    """Refcounted free-list page allocator (host side; pages are ints).

    Page ``TRASH_PAGE`` is reserved at construction.  Frees push onto the
    list tail and allocs pop from it (LIFO), so a request admitted right
    after another finishes reuses the same physical pages — the property the
    page-table-reuse regression test pins down.

    Prefix sharing maps one physical page into several page tables:
    :meth:`share` takes an extra reference and :meth:`release` drops one;
    the page only rejoins the free list when its count reaches zero.
    Guards are O(1): a membership set mirrors the LIFO list (the old
    ``p in self._free`` scan was O(P) per page, O(P²) per batch of frees),
    and the refcount array catches double frees and invalid pages."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (one is the reserved trash page)")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, TRASH_PAGE, -1))
        self._free_set = set(self._free)
        self._refs = [0] * num_pages
        self.min_free = len(self._free)       # low-water mark (stats)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` pages (each born with one reference); raises if the
        pool cannot satisfy the request (callers check :attr:`free_pages`
        first — admission control)."""
        if n > len(self._free):
            raise RuntimeError(f"page pool exhausted: want {n}, "
                               f"have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._free_set.discard(p)
            self._refs[p] = 1
        self.min_free = min(self.min_free, len(self._free))
        return pages

    def _check_allocated(self, p: int) -> None:
        if p == TRASH_PAGE or not (0 < p < self.num_pages):
            raise ValueError(f"freeing invalid page {p}")
        if p in self._free_set or self._refs[p] < 1:
            raise ValueError(f"double free of page {p}")

    def share(self, page: int) -> int:
        """Take an extra reference on an allocated page (map it read-only
        into another page table).  Returns the new count."""
        self._check_allocated(page)
        self._refs[page] += 1
        return self._refs[page]

    def release(self, page: int) -> int:
        """Drop one reference; the page rejoins the free list (LIFO tail)
        when the count reaches zero.  Returns the remaining count."""
        self._check_allocated(page)
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)
            self._free_set.add(page)
        return self._refs[page]

    def free(self, pages: list[int]) -> None:
        """Drop one reference on each page.  The whole batch is validated
        before any mutation (a bad page never half-applies the free): a page
        appearing k times in the batch needs refcount >= k, else the batch
        would drive its count negative mid-apply.  Pages reaching zero
        rejoin in reversed order — preserving the exact LIFO reuse order of
        the pre-refcount allocator."""
        occurrences: dict[int, int] = {}
        for p in pages:
            self._check_allocated(p)
            occurrences[p] = occurrences.get(p, 0) + 1
            if occurrences[p] > self._refs[p]:
                raise ValueError(
                    f"double free of page {p}: batch frees it "
                    f"{occurrences[p]} times but refcount is {self._refs[p]}")
        for p in reversed(pages):
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                self._free_set.add(p)


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-max(n_tokens, 1) // page_size)


# ---------------------------------------------------------------------------
# prefix-trie page cache (copy-on-write prefix sharing)
# ---------------------------------------------------------------------------


def page_keys(prompt, page_size: int) -> list[bytes]:
    """Content keys of a prompt's FULL pages: one ``bytes`` per complete
    ``page_size`` chunk (the partial tail page is never shared — its page
    will be written by the owner's decode stream)."""
    import numpy as np
    p = np.asarray(prompt, np.int32)
    return [p[i * page_size:(i + 1) * page_size].tobytes()
            for i in range(p.size // page_size)]


class _TrieNode:
    __slots__ = ("page", "children", "last_use")

    def __init__(self, page: int, tick: int):
        self.page = page
        self.children: dict[bytes, _TrieNode] = {}
        self.last_use = tick


class PrefixCache:
    """Trie keyed by full-page prompt content, each node pinning one
    physical page of prompt KV (the cache holds one pool reference per
    node).  ``lookup`` walks the longest cached chain; ``insert`` adopts a
    finished request's full prompt pages (transferring the caller's
    reference); ``evict`` drops least-recently-used *leaf* nodes whose page
    no live request still maps — interior nodes are never evicted before
    their children, so every cached chain stays reachable from the root."""

    def __init__(self):
        self._root: dict[bytes, _TrieNode] = {}
        self._tick = 0
        self._n_pages = 0

    def __len__(self) -> int:
        return self._n_pages

    def lookup(self, keys: list[bytes]) -> list[int]:
        """Longest cached page chain matching ``keys`` front-to-back."""
        self._tick += 1
        out: list[int] = []
        level = self._root
        for key in keys:
            node = level.get(key)
            if node is None:
                break
            node.last_use = self._tick
            out.append(node.page)
            level = node.children
        return out

    def insert(self, keys: list[bytes], pages: list[int]) -> set[int]:
        """Register ``pages`` along the ``keys`` path.  Returns the set of
        pages the cache ADOPTED (it now owns the caller's reference on
        those); pages whose key already had a node are not adopted — the
        caller still owns its reference and should release it."""
        self._tick += 1
        adopted: set[int] = set()
        level = self._root
        for key, page in zip(keys, pages):
            node = level.get(key)
            if node is None:
                node = _TrieNode(page, self._tick)
                level[key] = node
                adopted.add(page)
                self._n_pages += 1
            else:
                node.last_use = self._tick
            level = node.children
        return adopted

    def evict(self, pool: PagePool, n: int) -> int:
        """Release up to ``n`` cached pages back to ``pool``, least recently
        used leaves first (a node is evictable only when it has no children
        and no live request shares its page, i.e. the cache holds the sole
        reference).  Returns the number of pages actually evicted."""
        evicted = 0
        while evicted < n:
            # collect current leaves with their parents
            leaves: list[tuple[dict, bytes, _TrieNode]] = []
            stack = [(self._root, key, node) for key, node in
                     self._root.items()]
            while stack:
                level, key, node = stack.pop()
                if node.children:
                    stack.extend((node.children, k, c)
                                 for k, c in node.children.items())
                else:
                    leaves.append((level, key, node))
            leaves = [(lv, k, nd) for lv, k, nd in leaves
                      if pool.refcount(nd.page) == 1]
            if not leaves:
                break
            leaves.sort(key=lambda t: t[2].last_use)
            level, key, node = leaves[0]
            del level[key]
            self._n_pages -= 1
            pool.release(node.page)
            evicted += 1
        return evicted

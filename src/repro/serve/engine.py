"""Batched serving engine: continuous-batching style decode over a fixed
slot pool, with prefill via the full forward and jitted single-token steps.

This is deliberately simple but real: requests enter a queue (``enqueue`` /
``run``) or come as a batch (``generate``), get assigned slots, share jitted
single-token decode steps (cache updates are functional), and leave when they
emit EOS or hit ``max_new_tokens``.

Grouped-GEMM backend selection is context-scoped (DESIGN: mixed fleets share
one config while each host/engine picks its fastest available backend):

* the engine resolves its default backend **once, at construction** — via
  ``repro.core.gmm_backend.resolve`` with the engine's ``gmm_backend``
  argument at the call-site slot and ``cfg.gmm_backend`` at the config slot —
  and holds the ``ResolvedBackend``.  Mutating ``REPRO_GMM_BACKEND``
  afterwards cannot retarget a constructed engine, and two engines in one
  process can run different backends over the same config;
* each ``Request`` may carry its own ``gmm_backend`` override, validated at
  enqueue time (an unknown name raises immediately, never mid-generate);
* ``generate`` resolves per batch slot and groups slots by resolved backend,
  so one batch can mix requests pinned to different backends.

Decode steps are jitted per backend name (separate function objects keep the
jit caches apart) with the concrete name baked into the config, and every
call runs inside ``use_backend`` so an ambient scope at first-trace time
cannot leak into the cached computation.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checkpoint as CK
from repro.core import gmm_backend as GB
from repro.models import transformer as T


@dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = 2
    gmm_backend: str | None = None  # per-request override of the engine default
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 capacity: int = 512, greedy: bool = True, seed: int = 0,
                 gmm_backend: str | None = None, remat_policy=None,
                 mesh=None):
        # Snapshot the backend resolution at construction: precedence is the
        # explicit engine argument > active use_backend scope >
        # cfg.gmm_backend > env > auto, frozen into a ResolvedBackend.
        self.backend = GB.resolve(gmm_backend, config=cfg.gmm_backend)
        # Same discipline for the checkpoint plan: the engine argument
        # (name/spec/plan) wins over cfg.remat_policy; an unparseable spec
        # raises HERE, never mid-generate.  Decode never runs a backward, so
        # the plan is provenance + config hygiene — the canonical spec is
        # baked into the engine's cfg and surfaced as ``remat_plan``.
        self.remat_plan = CK.resolve_plan(remat_policy,
                                          config=cfg.remat_policy)
        self.cfg = cfg.replace(gmm_backend=self.backend.name,
                               remat_policy=self.remat_plan.spec)
        if cfg.is_moe:
            # Eagerly validate the plan's moe-scoped residual decisions
            # (coupled-FFN_A/B or save-Y_swi-under-recompute-A/B raise).
            CK.moe_residual_mode(self.cfg)
        # Validate the MoE distribution mode for this (cfg, mesh) pairing at
        # construction — decode steps run it via shard_map when a mesh is
        # given, and a bad pairing must not surface mid-generate.  ep_a2a is
        # degenerate for decode (single-token slabs rarely divide the model
        # axis, and there is nothing to exchange at S=1), so it falls back to
        # plain EP: numerically identical, same expert-sharded weight layout.
        if cfg.is_moe:
            from repro.models.moe_block import resolve_moe_parallel
            mode = resolve_moe_parallel(self.cfg, mesh)
            if mode == "ep_a2a":
                self.cfg = self.cfg.replace(moe_parallel="ep")
        self.mesh = mesh
        self.params = params
        self.slots = batch_slots
        self.capacity = capacity
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.pending: list[Request] = []
        self._decode_fns: dict[str, object] = {}

    def _decode_for(self, backend_name: str):
        """The jitted single-token decode step specialized to one backend.
        One function object per backend keeps their jit caches separate."""
        fn = self._decode_fns.get(backend_name)
        if fn is None:
            cfg = self.cfg.replace(gmm_backend=backend_name)
            fn = jax.jit(
                lambda p, c, tok, pos: T.decode_step(
                    p, c, {"tokens": tok}, pos, cfg, mesh=self.mesh),
                donate_argnums=(1,))   # cache updated in place
            self._decode_fns[backend_name] = fn
        return fn

    def resolve_request(self, request: Request) -> GB.ResolvedBackend:
        """The backend a request will decode with: its own override at the
        call-site slot, falling back to the engine's construction-time
        snapshot.  Raises on unknown/unavailable names."""
        if request.gmm_backend in (None, "", "auto"):
            return self.backend
        return GB.resolve(request.gmm_backend, config=self.cfg.gmm_backend)

    # -- queue API ----------------------------------------------------------

    def enqueue(self, request: Request) -> Request:
        """Admit a request to the pending queue.  Backend validation happens
        HERE — an unknown or unavailable ``gmm_backend`` raises at enqueue,
        never mid-generate with other requests' tokens in flight."""
        self.resolve_request(request)
        self.pending.append(request)
        return request

    def run(self) -> list[Request]:
        """Drain the pending queue in slot-sized batches."""
        done: list[Request] = []
        while self.pending:
            batch = self.pending[:self.slots]
            del self.pending[:self.slots]
            done.extend(self.generate(batch))
        return done

    # -- batched generation -------------------------------------------------

    def generate(self, requests: list[Request]) -> list[Request]:
        assert len(requests) <= self.slots
        # Resolve every slot up front (raises before any decode work), then
        # group slots by resolved backend — one batch may mix overrides.
        resolved = [self.resolve_request(r) for r in requests]
        groups: dict[str, list[int]] = {}
        for i, rb in enumerate(resolved):
            groups.setdefault(rb.name, []).append(i)
        for name, idxs in groups.items():
            self._generate_group([requests[i] for i in idxs], name)
        return requests

    def _prefill(self, prompts: np.ndarray, backend_name: str):
        """Sequential cache fill via the decode step (teacher-forcing each
        prompt token).  Prompts are right-aligned to a common length."""
        B, S = prompts.shape
        cache = T.init_cache(self.cfg, B, self.capacity)
        decode = self._decode_for(backend_name)
        logits = None
        for t in range(S):
            logits, cache = decode(
                self.params, cache, jnp.asarray(prompts[:, t:t + 1]),
                jnp.array(t))
        return logits, cache, S

    def _generate_group(self, requests: list[Request], backend_name: str):
        """Greedy-decode one group of requests that share a backend."""
        S = max(r.prompt.size for r in requests)
        prompts = np.zeros((len(requests), S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, S - r.prompt.size:] = r.prompt     # left-pad
        decode = self._decode_for(backend_name)
        # The use_backend scope pins trace-time resolution to this group's
        # backend even if the caller holds an ambient scope of their own.
        with GB.use_backend(backend_name):
            logits, cache, pos = self._prefill(prompts, backend_name)
            max_new = max(r.max_new_tokens for r in requests)
            for _ in range(max_new):
                nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
                for i, r in enumerate(requests):
                    if not r.done and len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(nxt[i]))
                        if nxt[i] == r.eos_id:
                            r.done = True
                if all(r.done or len(r.out_tokens) >= r.max_new_tokens
                       for r in requests):
                    break
                logits, cache = decode(
                    self.params, cache, jnp.asarray(nxt[:, None]),
                    jnp.array(pos))
                pos += 1

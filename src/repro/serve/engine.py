"""Batched serving engine: continuous-batching style decode over a fixed
slot pool, with prefill via the full forward and jitted single-token steps.

This is deliberately simple but real: requests enter a queue, get assigned
slots, share one jitted decode step (cache updates are functional), and leave
when they emit EOS or hit ``max_new_tokens``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = 2
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 capacity: int = 512, greedy: bool = True, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.capacity = capacity
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, tok, pos: T.decode_step(
                p, c, {"tokens": tok}, pos, cfg),
            donate_argnums=(1,))   # cache updated in place

    def _prefill(self, prompts: np.ndarray):
        """Sequential cache fill via the decode step (teacher-forcing each
        prompt token).  Prompts are right-aligned to a common length."""
        B, S = prompts.shape
        cache = T.init_cache(self.cfg, B, self.capacity)
        logits = None
        for t in range(S):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(prompts[:, t:t + 1]),
                jnp.array(t))
        return logits, cache, S

    def generate(self, requests: list[Request]) -> list[Request]:
        assert len(requests) <= self.slots
        S = max(r.prompt.size for r in requests)
        prompts = np.zeros((len(requests), S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, S - r.prompt.size:] = r.prompt     # left-pad
        logits, cache, pos = self._prefill(prompts)
        max_new = max(r.max_new_tokens for r in requests)
        for _ in range(max_new):
            nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
            for i, r in enumerate(requests):
                if not r.done and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(nxt[i]))
                    if nxt[i] == r.eos_id:
                        r.done = True
            if all(r.done or len(r.out_tokens) >= r.max_new_tokens
                   for r in requests):
                break
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(nxt[:, None]),
                jnp.array(pos))
            pos += 1
        return requests

"""Paged, continuously-batched serving engine (SHARK-Engine architecture).

Requests enter a queue (``enqueue`` / ``run``) or come as a batch
(``generate``), and the scheduler runs them through two jitted entry
families:

* **prefill** — ONE whole-prompt forward per admitted batch (bucketed to
  power-of-two ``(batch, seq)`` shapes so the jit cache stays bounded) that
  scatters every prompt position's k/v through per-request *page tables*
  into a block-paged KV pool (``serve/paged_cache``).  Prompts are
  right-padded and masked by per-request prefix length, so batched output ==
  solo output (the left-pad parity gate).
* **decode** — a single-token step over the full slot array with every
  request at its OWN position (``T.paged_decode_step``).  Inactive slots
  point at the reserved trash page and cost no correctness.  The gather can
  run as the dense jnp reference or the Pallas page-walk kernel
  (``paged_kernel=`` / ``REPRO_PAGED_ATTN``, resolved at construction like
  the grouped-GEMM backend).

Sampling is FOLDED INTO the jitted steps: only ``(slots,)`` token ids cross
the host boundary each step, never ``(slots, vocab)`` logits.  Greedy
argmaxes in-graph; ``greedy=False`` temperature-samples with a per-request
PRNG key — ``fold_in(fold_in(seed_key, request_id), token_index)`` — so a
request's token stream depends only on its own id and seed, NEVER on how
requests were batched or scheduled.  That schedule-independence is what
makes the async runtime (``serve/runtime``) token-identical to this
synchronous path under a fixed seed (the pipeline parity gate).

**Prefix sharing (``prefix_cache=True``)**: the engine keeps a persistent
:class:`~repro.serve.paged_cache.PrefixCache` — a trie over full-page
prompt chunks.  A finishing request donates its full prompt pages; a later
request whose prompt shares a page-aligned prefix maps the cached pages
read-only (one pool refcount each) and prefills ONLY the unshared suffix
through the offset-prefill path.  When the prompt is exactly covered by
shared pages, the last prompt token is re-fed and its target page is forked
first — copy-on-write: the writer gets a private device-side copy
(``paged_cache.copy_page``), the page table is remapped (branch-free, the
trash-page idiom), and the sharer's page is never mutated.  Cache pages are
evicted LRU-leaf-first when admission needs their space.

Scheduling is continuous and split into three stages — **admission**
(validation, prefix lookup, slot/page allocation, COW forks), **device**
(jitted prefill/decode dispatch; everything stays on device, including each
step's sampled tokens feeding the next step), and **sampling/emission**
(the only host sync: token ids to Python, ``on_token`` callbacks, EOS/limit
finish decisions).  The synchronous engine chains the stages inline;
``serve/runtime.AsyncServeRuntime`` runs them in pipelined threads
connected by ``WorkQueue``s.  A request's slot and pages return to the pool
the moment it finishes; admission is under a page budget with FIFO blocking
(``stats['blocked_admissions']``).

``kv_dtype='int8'`` stores the pool quantized via ``serve/kv_quant``'s
symmetric per-(position, head) scheme.

Grouped-GEMM backend selection is context-scoped (DESIGN: mixed fleets share
one config while each host/engine picks its fastest available backend): the
engine resolves once at construction (engine argument > ``use_backend``
scope > ``cfg.gmm_backend`` > env > auto) and holds the
``ResolvedBackend``; each ``Request`` may carry its own override, validated
at enqueue time; ``generate`` groups slots by resolved backend.  Steps are
jitted per backend name inside ``use_backend`` so an ambient scope at
first-trace time cannot leak into the cached computation.
"""

from __future__ import annotations

import itertools

from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checkpoint as CK
from repro.core import gmm_backend as GB
from repro.models import transformer as T
from repro.serve import paged_cache as PC


@dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = 2
    gmm_backend: str | None = None  # per-request override of the engine default
    on_token: Callable[[int], None] | None = None   # streaming: per token
    on_finish: Callable[[str], None] | None = None  # terminal event (reason)
    out_tokens: list = field(default_factory=list)
    done: bool = False
    finish_reason: str | None = None    # "eos" | "length" | "error"
    rid: int | None = None              # engine-assigned id (PRNG lane)


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _emit_token(r: Request, tok: int) -> None:
    r.out_tokens.append(tok)
    if r.on_token is not None:
        r.on_token(tok)


def _finish_request(r: Request, reason: str) -> None:
    r.done = True
    if r.finish_reason is None:
        r.finish_reason = reason
    if r.on_finish is not None:
        r.on_finish(reason)


class ServeEngine:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 capacity: int = 512, page_size: int = 16,
                 num_pages: int | None = None, kv_dtype: str | None = None,
                 greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0, gmm_backend: str | None = None,
                 prefix_cache: bool = False, paged_kernel: str | None = None,
                 remat_policy=None, mesh=None):
        # Snapshot the backend resolution at construction: precedence is the
        # explicit engine argument > active use_backend scope >
        # cfg.gmm_backend > env > auto, frozen into a ResolvedBackend.
        self.backend = GB.resolve(gmm_backend, config=cfg.gmm_backend)
        # The paged-attention implementation resolves with the same
        # discipline (arg > REPRO_PAGED_ATTN env > auto) and is baked into
        # the jitted steps — an unknown/unavailable kernel raises HERE.
        self.paged_attn = PC.resolve_paged_attn(paged_kernel)
        # Same for the checkpoint plan: the engine argument wins over
        # cfg.remat_policy; an unparseable spec raises HERE, never
        # mid-generate.  Decode never runs a backward, so the plan is
        # provenance + config hygiene.
        self.remat_plan = CK.resolve_plan(remat_policy,
                                          config=cfg.remat_policy)
        self.cfg = cfg.replace(gmm_backend=self.backend.name,
                               remat_policy=self.remat_plan.spec)
        if not T.paged_supported(cfg):
            raise ValueError(
                f"ServeEngine pages attention KV; {cfg.name} has "
                f"block pattern {cfg.block_pattern} (SSM carries are O(1) "
                f"per-slot state — serve those via T.decode_step directly)")
        if kv_dtype not in (None, "model", "int8"):
            raise ValueError(f"kv_dtype must be None|'model'|'int8', "
                             f"got {kv_dtype!r}")
        if not greedy and temperature <= 0:
            raise ValueError("temperature must be > 0 for sampling")
        if cfg.is_moe:
            # Eagerly validate the plan's moe-scoped residual decisions
            # (coupled-FFN_A/B or save-Y_swi-under-recompute-A/B raise).
            CK.moe_residual_mode(self.cfg)
        # Validate the MoE distribution mode for this (cfg, mesh) pairing at
        # construction — decode steps run it via shard_map when a mesh is
        # given, and a bad pairing must not surface mid-generate.  The token
        # exchanges are degenerate for decode (single-token slabs rarely
        # divide the expert axes, and there is nothing to exchange at S=1),
        # so an explicit ep_a2a / ep_a2a_hier falls back to plain EP:
        # numerically identical, same expert-sharded weight layout.  'auto'
        # stays 'auto' — the cost model resolves it per decode slab, and its
        # live-bytes tie-break lands on EP for decode-sized token counts.
        if cfg.is_moe:
            from repro.models.moe_block import resolve_moe_parallel
            if self.cfg.moe_parallel in ("ep_a2a", "ep_a2a_hier"):
                self.cfg = self.cfg.replace(moe_parallel="ep")
            resolve_moe_parallel(self.cfg, mesh)
        self.mesh = mesh
        self.params = params
        self.slots = batch_slots
        self.capacity = capacity
        self.page_size = page_size
        self.quantized = kv_dtype == "int8"
        self.pages_per_seq = PC.pages_needed(capacity, page_size)
        # Default budget: full occupancy at max capacity, plus the trash page.
        self.num_pages = (num_pages if num_pages is not None
                          else 1 + batch_slots * self.pages_per_seq)
        if self.num_pages < 2:
            raise ValueError("num_pages must be >= 2 (one is the trash page)")
        self.greedy = greedy
        self.temperature = temperature
        self._base_key = jax.random.PRNGKey(seed)
        self.pending: list[Request] = []
        # itertools.count: a single next() is atomic, so concurrent
        # submit() threads (async runtime) never mint duplicate rids.
        self._rid_counter = itertools.count()
        # Persistent device state: the page pool, the paged KV cache, and
        # the prefix trie live for the engine's life (prefix hits span
        # generate() calls), lazily created at first use.
        self._pool: PC.PagePool | None = None
        self._cache = None
        self._prefix = PC.PrefixCache() if prefix_cache else None
        self._decode_fns: dict[str, object] = {}
        self._prefill_fns: dict[tuple, object] = {}
        # last_tok scatter for admitted slots (shape-specialized by jit).
        self._merge_fn = jax.jit(
            lambda lt, tk, idx: lt.at[idx, 0].set(tk[:idx.shape[0]]))
        # COW fork: copy one physical page across every layer's pools
        # (leaves are (num_groups, P, page_size, ...) — page axis is 1).
        self._copy_page_fn = jax.jit(
            lambda c, src, dst: jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), c),
            donate_argnums=(0,))
        self.stats = {"prefill_calls": 0, "prefill_tokens": 0,
                      "decode_steps": 0, "decode_slot_tokens": 0,
                      "generated_tokens": 0, "blocked_admissions": 0,
                      "truncated_budgets": 0, "peak_pages_used": 0,
                      "prefix_hits": 0, "prefix_misses": 0,
                      "shared_pages_mapped": 0, "cow_forks": 0,
                      "prefix_evictions": 0}

    # -- persistent device state --------------------------------------------

    def _ensure_state(self) -> None:
        if self._pool is None:
            self._pool = PC.PagePool(self.num_pages)
            self._cache = T.init_paged_cache(self.cfg, self.num_pages,
                                             self.page_size,
                                             quantized=self.quantized)

    # -- jitted entry points ------------------------------------------------

    def _sample_traced(self, logits, rid, gidx):
        """In-graph sampling: (B, vocab) logits -> (B,) int32 token ids.
        Greedy argmaxes; otherwise each row samples with its own
        ``fold_in(fold_in(seed, rid), token_index)`` key — schedule- and
        batch-independent, the property both parity gates lean on."""
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        base = self._base_key

        def one(r, g, row):
            key = jax.random.fold_in(jax.random.fold_in(base, r), g)
            return jax.random.categorical(key, row / self.temperature)

        return jax.vmap(one)(rid, gidx, logits).astype(jnp.int32)

    def _decode_for(self, backend_name: str):
        """The jitted single-token decode step specialized to one backend —
        full slot array, per-request positions, sampling fused in (only the
        ``(slots,)`` token ids ever reach the host)."""
        fn = self._decode_fns.get(backend_name)
        if fn is None:
            cfg = self.cfg.replace(gmm_backend=backend_name)
            impl = self.paged_attn.name

            def step(p, c, tok, lens, pt, rid, gidx):
                logits, c2 = T.paged_decode_step(p, c, tok, lens, pt, cfg,
                                                 mesh=self.mesh,
                                                 attn_impl=impl)
                return self._sample_traced(logits, rid, gidx), c2

            fn = jax.jit(step, donate_argnums=(1,))   # cache updated in place
            self._decode_fns[backend_name] = fn
        return fn

    def _prefill_for(self, backend_name: str, bs: int, seq: int,
                     prefix: bool):
        """The jitted whole-prompt (or unshared-suffix) prefill for one
        (backend, batch-bucket, seq-bucket, prefix-path) — the SHARK
        per-batch-size entry-point family, with power-of-two bucketing
        keeping the family finite.  Returns sampled tokens, not logits."""
        key = (backend_name, bs, seq, prefix)
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg = self.cfg.replace(gmm_backend=backend_name)
            impl = self.paged_attn.name

            def pf(p, c, tok, lens, pt, offs, rid):
                logits, c2 = T.prefill(
                    p, tok, lens, c, pt, cfg, mesh=self.mesh,
                    offsets=offs if prefix else None, attn_impl=impl)
                gidx = jnp.zeros_like(rid)
                return self._sample_traced(logits, rid, gidx), c2

            fn = jax.jit(pf, donate_argnums=(1,))
            self._prefill_fns[key] = fn
        return fn

    # -- validation ---------------------------------------------------------

    def resolve_request(self, request: Request) -> GB.ResolvedBackend:
        """The backend a request will decode with: its own override at the
        call-site slot, falling back to the engine's construction-time
        snapshot.  Raises on unknown/unavailable names."""
        if request.gmm_backend in (None, "", "auto"):
            return self.backend
        return GB.resolve(request.gmm_backend, config=self.cfg.gmm_backend)

    def _limit(self, request: Request) -> int:
        """Effective new-token budget: the cache holds ``prompt + (T - 1)``
        written tokens for T generated, bounded by ``capacity``."""
        return min(request.max_new_tokens,
                   self.capacity - request.prompt.size + 1)

    def _validate(self, request: Request) -> None:
        self.resolve_request(request)
        if request.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {request.max_new_tokens} "
                f"(prefill always samples one token)")
        if request.prompt.size > self.capacity:
            raise ValueError(
                f"prompt of {request.prompt.size} tokens exceeds engine "
                f"capacity {self.capacity}")
        need = PC.pages_needed(
            request.prompt.size + self._limit(request) - 1, self.page_size)
        if need > self.num_pages - 1:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.num_pages - 1} allocatable pages")
        if request.rid is None:
            request.rid = next(self._rid_counter)

    # -- queue API ----------------------------------------------------------

    def enqueue(self, request: Request) -> Request:
        """Admit a request to the pending queue.  Backend + budget
        validation happens HERE — an unknown ``gmm_backend`` or an
        impossible-to-schedule request raises at enqueue, never mid-generate
        with other requests' tokens in flight."""
        self._validate(request)
        self.pending.append(request)
        return request

    def run(self) -> list[Request]:
        """Drain the pending queue.  The scheduler batches continuously, so
        the whole queue goes in at once — slots refill as requests finish."""
        batch = self.pending
        self.pending = []
        return self.generate(batch)

    # -- batched generation -------------------------------------------------

    def generate(self, requests: list[Request]) -> list[Request]:
        # Validate every request up front (raises before any decode work),
        # then group by resolved backend — one batch may mix overrides.
        for r in requests:
            self._validate(r)
        resolved = [self.resolve_request(r) for r in requests]
        groups: dict[str, list[int]] = {}
        for i, rb in enumerate(resolved):
            groups.setdefault(rb.name, []).append(i)
        for name, idxs in groups.items():
            self._serve_group([requests[i] for i in idxs], name)
        return requests

    def _serve_group(self, requests: list[Request], backend_name: str):
        """Continuously serve one group of requests sharing a backend: the
        three pipeline stages chained inline (the async runtime runs the
        same :class:`_GroupScheduler` stages across threads)."""
        sched = _GroupScheduler(self, requests, backend_name)
        # The use_backend scope pins trace-time resolution to this group's
        # backend even if the caller holds an ambient scope of their own.
        with GB.use_backend(backend_name):
            try:
                while sched.has_work():
                    admit = sched.try_admit()             # admission stage
                    if admit:
                        snap = [(s, sched.owner[s]) for s in admit]
                        ptoks = sched.dispatch_prefill(admit)   # device
                        for s in sched.emit_prefill(snap, np.asarray(ptoks)):
                            sched.release(s)              # emission stage
                    out = sched.dispatch_decode()         # device stage
                    if out is None:
                        continue
                    toks, snap = out
                    for s in sched.emit_decode(snap, np.asarray(toks)):
                        sched.release(s)                  # emission stage
            except Exception:
                for r in sched.in_flight() + list(sched.waiting):
                    if not r.done:
                        _finish_request(r, "error")
                raise
        self.stats["peak_pages_used"] = max(
            self.stats["peak_pages_used"],
            self.num_pages - 1 - self._pool.min_free)


class _GroupScheduler:
    """The old ``_serve_group`` monolith split into its three stages.

    * **admission** — :meth:`try_admit`: FIFO under the page budget, prefix
      trie lookup, shared-page mapping (refcounts), COW forks, LRU cache
      eviction under pressure;
    * **device** — :meth:`dispatch_prefill` / :meth:`dispatch_decode`: build
      host staging buffers, issue the jitted steps, keep the sampled-token
      array device-resident (each step's output feeds the next step's input
      without a host round-trip);
    * **sampling/emission** — :meth:`emit_prefill` / :meth:`emit_decode`:
      the only host sync; append tokens, fire streaming callbacks, decide
      EOS/limit finishes.  :meth:`release` returns a finished slot's pages
      (donating full prompt pages to the prefix cache).

    The synchronous engine calls the stages back-to-back; the async runtime
    (``serve/runtime``) calls admission+device on its device thread and
    emit_* on its emission thread, connected by ``WorkQueue``s.  Because
    sampling keys are per-request (never per-step-of-the-engine), tokens do
    not depend on which stage interleaving ran them.
    """

    def __init__(self, eng: ServeEngine, requests: list[Request],
                 backend_name: str):
        eng._ensure_state()
        self.eng = eng
        self.backend_name = backend_name
        self.pool = eng._pool
        self.ps = eng.page_size
        self.pps = eng.pages_per_seq
        n = eng.slots
        self.waiting: deque[Request] = deque(requests)
        self.free_slots = list(range(n - 1, -1, -1))
        self.owner: list[Request | None] = [None] * n
        self.mapped_pages: list[list[int] | None] = [None] * n
        self.shared_cols: list[dict | None] = [None] * n
        self.suffix_start = [0] * n
        self.cap_of = np.zeros(n, np.int32)     # max tokens ever written
        self.page_table = np.full((n, self.pps), PC.TRASH_PAGE, np.int32)
        self.lengths = np.zeros(n, np.int32)    # tokens in cache
        self.gen_count = np.zeros(n, np.int32)  # tokens produced (PRNG lane)
        self.rid = np.zeros(n, np.int32)
        self.last_tok = jnp.zeros((n, 1), jnp.int32)   # device-resident
        self.decode_fn = eng._decode_for(backend_name)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(o is not None for o in self.owner)

    def in_flight(self) -> list[Request]:
        return [o for o in self.owner if o is not None]

    # -- admission stage ----------------------------------------------------

    def try_admit(self) -> list[int]:
        """Admit pending requests while slots + pages allow, preserving FIFO
        order under the page budget.  With the prefix cache enabled, each
        prompt's full-page chain is looked up first: hits map the cached
        pages read-only (share refs) and shrink the private-page need to the
        unshared suffix; a fully-covered prompt re-feeds its last token into
        a copy-on-write fork of the final shared page."""
        eng = self.eng
        st = eng.stats
        admit: list[int] = []
        while self.waiting and self.free_slots:
            r = self.waiting[0]
            plen = int(r.prompt.size)
            limit = eng._limit(r)
            total_need = PC.pages_needed(plen + limit - 1, self.ps)
            def plan(shared):
                # A prompt exactly covered by shared pages still needs one
                # forward token for its first logits: re-feed the last
                # prompt token (its write forks the final shared page —
                # COW).
                n_shared = len(shared)
                refeed = n_shared > 0 and n_shared * self.ps >= plen
                sstart = plen - 1 if refeed else n_shared * self.ps
                need_private = total_need - n_shared + (1 if refeed else 0)
                return n_shared, refeed, sstart, need_private

            shared: list[int] = []
            if eng._prefix is not None:
                shared = eng._prefix.lookup(PC.page_keys(r.prompt, self.ps))
                # Pin the looked-up chain BEFORE any eviction: share()
                # lifts each page's refcount above 1, so evict() (which
                # only frees sole-owner leaves) can never reclaim the
                # pages this request is about to map.
                for pg in shared:
                    self.pool.share(pg)
            n_shared, refeed, sstart, need_private = plan(shared)
            if need_private > self.pool.free_pages and eng._prefix is not None:
                st["prefix_evictions"] += eng._prefix.evict(
                    self.pool, need_private - self.pool.free_pages)
                if need_private > self.pool.free_pages and shared:
                    # Not enough evictable OUTSIDE the pinned chain: trade
                    # sharing for capacity.  Unpin, evict again (the chain
                    # was just touched, so LRU takes everything else
                    # first), and re-plan on whatever chain survived.
                    for pg in shared:
                        self.pool.release(pg)
                    st["prefix_evictions"] += eng._prefix.evict(
                        self.pool, total_need - self.pool.free_pages)
                    shared = eng._prefix.lookup(
                        PC.page_keys(r.prompt, self.ps))
                    for pg in shared:
                        self.pool.share(pg)
                    n_shared, refeed, sstart, need_private = plan(shared)
            if need_private > self.pool.free_pages:
                # FIFO under the page budget: the head waits (and is
                # accounted), later requests do not jump it.  Unpin the
                # chain — the cache keeps its own reference.
                for pg in shared:
                    self.pool.release(pg)
                st["blocked_admissions"] += 1
                break
            self.waiting.popleft()
            if limit < r.max_new_tokens:
                # Capacity silently bounds the budget; surface it.
                st["truncated_budgets"] += 1
            if eng._prefix is not None:
                st["prefix_hits" if n_shared else "prefix_misses"] += 1
                st["shared_pages_mapped"] += n_shared
            slot = self.free_slots.pop()
            priv = self.pool.alloc(need_private)
            row = np.full(self.pps, PC.TRASH_PAGE, np.int32)
            row[:n_shared] = shared
            n_tail = total_need - n_shared
            if n_tail:
                row[n_shared:total_need] = priv[:n_tail]
            self.owner[slot] = r
            self.mapped_pages[slot] = shared + priv
            self.shared_cols[slot] = {c: shared[c] for c in range(n_shared)}
            self.suffix_start[slot] = sstart
            self.cap_of[slot] = plen + limit - 1
            self.lengths[slot] = 0
            self.gen_count[slot] = 0
            self.rid[slot] = r.rid
            if refeed:
                self._fork(slot, n_shared - 1, priv[n_tail], row)
            self.page_table[slot] = row
            admit.append(slot)
        return admit

    def _fork(self, slot: int, col: int, new_page: int, row) -> None:
        """Copy-on-write: fork shared column ``col`` into ``new_page`` (a
        device-side page copy), remap the writer's table, and drop the
        writer's reference on the shared original — the sharer's page is
        never written."""
        eng = self.eng
        old = self.shared_cols[slot].pop(col)
        eng._cache = eng._copy_page_fn(eng._cache, old, new_page)
        row[col] = new_page
        self.pool.release(old)
        self.mapped_pages[slot].remove(old)
        eng.stats["cow_forks"] += 1

    # -- device stage -------------------------------------------------------

    def dispatch_prefill(self, admit: list[int]):
        """One jitted prefill over the admitted batch (suffixes only when
        prefix sharing applies).  Returns the sampled-token device array;
        the slots' ``last_tok`` lanes are updated device-side."""
        eng = self.eng
        use_prefix = eng._prefix is not None
        sufs = [self.owner[s].prompt.size - self.suffix_start[s]
                for s in admit]
        # Clamp the pow2 seq bucket to the page table's logical width: a
        # wider bucket would make the prefill pad tail spill past the table
        # (routed to the trash page, but the clamp keeps the prefill shape
        # honest and the jit-cache family within the table).
        sb = min(_pow2(max(sufs)), self.pps * self.ps)
        bb = _pow2(len(admit))
        toks = np.zeros((bb, sb), np.int32)
        lens = np.zeros(bb, np.int32)
        offs = np.zeros(bb, np.int32)
        rid = np.zeros(bb, np.int32)
        pt = np.full((bb, self.pps), PC.TRASH_PAGE, np.int32)
        for i, s in enumerate(admit):
            r = self.owner[s]
            suf = r.prompt[self.suffix_start[s]:]
            toks[i, :suf.size] = suf
            lens[i] = suf.size
            offs[i] = self.suffix_start[s]
            rid[i] = self.rid[s]
            pt[i] = self.page_table[s]
        pf = eng._prefill_for(self.backend_name, bb, sb, use_prefix)
        ptoks, eng._cache = pf(eng.params, eng._cache, jnp.asarray(toks),
                               jnp.asarray(lens), jnp.asarray(pt),
                               jnp.asarray(offs), jnp.asarray(rid))
        eng.stats["prefill_calls"] += 1
        eng.stats["prefill_tokens"] += int(lens[:len(admit)].sum())
        self.last_tok = eng._merge_fn(
            self.last_tok, ptoks,
            jnp.asarray(np.asarray(admit, np.int32)))
        for s in admit:
            self.lengths[s] = self.owner[s].prompt.size
            self.gen_count[s] = 1
        return ptoks

    def dispatch_decode(self):
        """One decode step over the full slot array.  Slots that already
        wrote their last reserved position ("frozen": the async runtime may
        run ahead of finish notifications) are routed to the trash page so
        they cannot touch live pages.  Returns ``(token device array,
        [(slot, request, token_index), ...])`` for the emission stage, or
        ``None`` when nothing is live."""
        eng = self.eng
        live = [s for s in range(eng.slots)
                if self.owner[s] is not None
                and self.lengths[s] < self.cap_of[s]]
        if not live:
            return None
        frozen = [s for s in range(eng.slots)
                  if self.owner[s] is not None and s not in live]
        lens_step = self.lengths
        pt_step = self.page_table
        if frozen:
            lens_step = lens_step.copy()
            pt_step = pt_step.copy()
            for s in frozen:
                lens_step[s] = 0
                pt_step[s] = PC.TRASH_PAGE
        gidx = self.gen_count
        toks, eng._cache = self.decode_fn(
            eng.params, eng._cache, self.last_tok, jnp.asarray(lens_step),
            jnp.asarray(pt_step), jnp.asarray(self.rid), jnp.asarray(gidx))
        self.last_tok = toks[:, None]
        eng.stats["decode_steps"] += 1
        eng.stats["decode_slot_tokens"] += len(live)
        snap = [(s, self.owner[s], int(self.gen_count[s])) for s in live]
        for s in live:
            self.lengths[s] += 1
            self.gen_count[s] += 1
        return toks, snap

    # -- sampling/emission stage --------------------------------------------

    def _emit_one(self, r: Request, tok: int) -> bool:
        """Append + stream one token; returns True when the request is now
        finished (EOS or budget)."""
        eng = self.eng
        _emit_token(r, tok)
        eng.stats["generated_tokens"] += 1
        if tok == r.eos_id:
            _finish_request(r, "eos")
        elif len(r.out_tokens) >= eng._limit(r):
            _finish_request(r, "length")
        return r.done

    def emit_prefill(self, snap: list[tuple[int, Request]],
                     np_toks) -> list[int]:
        """Emit each admitted request's first token; returns slots to
        release."""
        finished = []
        for i, (s, r) in enumerate(snap):
            if r.done:       # async run-ahead: already terminal
                continue
            if self._emit_one(r, int(np_toks[i])):
                finished.append(s)
        return finished

    def emit_decode(self, snap: list[tuple[int, Request, int]],
                    np_toks) -> list[int]:
        """Emit one decode step's tokens; returns slots to release.  Tokens
        for requests that finished since dispatch (async run-ahead) are
        dropped — the synchronous path never produces them."""
        finished = []
        for s, r, _tidx in snap:
            if r.done:
                continue
            if self._emit_one(r, int(np_toks[s])):
                finished.append(s)
        return finished

    def release(self, slot: int) -> None:
        """Return a finished slot's pages.  With the prefix cache, the
        request's FULL prompt pages are donated to the trie first (the
        cache adopts one reference per newly cached page); every other
        reference is dropped in a single batch so the pre-refcount LIFO
        reuse order is preserved exactly."""
        eng = self.eng
        r = self.owner[slot]
        pages = self.mapped_pages[slot]
        adopted: set[int] = set()
        if eng._prefix is not None:
            n_full = r.prompt.size // self.ps
            chain = [int(self.page_table[slot, c]) for c in range(n_full)]
            adopted = eng._prefix.insert(
                PC.page_keys(r.prompt, self.ps), chain)
        self.pool.free([p for p in pages if p not in adopted])
        self.owner[slot] = None
        self.mapped_pages[slot] = None
        self.shared_cols[slot] = None
        self.page_table[slot, :] = PC.TRASH_PAGE   # stale entries must not
        self.lengths[slot] = 0                     # alias freshly reused pages
        self.cap_of[slot] = 0
        self.free_slots.append(slot)

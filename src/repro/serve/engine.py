"""Paged, continuously-batched serving engine (SHARK-Engine architecture).

Requests enter a queue (``enqueue`` / ``run``) or come as a batch
(``generate``), and the scheduler runs them through two jitted entry
families:

* **prefill** — ONE whole-prompt forward per admitted batch (bucketed to
  power-of-two ``(batch, seq)`` shapes so the jit cache stays bounded) that
  scatters every prompt position's k/v through per-request *page tables*
  into a block-paged KV pool (``serve/paged_cache``).  This replaces the
  seed's token-at-a-time teacher-forcing loop — and its left-pad bug, where
  pad tokens entered the cache at *valid* positions and a short prompt's
  output depended on its batch-mates.  Prompts are right-padded and masked
  by per-request prefix length, so batched output == solo output.
* **decode** — a single-token step over the full slot array with every
  request at its OWN position (``T.paged_decode_step``).  Inactive slots
  point at the reserved trash page and cost no correctness.

Scheduling is continuous: a request's slot and pages return to the pool the
moment it emits EOS or hits ``max_new_tokens``, and the next pending request
is admitted immediately — no head-of-line blocking on the batch's
``max(max_new_tokens)``, and finished requests never burn decode FLOPs.
Admission is under a page budget (``num_pages``); a pending request that
does not fit increments ``stats['blocked_admissions']`` (the ``ep_a2a``
overflow-accounting idiom) and waits, preserving FIFO order.

``kv_dtype='int8'`` stores the pool quantized via ``serve/kv_quant``'s
symmetric per-(position, head) scheme — quantize at append, attend against
int8 with f32 accumulation — roughly halving KV bytes per token.

Sampling: ``greedy=True`` argmaxes; ``greedy=False`` temperature-samples
with a per-step split of the engine's PRNG key, so a fixed ``seed`` makes a
run deterministic.

Grouped-GEMM backend selection is context-scoped (DESIGN: mixed fleets share
one config while each host/engine picks its fastest available backend):

* the engine resolves its default backend **once, at construction** — via
  ``repro.core.gmm_backend.resolve`` with the engine's ``gmm_backend``
  argument at the call-site slot and ``cfg.gmm_backend`` at the config slot —
  and holds the ``ResolvedBackend``.  Mutating ``REPRO_GMM_BACKEND``
  afterwards cannot retarget a constructed engine, and two engines in one
  process can run different backends over the same config;
* each ``Request`` may carry its own ``gmm_backend`` override, validated at
  enqueue time (an unknown name raises immediately, never mid-generate);
* ``generate`` resolves per batch slot and groups slots by resolved backend,
  so one batch can mix requests pinned to different backends.

Decode/prefill steps are jitted per backend name (separate function objects
keep the jit caches apart) with the concrete name baked into the config, and
every call runs inside ``use_backend`` so an ambient scope at first-trace
time cannot leak into the cached computation.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checkpoint as CK
from repro.core import gmm_backend as GB
from repro.models import transformer as T
from repro.serve import paged_cache as PC


@dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = 2
    gmm_backend: str | None = None  # per-request override of the engine default
    out_tokens: list = field(default_factory=list)
    done: bool = False


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class ServeEngine:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 capacity: int = 512, page_size: int = 16,
                 num_pages: int | None = None, kv_dtype: str | None = None,
                 greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0, gmm_backend: str | None = None,
                 remat_policy=None, mesh=None):
        # Snapshot the backend resolution at construction: precedence is the
        # explicit engine argument > active use_backend scope >
        # cfg.gmm_backend > env > auto, frozen into a ResolvedBackend.
        self.backend = GB.resolve(gmm_backend, config=cfg.gmm_backend)
        # Same discipline for the checkpoint plan: the engine argument
        # (name/spec/plan) wins over cfg.remat_policy; an unparseable spec
        # raises HERE, never mid-generate.  Decode never runs a backward, so
        # the plan is provenance + config hygiene — the canonical spec is
        # baked into the engine's cfg and surfaced as ``remat_plan``.
        self.remat_plan = CK.resolve_plan(remat_policy,
                                          config=cfg.remat_policy)
        self.cfg = cfg.replace(gmm_backend=self.backend.name,
                               remat_policy=self.remat_plan.spec)
        if not T.paged_supported(cfg):
            raise ValueError(
                f"ServeEngine pages attention KV; {cfg.name} has "
                f"block pattern {cfg.block_pattern} (SSM carries are O(1) "
                f"per-slot state — serve those via T.decode_step directly)")
        if kv_dtype not in (None, "model", "int8"):
            raise ValueError(f"kv_dtype must be None|'model'|'int8', "
                             f"got {kv_dtype!r}")
        if not greedy and temperature <= 0:
            raise ValueError("temperature must be > 0 for sampling")
        if cfg.is_moe:
            # Eagerly validate the plan's moe-scoped residual decisions
            # (coupled-FFN_A/B or save-Y_swi-under-recompute-A/B raise).
            CK.moe_residual_mode(self.cfg)
        # Validate the MoE distribution mode for this (cfg, mesh) pairing at
        # construction — decode steps run it via shard_map when a mesh is
        # given, and a bad pairing must not surface mid-generate.  The token
        # exchanges are degenerate for decode (single-token slabs rarely
        # divide the expert axes, and there is nothing to exchange at S=1),
        # so an explicit ep_a2a / ep_a2a_hier falls back to plain EP:
        # numerically identical, same expert-sharded weight layout.  'auto'
        # stays 'auto' — the cost model resolves it per decode slab, and its
        # live-bytes tie-break lands on EP for decode-sized token counts.
        if cfg.is_moe:
            from repro.models.moe_block import resolve_moe_parallel
            if self.cfg.moe_parallel in ("ep_a2a", "ep_a2a_hier"):
                self.cfg = self.cfg.replace(moe_parallel="ep")
            resolve_moe_parallel(self.cfg, mesh)
        self.mesh = mesh
        self.params = params
        self.slots = batch_slots
        self.capacity = capacity
        self.page_size = page_size
        self.quantized = kv_dtype == "int8"
        self.pages_per_seq = PC.pages_needed(capacity, page_size)
        # Default budget: full occupancy at max capacity, plus the trash page.
        self.num_pages = (num_pages if num_pages is not None
                          else 1 + batch_slots * self.pages_per_seq)
        if self.num_pages < 2:
            raise ValueError("num_pages must be >= 2 (one is the trash page)")
        self.greedy = greedy
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.pending: list[Request] = []
        self._decode_fns: dict[str, object] = {}
        self._prefill_fns: dict[tuple, object] = {}
        self.stats = {"prefill_calls": 0, "prefill_tokens": 0,
                      "decode_steps": 0, "decode_slot_tokens": 0,
                      "generated_tokens": 0, "blocked_admissions": 0,
                      "truncated_budgets": 0, "peak_pages_used": 0}

    # -- jitted entry points ------------------------------------------------

    def _decode_for(self, backend_name: str):
        """The jitted single-token decode step specialized to one backend —
        full slot array, per-request positions.  One function object per
        backend keeps their jit caches separate."""
        fn = self._decode_fns.get(backend_name)
        if fn is None:
            cfg = self.cfg.replace(gmm_backend=backend_name)
            fn = jax.jit(
                lambda p, c, tok, lens, pt: T.paged_decode_step(
                    p, c, tok, lens, pt, cfg, mesh=self.mesh),
                donate_argnums=(1,))   # cache updated in place
            self._decode_fns[backend_name] = fn
        return fn

    def _prefill_for(self, backend_name: str, bs: int, seq: int):
        """The jitted whole-prompt prefill for one (backend, batch-bucket,
        seq-bucket) — the SHARK per-batch-size entry-point family, with
        power-of-two bucketing keeping the family finite."""
        key = (backend_name, bs, seq)
        fn = self._prefill_fns.get(key)
        if fn is None:
            cfg = self.cfg.replace(gmm_backend=backend_name)
            fn = jax.jit(
                lambda p, c, tok, lens, pt: T.prefill(
                    p, tok, lens, c, pt, cfg, mesh=self.mesh),
                donate_argnums=(1,))
            self._prefill_fns[key] = fn
        return fn

    # -- validation ---------------------------------------------------------

    def resolve_request(self, request: Request) -> GB.ResolvedBackend:
        """The backend a request will decode with: its own override at the
        call-site slot, falling back to the engine's construction-time
        snapshot.  Raises on unknown/unavailable names."""
        if request.gmm_backend in (None, "", "auto"):
            return self.backend
        return GB.resolve(request.gmm_backend, config=self.cfg.gmm_backend)

    def _limit(self, request: Request) -> int:
        """Effective new-token budget: the cache holds ``prompt + (T - 1)``
        written tokens for T generated, bounded by ``capacity``."""
        return min(request.max_new_tokens,
                   self.capacity - request.prompt.size + 1)

    def _validate(self, request: Request) -> None:
        self.resolve_request(request)
        if request.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {request.max_new_tokens} "
                f"(prefill always samples one token)")
        if request.prompt.size > self.capacity:
            raise ValueError(
                f"prompt of {request.prompt.size} tokens exceeds engine "
                f"capacity {self.capacity}")
        need = PC.pages_needed(
            request.prompt.size + self._limit(request) - 1, self.page_size)
        if need > self.num_pages - 1:
            raise ValueError(
                f"request needs {need} pages but the pool only has "
                f"{self.num_pages - 1} allocatable pages")

    # -- queue API ----------------------------------------------------------

    def enqueue(self, request: Request) -> Request:
        """Admit a request to the pending queue.  Backend + budget
        validation happens HERE — an unknown ``gmm_backend`` or an
        impossible-to-schedule request raises at enqueue, never mid-generate
        with other requests' tokens in flight."""
        self._validate(request)
        self.pending.append(request)
        return request

    def run(self) -> list[Request]:
        """Drain the pending queue.  The scheduler batches continuously, so
        the whole queue goes in at once — slots refill as requests finish."""
        batch = self.pending
        self.pending = []
        return self.generate(batch)

    # -- batched generation -------------------------------------------------

    def generate(self, requests: list[Request]) -> list[Request]:
        # Validate every request up front (raises before any decode work),
        # then group by resolved backend — one batch may mix overrides.
        for r in requests:
            self._validate(r)
        resolved = [self.resolve_request(r) for r in requests]
        groups: dict[str, list[int]] = {}
        for i, rb in enumerate(resolved):
            groups.setdefault(rb.name, []).append(i)
        for name, idxs in groups.items():
            self._serve_group([requests[i] for i in idxs], name)
        return requests

    def _sample(self, logits) -> np.ndarray:
        """Next token per row.  Greedy argmaxes; otherwise temperature
        sampling with a fresh per-step split of the engine key (fixed seed
        => deterministic run)."""
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        self.key, k = jax.random.split(self.key)
        nxt = jax.random.categorical(k, logits / self.temperature, axis=-1)
        return np.asarray(nxt).astype(np.int32)

    def _serve_group(self, requests: list[Request], backend_name: str):
        """Continuously serve one group of requests sharing a backend."""
        ps = self.page_size
        pps = self.pages_per_seq
        pool = PC.PagePool(self.num_pages)
        waiting = deque(requests)
        free_slots = list(range(self.slots - 1, -1, -1))
        owner: list[Request | None] = [None] * self.slots
        pages_of: list[list[int] | None] = [None] * self.slots
        page_table = np.full((self.slots, pps), PC.TRASH_PAGE, np.int32)
        lengths = np.zeros(self.slots, np.int32)     # tokens in cache
        last_tok = np.zeros((self.slots, 1), np.int32)
        cache = T.init_paged_cache(self.cfg, self.num_pages, ps,
                                   quantized=self.quantized)
        decode = self._decode_for(backend_name)

        def finish(slot: int):
            pool.free(pages_of[slot])
            owner[slot] = None
            pages_of[slot] = None
            page_table[slot, :] = PC.TRASH_PAGE   # stale entries must not
            lengths[slot] = 0                     # alias freshly reused pages
            last_tok[slot, 0] = 0
            free_slots.append(slot)

        # The use_backend scope pins trace-time resolution to this group's
        # backend even if the caller holds an ambient scope of their own.
        with GB.use_backend(backend_name):
            while waiting or any(o is not None for o in owner):
                # -- admit from pending the moment slots + pages allow ------
                admit: list[int] = []
                while waiting and free_slots:
                    r = waiting[0]
                    need = PC.pages_needed(
                        r.prompt.size + self._limit(r) - 1, ps)
                    if need > pool.free_pages:
                        # FIFO under the page budget: the head waits (and is
                        # accounted), later requests do not jump it.
                        self.stats["blocked_admissions"] += 1
                        break
                    waiting.popleft()
                    if self._limit(r) < r.max_new_tokens:
                        # Capacity silently bounds the budget; surface it.
                        self.stats["truncated_budgets"] += 1
                    slot = free_slots.pop()
                    pgs = pool.alloc(need)
                    owner[slot] = r
                    pages_of[slot] = pgs
                    page_table[slot, :] = PC.TRASH_PAGE
                    page_table[slot, :need] = pgs
                    admit.append(slot)

                # -- prefill the newly admitted batch in ONE jitted call ----
                if admit:
                    # Clamp the pow2 seq bucket to the page table's logical
                    # width: a wider bucket would make write_prefill's pad
                    # tail spill past the table (routed to the trash page,
                    # but the clamp keeps the prefill shape honest and the
                    # jit-cache family within the table).
                    sb = min(_pow2(max(owner[s].prompt.size for s in admit)),
                             pps * ps)
                    bb = _pow2(len(admit))
                    toks = np.zeros((bb, sb), np.int32)
                    lens = np.zeros(bb, np.int32)
                    pt = np.full((bb, pps), PC.TRASH_PAGE, np.int32)
                    for i, s in enumerate(admit):
                        p = owner[s].prompt
                        toks[i, :p.size] = p
                        lens[i] = p.size
                        pt[i] = page_table[s]
                    pf = self._prefill_for(backend_name, bb, sb)
                    logits, cache = pf(self.params, cache, jnp.asarray(toks),
                                       jnp.asarray(lens), jnp.asarray(pt))
                    self.stats["prefill_calls"] += 1
                    self.stats["prefill_tokens"] += int(lens.sum())
                    nxt = self._sample(logits)
                    for i, s in enumerate(admit):
                        r = owner[s]
                        tok = int(nxt[i])
                        r.out_tokens.append(tok)
                        self.stats["generated_tokens"] += 1
                        lengths[s] = r.prompt.size
                        last_tok[s, 0] = tok
                        if tok == r.eos_id:
                            r.done = True
                        if r.done or len(r.out_tokens) >= self._limit(r):
                            finish(s)

                active = [s for s in range(self.slots)
                          if owner[s] is not None]
                if not active:
                    continue

                # -- one decode step over the full slot array ---------------
                # Inactive slots write through the trash page and their
                # logits rows are ignored — no per-shape re-jit as occupancy
                # changes.
                logits, cache = decode(self.params, cache,
                                       jnp.asarray(last_tok),
                                       jnp.asarray(lengths),
                                       jnp.asarray(page_table))
                self.stats["decode_steps"] += 1
                self.stats["decode_slot_tokens"] += len(active)
                nxt = self._sample(logits)
                for s in active:
                    r = owner[s]
                    tok = int(nxt[s])
                    r.out_tokens.append(tok)
                    self.stats["generated_tokens"] += 1
                    lengths[s] += 1
                    last_tok[s, 0] = tok
                    if tok == r.eos_id:
                        r.done = True
                    if r.done or len(r.out_tokens) >= self._limit(r):
                        finish(s)

        self.stats["peak_pages_used"] = max(
            self.stats["peak_pages_used"],
            self.num_pages - 1 - pool.min_free)

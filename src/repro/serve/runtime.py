"""Asynchronous serving runtime: the engine's scheduler stages, pipelined.

``ServeEngine._serve_group`` chains three stages inline — admission
(validation, prefix lookup, page allocation), device (jitted
prefill/decode dispatch), and sampling/emission (the only host sync) —
serialized, so the device sits idle during every host round-trip.  This
module runs the SAME ``_GroupScheduler`` stages on three pipelined threads
connected by bounded :class:`WorkQueue`s:

* **admission thread** — pops submitted requests, stages each prompt into a
  bounded :class:`TransferBufferPool` buffer (the pool is the backpressure:
  when every buffer is in flight, admission waits rather than queueing
  unbounded host copies), and hands the request to the device thread;
* **device thread** — owns the scheduler state (slots, page tables, pool,
  prefix trie) and the device-resident ``last_tok`` array; admits staged
  requests, dispatches prefill for new arrivals OVERLAPPED with in-flight
  decode, and pushes each step's device token array to the emission queue
  WITHOUT waiting on it (the bounded queue is the device-side
  backpressure);
* **emission thread** — syncs the token ids to host (``np.asarray``, the
  pipeline's only blocking transfer), appends/streams them (``on_token``),
  decides EOS/budget finishes, and posts finished slots back to the device
  thread for release.

The device thread may run AHEAD of finish notifications: a slot whose
request finished two queue entries ago still decodes until its release
arrives.  That run-ahead is harmless by construction — the scheduler
freezes a slot once it has written its last reserved position (writes
route to the trash page), emission drops tokens for finished requests, and
sampling keys are per-``(request id, token index)`` so tokens never depend
on scheduling.  Those three properties make the pipelined runtime
TOKEN-IDENTICAL to the synchronous engine under a fixed seed — asserted by
``tests/test_runtime.py`` and the ``serving/pipeline`` bench gate.

Terminal events: every request ends with exactly one ``on_finish(reason)``
— ``"eos"``, ``"length"``, or ``"error"`` (a crashed pipeline finishes
every in-flight request with ``"error"`` before re-raising from ``run`` /
``close``).  :meth:`AsyncServeRuntime.stream` wraps the callbacks in an
iterator: it yields token ids as they emit and raises ``StopIteration``
carrying the finish reason.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.core import gmm_backend as GB
from repro.serve.engine import Request, ServeEngine, _GroupScheduler

_SENTINEL = object()


class WorkQueue:
    """A bounded FIFO between pipeline stages, instrumented: depth high-water
    mark and producer blocking are visible in ``stats`` so a starved stage
    can be diagnosed from counters rather than profiles."""

    def __init__(self, name: str, maxsize: int = 0):
        self.name = name
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self._lock = threading.Lock()
        self.stats = {"puts": 0, "gets": 0, "max_depth": 0, "put_waits": 0}

    def put(self, item) -> None:
        if self._q.maxsize and self._q.full():
            with self._lock:
                self.stats["put_waits"] += 1
        self._q.put(item)
        with self._lock:
            self.stats["puts"] += 1
            self.stats["max_depth"] = max(self.stats["max_depth"],
                                          self._q.qsize())

    def get(self, timeout: float | None = None):
        """Pop one item; returns ``None`` on timeout (or immediately when
        ``timeout=None`` finds the queue empty)."""
        try:
            item = (self._q.get_nowait() if timeout is None
                    else self._q.get(timeout=timeout))
        except queue.Empty:
            return None
        with self._lock:
            self.stats["gets"] += 1
        return item


class TransferBuffer:
    """One reusable host staging buffer (stand-in for pinned H2D memory):
    a prompt is copied in on the admission thread and the buffer is held
    until the device thread has dispatched that request's prefill."""

    def __init__(self, capacity: int):
        self.arr = np.zeros(capacity, np.int32)
        self.used = 0

    def stage(self, prompt: np.ndarray) -> None:
        self.used = prompt.size
        self.arr[:self.used] = prompt


class TransferBufferPool:
    """A bounded pool of :class:`TransferBuffer`s.  ``acquire`` blocks when
    every buffer is in flight — this bound, not an unbounded queue, is what
    throttles admission when the device falls behind."""

    def __init__(self, n: int, capacity: int):
        self._free: queue.Queue = queue.Queue()
        for _ in range(n):
            self._free.put(TransferBuffer(capacity))
        self.size = n
        self.stats = {"acquires": 0, "acquire_waits": 0}

    def acquire(self) -> TransferBuffer:
        if self._free.empty():
            self.stats["acquire_waits"] += 1
        buf = self._free.get()
        self.stats["acquires"] += 1
        return buf

    def release(self, buf: TransferBuffer) -> None:
        self._free.put(buf)


class RequestHandle:
    """Caller-side view of a submitted request: iterate :meth:`stream` for
    live tokens, or block on :meth:`result` for the finished request."""

    def __init__(self, request: Request, runtime: "AsyncServeRuntime"):
        self.request = request
        self._runtime = runtime
        self._events: queue.Queue = queue.Queue()
        self._done = threading.Event()
        prev_tok, prev_fin = request.on_token, request.on_finish

        def on_token(tok: int) -> None:
            self._events.put(("token", tok))
            if prev_tok is not None:
                prev_tok(tok)

        def on_finish(reason: str) -> None:
            self._events.put(("finish", reason))
            self._done.set()
            if prev_fin is not None:
                prev_fin(reason)

        request.on_token = on_token
        request.on_finish = on_finish

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def finish_reason(self) -> str | None:
        return self.request.finish_reason

    @property
    def tokens(self) -> list:
        return list(self.request.out_tokens)

    def stream(self, timeout: float = 60.0) -> Iterator[int]:
        """Yield token ids as the emission stage produces them; the
        generator's ``StopIteration`` value is the finish reason.  Raises
        :class:`TimeoutError` (after surfacing any pipeline error) when no
        event arrives within ``timeout`` seconds — mirroring ``result()``
        rather than leaking ``queue.Empty``."""
        while True:
            try:
                kind, payload = self._events.get(timeout=timeout)
            except queue.Empty:
                self._runtime._check_error()
                raise TimeoutError(
                    f"no token or terminal event within {timeout}s") from None
            if kind == "finish":
                return payload
            yield payload

    def result(self, timeout: float | None = None) -> Request:
        if not self._done.wait(timeout):
            raise TimeoutError("request did not finish in time")
        self._runtime._check_error()
        return self.request


class AsyncServeRuntime:
    """Pipelined front-end over a :class:`ServeEngine`.

    One runtime owns one engine and serves the engine's default backend
    (per-request backend overrides would split the slot array across jit
    families mid-flight; use separate engines for mixed fleets).  Threads
    start lazily on first submit; ``close()`` (or the context manager)
    drains and joins them.
    """

    def __init__(self, engine: ServeEngine, *, queue_depth: int = 4,
                 transfer_buffers: int = 4):
        if queue_depth < 1 or transfer_buffers < 1:
            raise ValueError("queue_depth and transfer_buffers must be >= 1")
        self.engine = engine
        self.buffers = TransferBufferPool(transfer_buffers, engine.capacity)
        self.ingress_q = WorkQueue("ingress")                   # -> admission
        self.staged_q = WorkQueue("staged", maxsize=queue_depth)  # -> device
        self.emit_q = WorkQueue("emit", maxsize=queue_depth)    # -> emission
        self.finish_q = WorkQueue("finish")                     # -> device
        self._sched: _GroupScheduler | None = None
        self._threads: list[threading.Thread] = []
        self._wake = threading.Event()
        self._closed = False
        self._error: BaseException | None = None
        self._lock = threading.Lock()
        self._bufs: dict[int, TransferBuffer] = {}   # rid -> staged buffer

    # -- lifecycle ----------------------------------------------------------

    def _ensure_started(self) -> None:
        if self._threads:
            return
        self._sched = _GroupScheduler(self.engine, [],
                                      self.engine.backend.name)
        for name, fn in (("admission", self._admission_loop),
                         ("device", self._device_loop),
                         ("emission", self._emission_loop)):
            t = threading.Thread(target=fn, name=f"serve-{name}",
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError("serving pipeline failed") from self._error

    def close(self) -> None:
        """Drain in-flight requests, stop the pipeline, join the threads."""
        if self._closed:
            if self._threads:
                for t in self._threads:
                    t.join(timeout=60.0)
            self._check_error()
            return
        self._closed = True
        if self._threads:
            self.ingress_q.put(_SENTINEL)
            self._wake.set()
            for t in self._threads:
                t.join(timeout=60.0)
        if self._sched is not None and self.engine._pool is not None:
            self.engine.stats["peak_pages_used"] = max(
                self.engine.stats["peak_pages_used"],
                self.engine.num_pages - 1 - self.engine._pool.min_free)
        self._check_error()

    def __enter__(self) -> "AsyncServeRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- public API ---------------------------------------------------------

    def submit(self, request: Request) -> RequestHandle:
        """Validate (raises HERE, on the caller's thread) and hand the
        request to the pipeline; returns immediately with a handle."""
        if self._closed:
            raise RuntimeError("runtime is closed")
        self._check_error()
        resolved = self.engine.resolve_request(request)
        if resolved.name != self.engine.backend.name:
            raise ValueError(
                f"async runtime serves the engine backend "
                f"{self.engine.backend.name!r}; request asked for "
                f"{resolved.name!r} (use a separate engine)")
        self.engine._validate(request)
        handle = RequestHandle(request, self)
        self._ensure_started()
        self.ingress_q.put(request)
        return handle

    def stream(self, request: Request, timeout: float = 60.0):
        """Submit + iterate: yields token ids live, terminal event as the
        generator return value."""
        return self.submit(request).stream(timeout=timeout)

    def run(self, requests: list[Request]) -> list[Request]:
        """Submit a batch and block until every request reached a terminal
        event.  The runtime stays open for further submissions."""
        handles = [self.submit(r) for r in requests]
        for h in handles:
            h.result(timeout=600.0)
        return requests

    # -- pipeline threads ---------------------------------------------------

    def _admission_loop(self) -> None:
        try:
            while True:
                item = self.ingress_q.get(timeout=0.1)
                if item is _SENTINEL:
                    self.staged_q.put(_SENTINEL)
                    return
                if item is None:
                    if self._error is not None:
                        return
                    continue
                buf = self.buffers.acquire()     # backpressure lives here
                buf.stage(item.prompt)
                self._bufs[item.rid] = buf
                self.staged_q.put(item)
                self._wake.set()
        except BaseException as e:      # pragma: no cover - defensive
            self._fail(e)

    def _device_loop(self) -> None:
        sched = self._sched
        try:
            with GB.use_backend(sched.backend_name):
                closing = False
                while True:
                    progressed = False
                    while (s := self.finish_q.get()) is not None:
                        sched.release(s)
                        progressed = True
                    while (r := self.staged_q.get()) is not None:
                        if r is _SENTINEL:
                            closing = True
                        else:
                            sched.waiting.append(r)
                            progressed = True
                    admit = sched.try_admit()
                    if admit:
                        snap = [(s, sched.owner[s]) for s in admit]
                        ptoks = sched.dispatch_prefill(admit)
                        for s in admit:
                            buf = self._bufs.pop(sched.owner[s].rid, None)
                            if buf is not None:
                                self.buffers.release(buf)
                        self.emit_q.put(("prefill", snap, ptoks))
                        progressed = True
                    out = sched.dispatch_decode()
                    if out is not None:
                        toks, snap = out
                        self.emit_q.put(("decode", snap, toks))
                        progressed = True
                    if not progressed:
                        if closing and not sched.has_work():
                            self.emit_q.put(_SENTINEL)
                            return
                        self._wake.wait(0.002)
                        self._wake.clear()
        except BaseException as e:
            self._fail(e)
            self.emit_q.put(_SENTINEL)

    def _emission_loop(self) -> None:
        sched = self._sched
        try:
            while True:
                item = self.emit_q.get(timeout=0.1)
                if item is _SENTINEL:
                    return
                if item is None:
                    if self._error is not None:
                        return
                    continue
                kind, snap, dev_toks = item
                np_toks = np.asarray(dev_toks)   # the pipeline's only sync
                if kind == "prefill":
                    finished = sched.emit_prefill(snap, np_toks)
                else:
                    finished = sched.emit_decode(snap, np_toks)
                for s in finished:
                    self.finish_q.put(s)
                if finished:
                    self._wake.set()
        except BaseException as e:      # pragma: no cover - defensive
            self._fail(e)

    def _fail(self, exc: BaseException) -> None:
        """First failure wins: record it, terminate every non-finished
        request with an ``"error"`` event, and unblock the other stages."""
        with self._lock:
            if self._error is None:
                self._error = exc
        sched = self._sched
        seen = []
        if sched is not None:
            seen = sched.in_flight() + list(sched.waiting)
        while (r := self.ingress_q.get()) is not None:
            if r is not _SENTINEL:
                seen.append(r)
        while (r := self.staged_q.get()) is not None:
            if r is not _SENTINEL:
                seen.append(r)
        from repro.serve.engine import _finish_request
        for r in seen:
            if isinstance(r, Request) and not r.done:
                _finish_request(r, "error")
        self._wake.set()

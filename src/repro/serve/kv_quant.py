"""Int8 KV-cache quantization (beyond-paper serving optimization).

The paper's memory wall for training is activation buffers; for *decode* the
wall is the KV cache (e.g. deepseek-33b x decode_32k: 4.2 GiB/device — the
largest single input of any pair in the dry-run).  Symmetric per-(position,
head) int8 quantization cuts it ~2x vs bf16 with <1e-2 relative attention
error (tested), at the cost of one rescale per read — decode attention is
bandwidth-bound, so halving cache bytes is worth far more than the extra
multiply.

Layout: values int8 (B, C, H, D) + scales f16 (B, C, H, 1); the scale is the
per-vector absmax / 127.  Quantization happens once at append time; the
dequantized tile is transient in the attention einsum.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantizedKVCache(NamedTuple):
    k_q: jax.Array        # int8 (B, C, Hkv, Dh)
    k_scale: jax.Array    # f16  (B, C, Hkv, 1)
    v_q: jax.Array        # int8 (B, C, Hkv, Dh)
    v_scale: jax.Array    # f16  (B, C, Hkv, 1)
    slot_pos: jax.Array   # int32 (B, C) — per-request, so batched requests
    # can sit at different positions (mixed-prompt-length serving)


def quantize(x: jax.Array):
    """Symmetric int8 over the last axis.  x: (..., D)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def init_quant_cache(batch: int, capacity: int, n_kv: int,
                     head_dim: int) -> QuantizedKVCache:
    return QuantizedKVCache(
        k_q=jnp.zeros((batch, capacity, n_kv, head_dim), jnp.int8),
        k_scale=jnp.zeros((batch, capacity, n_kv, 1), jnp.float16),
        v_q=jnp.zeros((batch, capacity, n_kv, head_dim), jnp.int8),
        v_scale=jnp.zeros((batch, capacity, n_kv, 1), jnp.float16),
        slot_pos=jnp.full((batch, capacity), -1, jnp.int32),
    )


def append(cache: QuantizedKVCache, k: jax.Array, v: jax.Array,
           pos: jax.Array) -> QuantizedKVCache:
    """Append one token's k/v (B, Hkv, Dh) at absolute position ``pos`` —
    scalar (whole batch in lockstep) or (B,) per-request positions —
    rolling over capacity."""
    B, C = cache.slot_pos.shape
    pos = jnp.broadcast_to(jnp.asarray(pos).reshape(-1), (B,))
    slot = (pos % C).astype(jnp.int32)
    bidx = jnp.arange(B)
    kq, ks = quantize(k)
    vq, vs = quantize(v)
    return QuantizedKVCache(
        k_q=cache.k_q.at[bidx, slot].set(kq),
        k_scale=cache.k_scale.at[bidx, slot].set(ks),
        v_q=cache.v_q.at[bidx, slot].set(vq),
        v_scale=cache.v_scale.at[bidx, slot].set(vs),
        slot_pos=cache.slot_pos.at[bidx, slot].set(pos.astype(jnp.int32)),
    )


def decode_attention_quant(q: jax.Array, cache: QuantizedKVCache,
                           pos: jax.Array, *, window: int = 0,
                           cap: float = 0.0) -> jax.Array:
    """One-token attention against the int8 cache.

    q: (B, 1, Hq, Dh).  Scores are computed as (q·k_q)·k_scale — the int8
    matmul accumulates in f32 and the per-vector scale is applied to the
    score, so no dequantized (B, C, H, D) f32 copy of the cache is ever
    materialized.
    """
    B, _, Hq, Dh = q.shape
    _, C, Hkv, _ = cache.k_q.shape
    G = Hq // Hkv
    pos = jnp.broadcast_to(jnp.asarray(pos).reshape(-1), (B,))
    qf = (q.reshape(B, Hkv, G, Dh) * Dh ** -0.5).astype(jnp.float32)
    s = jnp.einsum("bhgd,bchd->bhgc", qf,
                   cache.k_q.astype(jnp.float32))
    s = s * cache.k_scale[..., 0].astype(jnp.float32).transpose(0, 2, 1)[
        :, :, None, :]
    if cap:
        s = cap * jnp.tanh(s / cap)
    valid = (cache.slot_pos >= 0) & (cache.slot_pos <= pos[:, None])
    if window:
        valid &= cache.slot_pos > pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    pv = p * cache.v_scale[..., 0].astype(jnp.float32).transpose(0, 2, 1)[
        :, :, None, :]
    out = jnp.einsum("bhgc,bchd->bhgd", pv,
                     cache.v_q.astype(jnp.float32))
    return out.reshape(B, 1, Hq, Dh).astype(q.dtype)


def cache_bytes(cache) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cache))

"""Mixtral-8x7B: 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=0, vocab_size=32000,
    num_experts=8, top_k=2, moe_d_ff=14336,
    sliding_window=4096, ffn_act="swiglu", rope_theta=1_000_000.0,
    block_pattern=("attn_local_moe",),
    citation="arXiv:2401.04088",
)

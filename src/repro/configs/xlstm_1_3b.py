"""xLSTM-1.3B: sLSTM + mLSTM blocks (one sLSTM per 8 layers)
[arXiv:2405.04517]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", arch_type="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    head_dim=512, d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    slstm_every=8,
    citation="arXiv:2405.04517",
)

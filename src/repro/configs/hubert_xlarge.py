"""HuBERT-XLarge: encoder-only audio transformer (w2v2 architecture);
the mel/conv frontend is a stub — ``input_specs`` provides frame embeddings
[arXiv:2106.07447]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", arch_type="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    head_dim=80, d_ff=5120, vocab_size=504,
    ffn_act="gelu", causal=False, input_kind="frames",
    block_pattern=("attn_ffn",),
    citation="arXiv:2106.07447",
)

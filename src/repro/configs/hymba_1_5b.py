"""Hymba-1.5B: hybrid heads — attention and Mamba heads run in parallel on
the same input and are mean-fused; SWA on attention heads; ssm_state=16
[arXiv:2411.13676].

Deviation (DESIGN.md §7): Hymba keeps 3 full-attention layers (first, middle,
last); we use sliding-window attention uniformly so the layer stack stays
scan-homogeneous and the arch is long_500k-capable end to end.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", arch_type="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    ffn_act="swiglu", sliding_window=1024,
    ssm_state=16, ssm_heads=25,
    block_pattern=("hymba",),
    # adopted from EXPERIMENTS.md §Perf P3: 128-token KV chunks cut the
    # masked-window attention waste (-20% memory term vs the 512 default;
    # 64 gave a further -2.7% -> converged, 128 kept for MXU alignment)
    attn_chunk=128,
    citation="arXiv:2411.13676",
)

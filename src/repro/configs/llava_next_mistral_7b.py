"""LLaVA-NeXT (Mistral-7B backbone): dense SwiGLU GQA decoder consuming
anyres-tiled patch embeddings from a stubbed vision tower + projector
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

Anyres tiling: a base 24x24=576-patch view plus up to four 576-patch tiles ->
2880 image-token slots, reflected in ``num_image_tokens``.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", arch_type="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    ffn_act="swiglu", rope_theta=1_000_000.0,
    input_kind="mixed", num_image_tokens=2880,
    block_pattern=("attn_ffn",),
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

"""Config registry: ``get_config(arch_id)`` for the 10 assigned architectures
plus the paper's Table-1 configs (``paper_conf1`` … ``paper_conf7``)."""

from __future__ import annotations

import importlib

from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                TrainConfig)
from repro.configs.paper_tables import PAPER_CONFS

ARCH_IDS = [
    "yi_6b", "qwen3_moe_30b_a3b", "xlstm_1_3b", "deepseek_coder_33b",
    "gemma2_27b", "mixtral_8x7b", "hubert_xlarge",
    "llava_next_mistral_7b", "hymba_1_5b", "qwen3_14b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "yi-6b": "yi_6b", "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "xlstm-1.3b": "xlstm_1_3b", "deepseek-coder-33b": "deepseek_coder_33b",
    "gemma2-27b": "gemma2_27b", "mixtral-8x7b": "mixtral_8x7b",
    "hubert-xlarge": "hubert_xlarge",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "hymba-1.5b": "hymba_1_5b", "qwen3-14b": "qwen3_14b",
})


def get_config(arch_id: str) -> ModelConfig:
    key = _ALIASES.get(arch_id, arch_id)
    if key.startswith("paper_conf"):
        return PAPER_CONFS[key]
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


__all__ = ["get_config", "ARCH_IDS", "ModelConfig", "TrainConfig",
           "InputShape", "INPUT_SHAPES", "PAPER_CONFS"]

"""Config system: model/arch configs, input shapes, and run settings."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  One instance per assigned architecture (see
    ``src/repro/configs/<id>.py``); ``reduced()`` yields the CPU smoke-test
    variant of the same family."""

    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                    # 0 -> d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 1024
    ffn_act: str = "swiglu"              # swiglu | gelu | silu | relu

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                    # per-expert hidden dim
    moe_impl: str = "blaze"              # blaze | blaze_pallas | megablocks | dense
    moe_parallel: str = "auto"           # distribution mode: auto | ep |
    # ep_a2a | ep_a2a_hier | tp (README "Distribution modes"; auto ranks the
    # feasible modes with roofline.select_moe_parallel's collective cost
    # model per config x mesh and picks by predicted step time, breaking
    # near-ties toward lower per-device live bytes)
    moe_a2a_capacity: float = 2.0        # ep_a2a*: per-destination-rank slot
    # capacity factor relative to the uniform share L*k/n_ranks; slots beyond
    # it are dropped and accounted in the a2a_overflow stat
    moe_a2a_chunks: int = 1              # ep_a2a: split the exchange buffers
    # into this many double-buffered chunks so chunk i's all_to_all overlaps
    # chunk i-1's grouped GEMM; 1 = single exchange (no overlap)
    gmm_backend: str = "auto"            # grouped-GEMM backend: auto | ragged
    # | segment | pallas — the *config* slot of the resolution precedence
    # (call-site arg > use_backend scope > this > $REPRO_GMM_BACKEND > auto;
    # see repro.core.gmm_backend.resolve)
    save_yswi: bool = True               # DEPRECATED alias: the MoE VJP's
    # Y_swi residual when the checkpoint plan leaves it open.  An explicit
    # moe-scoped FFN_YSWI decision in `remat_policy` (e.g.
    # "moe:recompute=ffn_yswi") overrides this bool; see
    # repro.core.checkpoint.moe_residual_mode.
    aux_loss_weight: float = 0.01
    z_loss_weight: float = 1e-3

    # --- attention variants --------------------------------------------------
    sliding_window: int = 0              # 0 -> full attention
    local_global_period: int = 0         # gemma2: 2 -> alternate local/global
    attn_softcap: float = 0.0            # gemma2: 50.0
    final_softcap: float = 0.0           # gemma2: 30.0
    qk_norm: bool = False                # qwen3
    post_norms: bool = False             # gemma2 sandwich norms
    causal: bool = True                  # False for encoder-only (hubert)
    rope_theta: float = 10_000.0

    # --- SSM / hybrid --------------------------------------------------------
    block_pattern: tuple[str, ...] = ("attn_ffn",)  # scanned per-group pattern
    ssm_state: int = 0                   # mamba/hymba state size
    ssm_heads: int = 0                   # parallel SSM heads (hymba)
    mamba_dual: bool = False             # Mamba-2 chunked dual form (§Perf)
    slstm_every: int = 0                 # xlstm: one sLSTM per this many layers

    # --- modality frontends (stubs per the brief) ---------------------------
    input_kind: str = "tokens"           # tokens | frames (audio) | mixed (vlm)
    num_image_tokens: int = 0            # vlm: patch-embedding slots per sample

    # --- numerics / system ---------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # Activation-checkpoint plan: a registry name ("none" | "paper" |
    # "paper_min" | "full" | "dots") or a CheckpointPlan spec like
    # "save=ffn_a,ffn_b,qkv;moe:recompute=ffn_yswi" (see
    # repro.core.checkpoint and README "Activation checkpoint plans").
    # "none" = recompute the layer in backward (production default; the
    # paper's A/B/Y_swi residual policy is enforced *inside* the MoE layer's
    # custom VJP and applies during the remat replay).
    remat_policy: str = "none"
    scan_layers: bool = True
    attn_chunk: int = 512                # flash-attention KV chunk
    use_pallas: bool = False             # kernel path (single device only)
    block_causal_skip: bool = True       # skip fully-masked KV chunks (hillclimb)
    serve_replicate_weights: bool = False  # decode: replicate weights over
    # the data axes instead of FSDP-sharding them (no per-layer gathers)
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def checkpoint_plan(self):
        """The resolved :class:`repro.core.checkpoint.CheckpointPlan` behind
        ``remat_policy`` (name or spec)."""
        from repro.core.checkpoint import resolve_plan
        return resolve_plan(config=self.remat_policy).plan

    @property
    def resolved_save_yswi(self) -> bool:
        """Derived view of the plan's FFN_YSWI decision in the MoE scope
        (falls back to the deprecated ``save_yswi`` alias when the plan
        leaves it open)."""
        from repro.core.checkpoint import moe_residual_mode
        return moe_residual_mode(self) == "ab_yswi"

    @property
    def pattern_period(self) -> int:
        if self.slstm_every:
            return self.slstm_every
        if self.local_global_period:
            return self.local_global_period
        return 1

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.pattern_period == 0
        return self.num_layers // self.pattern_period

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 groups, d_model<=512, <=4 experts."""
        period = self.pattern_period
        kw = dict(
            num_layers=2 * period if period > 1 else 2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_chunk=64,
            dtype="float32",
        )
        if self.is_moe:
            kw.update(num_experts=4, top_k=min(self.top_k, 2), moe_d_ff=128)
        if self.ssm_heads:
            kw.update(ssm_heads=2)
        if self.num_image_tokens:
            kw.update(num_image_tokens=16)
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    batch_size: int = 8
    seq_len: int = 256
    num_microbatches: int = 1            # gradient accumulation
    gmm_backend: str = "auto"            # grouped-GEMM backend for the train
    # step; "auto" defers to the model config then the precedence chain
    # (see repro.core.gmm_backend.resolve)
    seed: int = 0
    checkpoint_every: int = 0            # 0 -> disabled
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10

"""The paper's Table-1 MoE configurations (conf1..conf7), used by the
benchmark harness to reproduce Figures 3-6.  ffn_hidden = 4 x input_d.

Fields: (input_d, experts, top_k, batch, seq_len)."""

from repro.configs.base import ModelConfig

_TABLE1 = {
    "paper_conf1": (512, 4, 1, 32, 2048),
    "paper_conf2": (1024, 8, 2, 32, 2048),
    "paper_conf3": (1024, 16, 4, 32, 2048),
    "paper_conf4": (2048, 16, 4, 32, 1024),
    "paper_conf5": (512, 16, 4, 32, 1024),
    "paper_conf6": (1024, 16, 4, 16, 1024),
    "paper_conf7": (2048, 8, 4, 16, 512),
}


def _mk(name, d, e, k, b, s):
    return ModelConfig(
        name=name, arch_type="moe", num_layers=1,
        d_model=d, num_heads=max(d // 128, 1), num_kv_heads=max(d // 128, 1),
        d_ff=0, vocab_size=32000,
        num_experts=e, top_k=k, moe_d_ff=4 * d,
        ffn_act="swiglu",
        block_pattern=("attn_moe",), dtype="float32",
    )


PAPER_CONFS = {n: _mk(n, *v) for n, v in _TABLE1.items()}
PAPER_TABLE1 = _TABLE1

"""Qwen3-30B-A3B: 128-expert top-8 MoE with GQA + qk-norm
[hf:Qwen/Qwen3-30B-A3B]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", arch_type="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=0, vocab_size=151936,
    num_experts=128, top_k=8, moe_d_ff=768,
    qk_norm=True, ffn_act="swiglu", rope_theta=1_000_000.0,
    block_pattern=("attn_moe",),
    citation="hf:Qwen/Qwen3-30B-A3B",
)

"""Gemma2-27B: alternating local(4096)/global attention, logit softcaps,
sandwich norms [arXiv:2408.00118]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", arch_type="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
    head_dim=128, d_ff=36864, vocab_size=256000,
    ffn_act="swiglu",
    sliding_window=4096, local_global_period=2,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    block_pattern=("attn_local_ffn", "attn_ffn"),
    citation="arXiv:2408.00118",
)

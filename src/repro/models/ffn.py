"""Dense FFN sublayers.  The SwiGLU variant applies the paper's fusion +
checkpoint policy (save A/B, recompute SiLU) — via the Pallas fused kernel
when ``cfg.use_pallas`` (single device), else via checkpoint-tagged XLA ops
that the named remat policy treats identically."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.checkpoint import FFN_A, FFN_B, FFN_YSWI, tag
from repro.models.common import dense_init


def init_ffn_params(key, cfg, d: int, d_ff: int) -> dict:
    ks = jax.random.split(key, 3)
    pd = jnp.dtype(cfg.param_dtype)
    p = {"w1": dense_init(ks[0], (d, d_ff), 0, pd),
         "w3": dense_init(ks[2], (d_ff, d), 0, pd)}
    if cfg.ffn_act == "swiglu":
        p["w2"] = dense_init(ks[1], (d, d_ff), 0, pd)
    return p


def ffn_sublayer(x: jax.Array, p: dict, cfg) -> jax.Array:
    B, S, d = x.shape
    dt = x.dtype
    xf = x.reshape(B * S, d)
    if cfg.ffn_act == "swiglu":
        if cfg.use_pallas:
            from repro.kernels.ops import swiglu as swiglu_fused
            y = swiglu_fused(xf, p["w1"].astype(dt), p["w2"].astype(dt))
        else:
            a = tag(xf @ p["w1"].astype(dt), FFN_A)
            b = tag(xf @ p["w2"].astype(dt), FFN_B)
            y = tag(jax.nn.silu(a) * b, FFN_YSWI)
    else:
        a = tag(xf @ p["w1"].astype(dt), FFN_A)
        act = {"gelu": jax.nn.gelu, "relu": jax.nn.relu,
               "silu": jax.nn.silu}[cfg.ffn_act]
        y = act(a)
    return (y @ p["w3"].astype(dt)).reshape(B, S, d)

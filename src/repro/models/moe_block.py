"""MoE transformer sublayer: router + MoEBlaze expert FFN, with the
distributed (beyond-paper) integration.

Distribution (DESIGN.md §5): tokens stay sharded on the data axes; every
expert's FFN hidden dimension ``h`` is tensor-sharded over ``model``.  Inside
the ``shard_map`` body each device runs the *unmodified single-device
MoEBlaze algorithm* — local gating, sort-free dispatch build, gather-GMM
experts, gather-of-partials combine — on its local tokens and its ``h``-shard
of every expert, followed by a single ``psum`` over ``model``.  This keeps the
paper's dropless, never-materialized dispatch intact per device, adds exactly
one collective per MoE layer, and needs no ragged all-to-all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import gmm_backend as GB
from repro.core import routing
from repro.core.baseline import moe_ffn_dense, moe_ffn_megablocks
from repro.core.checkpoint import MOE_GATES, tag
from repro.core.moe_layer import moe_ffn_blaze
from repro.models.common import dense_init


def init_moe_params(key, cfg, d: int) -> dict:
    E, h = cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wg": dense_init(ks[0], (d, E), 0, pd),
        "w1": dense_init(ks[1], (E, d, h), 1, pd),
        "w3": dense_init(ks[3], (E, h, d), 1, pd),
    }
    if cfg.ffn_act == "swiglu":
        p["w2"] = dense_init(ks[2], (E, d, h), 1, pd)
    return p


def _moe_local(xf: jax.Array, p: dict, cfg):
    """Single-device MoEBlaze path on a (L, d) token slab."""
    E, k = cfg.num_experts, cfg.top_k
    g = routing.top_k_gating(xf, p["wg"].astype(xf.dtype), k)
    if cfg.moe_impl == "proxy_gmm":
        # COST-MODEL STAND-IN, dry-run probes only (never executed): XLA's
        # CPU decomposition of ragged_dot is dense-per-group (E x FLOPs /
        # temps), which misrepresents the TPU gmm lowering.  This proxy has
        # the gmm's exact useful FLOPs (L·k rows through d->h->d) and reads
        # the full expert weight bank once (the .sum(0) reductions), but is
        # NOT numerically the MoE.  See EXPERIMENTS.md §Roofline.
        disp = routing.build_dispatch(g.topk_experts, E)   # keep build cost
        gates = g.topk_weights.astype(xf.dtype)
        xg = jnp.take(xf, disp.expert_token_indices, axis=0)
        w1e = p["w1"].sum(0).astype(xf.dtype)
        w3e = p["w3"].sum(0).astype(xf.dtype)
        a = xg @ w1e
        if "w2" in p:
            y_act = jax.nn.silu(a) * (xg @ p["w2"].sum(0).astype(xf.dtype))
        else:
            y_act = jax.nn.silu(a)
        p_out = y_act @ w3e
        L = xf.shape[0]
        parts = jnp.take(p_out, disp.token_index_map.reshape(-1),
                         axis=0).reshape(L, k, -1)
        y = jnp.einsum("lk,lkd->ld", gates, parts)
        aux = (cfg.aux_loss_weight *
               routing.load_balance_loss(g.router_probs, g.topk_experts, E)
               + cfg.z_loss_weight * routing.router_z_loss(g.logits))
        return y, aux
    if cfg.moe_impl == "dense":
        y = moe_ffn_dense(xf, g.router_probs, g.topk_experts,
                          g.topk_weights.astype(xf.dtype),
                          p["w1"], p["w3"], p.get("w2"),
                          activation=cfg.ffn_act)
    else:
        if cfg.moe_impl == "blaze_pallas":
            from repro.kernels.dispatch import build_dispatch_pallas
            disp = build_dispatch_pallas(g.topk_experts, E)
        else:
            disp = routing.build_dispatch(g.topk_experts, E)
        gates = tag(g.topk_weights.astype(xf.dtype), MOE_GATES)
        # cfg.gmm_backend enters the precedence chain at the *config* slot:
        # an explicit call-site choice or an active use_backend() scope wins,
        # env/auto fill in when the config says "auto".
        rb = GB.resolve(None, config=cfg.gmm_backend)
        if cfg.moe_impl == "megablocks":
            y = moe_ffn_megablocks(xf, gates, disp, p["w1"], p["w3"],
                                   p.get("w2"), activation=cfg.ffn_act,
                                   backend=rb)
        elif cfg.moe_impl == "blaze_pallas":
            from repro.kernels.ops import moe_ffn_blaze_pallas
            y = moe_ffn_blaze_pallas(xf, gates, disp, p["w1"], p["w3"],
                                     p["w2"], backend=rb)
        else:
            y = moe_ffn_blaze(xf, gates, disp, p["w1"], p["w3"], p.get("w2"),
                              activation=cfg.ffn_act,
                              save_yswi=cfg.save_yswi,
                              backend=rb)
    aux = (cfg.aux_loss_weight *
           routing.load_balance_loss(g.router_probs, g.topk_experts, E)
           + cfg.z_loss_weight * routing.router_z_loss(g.logits))
    return y, aux


def _aux_of(g, cfg):
    return (cfg.aux_loss_weight *
            routing.load_balance_loss(g.router_probs, g.topk_experts,
                                      cfg.num_experts)
            + cfg.z_loss_weight * routing.router_z_loss(g.logits))


def _moe_local_ep(xf: jax.Array, p: dict, cfg, n_model: int):
    """Expert-parallel shard body: this device owns ``E/n_model`` experts
    (weights arrive local via in_specs — no gather).  Each device computes
    its experts' contributions for all local tokens; ``psum`` over 'model'
    combines.  Implemented with the dense-dispatch formulation at the XLA
    level; on real TPU the Pallas gather-GMM (`kernels/gather_gmm.py`) plays
    this role with no dense waste (cost-modelled by 'proxy_gmm')."""
    E, k = cfg.num_experts, cfg.top_k
    E_loc = E // n_model
    L = xf.shape[0]
    g = routing.top_k_gating(xf, p["wg"].astype(xf.dtype), k)
    if cfg.moe_impl == "proxy_gmm":
        # gmm cost model under EP: ~L·k/n_model rows through one d->h->d,
        # plus one read of the local expert bank.  NOT numerically the MoE.
        rows = max(L * k // n_model, 1)
        xg = jnp.take(xf, jnp.arange(rows) % L, axis=0)
        a = xg @ p["w1"].sum(0).astype(xf.dtype)
        y_act = jax.nn.silu(a)
        if "w2" in p:
            y_act = y_act * (xg @ p["w2"].sum(0).astype(xf.dtype))
        p_out = y_act @ p["w3"].sum(0).astype(xf.dtype)
        y = jnp.zeros_like(xf).at[jnp.arange(rows) % L].add(p_out)
        gm = g.topk_weights.astype(xf.dtype).mean()
        return y * gm, _aux_of(g, cfg)
    # dense-dispatch on the local expert slice
    idx = jax.lax.axis_index("model")
    cw = jnp.zeros((L, E), g.topk_weights.dtype)
    cw = cw.at[jnp.arange(L)[:, None], g.topk_experts].set(g.topk_weights)
    cw_loc = jax.lax.dynamic_slice_in_dim(cw, idx * E_loc, E_loc, axis=1)
    a = jnp.einsum("ld,edh->leh", xf, p["w1"].astype(xf.dtype))
    if cfg.ffn_act == "swiglu" and "w2" in p:
        from repro.core.moe_layer import _silu
        y_act = _silu(a) * jnp.einsum("ld,edh->leh", xf,
                                      p["w2"].astype(xf.dtype))
    else:
        from repro.core.moe_layer import _ACTS
        y_act = _ACTS.get(cfg.ffn_act, _ACTS["silu"])[0](a)
    p_out = jnp.einsum("leh,ehd->led", y_act, p["w3"].astype(xf.dtype))
    y = jnp.einsum("le,led->ld", cw_loc.astype(p_out.dtype), p_out)
    return y, _aux_of(g, cfg)


def moe_sublayer(x: jax.Array, p: dict, cfg, *, mesh=None,
                 dp_axes=("pod", "data")):
    """(B, S, d) -> ((B, S, d), aux_loss).

    Distribution modes (DESIGN.md §5):
      * EP — experts sharded over 'model' when ``E % model == 0`` (weights
        never gathered; one psum combines expert contributions);
      * TP — otherwise the expert hidden dim is tensor-sharded over 'model'
        and the unmodified single-device MoEBlaze algorithm runs per shard.
    """
    B, S, d = x.shape

    if mesh is None:
        y, aux = _moe_local(x.reshape(B * S, d), p, cfg)
        return y.reshape(B, S, d), aux

    n_model = mesh.shape.get("model", 1)
    if cfg.moe_parallel == "ep":
        ep = True
    elif cfg.moe_parallel == "tp":
        ep = False
    else:
        ep = (cfg.num_experts % max(n_model, 1) == 0
              and cfg.num_experts >= n_model and n_model > 1)
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    batch_axes = dp_axes if (B % max(n_dp, 1) == 0 and n_dp > 1) else ()
    x_spec = P(batch_axes if batch_axes else None, None, None)
    if ep:
        p_specs = {"wg": P(None, None), "w1": P("model", None, None),
                   "w2": P("model", None, None), "w3": P("model", None, None)}
    else:
        p_specs = {"wg": P(None, None), "w1": P(None, None, "model"),
                   "w2": P(None, None, "model"), "w3": P(None, "model", None)}
    p_specs = {k_: v for k_, v in p_specs.items() if k_ in p}
    all_axes = tuple(mesh.axis_names)

    def body(xl, pl_):
        Bl, Sl, _ = xl.shape
        xf = xl.reshape(Bl * Sl, d)
        if ep:
            y, aux = _moe_local_ep(xf, pl_, cfg, n_model)
        else:
            y, aux = _moe_local(xf, pl_, cfg)
        # The one collective the MoE layer adds: combine partials.
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(Bl, Sl, d), aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, p_specs),
        out_specs=(x_spec, P()),
        check=False,
    )(x, p)
    return y, aux

"""MoE transformer sublayer: router + MoEBlaze expert FFN, with padding-free
distributed execution.

One *Dispatch-driven* path (paper §4.1) serves every expert placement — the
compact index structures from ``core/routing.py`` are built once and either
used whole (single device, TP) or compacted to a device-local expert range
(``routing.slice_dispatch``), so the fused-SwiGLU ``custom_vjp``, the
paper's residual policy, the ``checkpoint.tag`` remat tags and the resolved
grouped-GEMM backend apply identically on one device and under a mesh.

Distribution modes (``cfg.moe_parallel``, README "Distribution modes"):

  * ``ep``     — experts sharded over 'model' (weights never gathered).  Each
    device slices the global Dispatch to its expert range and runs the SAME
    ``moe_ffn_blaze`` on its local tokens; one ``psum`` combines partials.
    Non-local slots rotate into the sliced structure's dead zone, where the
    grouped GEMM produces exact zeros — no capacity padding, no dense L×E.
  * ``ep_a2a`` — tokens sharded over 'model' as well: each device routes its
    L/n chunk, groups slots by destination rank with the same sort-free
    dispatch build, and exchanges capacity-bounded row buffers with
    ``jax.lax.all_to_all`` (counts first; overflow is accounted and surfaced
    as a stat, never silently padded).  The first genuinely distributed
    dispatch in the repo.
  * ``tp``     — every expert's hidden dim tensor-sharded over 'model'; the
    unmodified single-device algorithm runs per shard.
  * ``auto``   — ``ep`` when the expert count divides the model axis, else
    ``tp``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import gmm_backend as GB
from repro.core import routing
from repro.core.baseline import moe_ffn_dense, moe_ffn_megablocks
from repro.core.checkpoint import MOE_GATES, moe_residual_mode, tag
from repro.core.moe_layer import moe_ffn_blaze
from repro.models.common import dense_init

MOE_PARALLEL_MODES = ("auto", "ep", "ep_a2a", "tp")


def init_moe_params(key, cfg, d: int) -> dict:
    E, h = cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wg": dense_init(ks[0], (d, E), 0, pd),
        "w1": dense_init(ks[1], (E, d, h), 1, pd),
        "w3": dense_init(ks[3], (E, h, d), 1, pd),
    }
    if cfg.ffn_act == "swiglu":
        p["w2"] = dense_init(ks[2], (E, d, h), 1, pd)
    return p


def resolve_moe_parallel(cfg, mesh) -> str:
    """Concrete distribution mode for (cfg, mesh): ``single`` | ``tp`` |
    ``ep`` | ``ep_a2a``.

    Validates forced modes at entry: expert parallelism with
    ``E % n_model != 0`` would truncate ``E_loc = E // n_model`` and silently
    drop experts — raise a clear error instead of computing garbage.
    """
    if cfg.moe_parallel not in MOE_PARALLEL_MODES:
        raise ValueError(
            f"unknown moe_parallel {cfg.moe_parallel!r}; "
            f"known: {MOE_PARALLEL_MODES}")
    if mesh is None:
        return "single"
    n_model = mesh.shape.get("model", 1)
    if cfg.moe_parallel == "auto":
        ep = (cfg.num_experts % max(n_model, 1) == 0
              and cfg.num_experts >= n_model and n_model > 1)
        return "ep" if ep else "tp"
    if cfg.moe_parallel in ("ep", "ep_a2a") and n_model > 1 \
            and cfg.num_experts % n_model != 0:
        raise ValueError(
            f"moe_parallel={cfg.moe_parallel!r} requires num_experts "
            f"divisible by the 'model' axis, got E={cfg.num_experts} % "
            f"n_model={n_model} != 0 — E_loc = E // n_model would silently "
            "drop experts.  Use moe_parallel='tp' or resize the mesh.")
    return cfg.moe_parallel


def _aux_of(g, cfg):
    return (cfg.aux_loss_weight *
            routing.load_balance_loss(g.router_probs, g.topk_experts,
                                      cfg.num_experts)
            + cfg.z_loss_weight * routing.router_z_loss(g.logits))


def _moe_dispatch(xf: jax.Array, p: dict, cfg, g, disp, rb, *,
                  sliced: bool = False):
    """The shared Dispatch-driven expert compute: gate tagging + the chosen
    implementation over an (already global or already sliced) dispatch.

    Under a sliced dispatch the fused-Pallas composition (``blaze_pallas``)
    and the GShard ``dense`` oracle fall through to ``moe_ffn_blaze`` — the
    fused kernels are a single-device composition (``cfg.use_pallas``
    contract) and the dense oracle has no dispatch to slice; the resolved
    backend still selects the grouped-GEMM kernels inside.
    """
    gates = tag(g.topk_weights.astype(xf.dtype), MOE_GATES)
    if cfg.moe_impl == "megablocks":
        return moe_ffn_megablocks(xf, gates, disp, p["w1"], p["w3"],
                                  p.get("w2"), activation=cfg.ffn_act,
                                  backend=rb)
    if cfg.moe_impl == "blaze_pallas" and not sliced:
        # The fused-Pallas composition has a fixed residual set; a plan
        # whose moe-scoped overrides ask for a different one must fail
        # loudly here, not be silently ignored.
        mode = moe_residual_mode(cfg)
        if mode != ("ab_yswi" if cfg.save_yswi else "ab"):
            raise ValueError(
                f"moe_impl='blaze_pallas' cannot honor the checkpoint "
                f"plan's moe-scoped residual mode {mode!r} (the fused "
                "kernels manage a fixed residual set); use "
                "moe_impl='blaze' or drop the moe-scoped overrides")
        from repro.kernels.ops import moe_ffn_blaze_pallas
        return moe_ffn_blaze_pallas(xf, gates, disp, p["w1"], p["w3"],
                                    p["w2"], backend=rb)
    # Residual set from the checkpoint plan's moe scope (the deprecated
    # cfg.save_yswi bool is the fallback when the plan leaves it open).
    return moe_ffn_blaze(xf, gates, disp, p["w1"], p["w3"], p.get("w2"),
                         activation=cfg.ffn_act,
                         residuals=moe_residual_mode(cfg), backend=rb)


def _moe_local(xf: jax.Array, p: dict, cfg, backend=None):
    """Single-device / tensor-parallel MoEBlaze path on a (L, d) token slab."""
    E, k = cfg.num_experts, cfg.top_k
    g = routing.top_k_gating(xf, p["wg"].astype(xf.dtype), k)
    if cfg.moe_impl == "proxy_gmm":
        # COST-MODEL STAND-IN, dry-run probes only (never executed): XLA's
        # CPU decomposition of ragged_dot is dense-per-group (E x FLOPs /
        # temps), which misrepresents the TPU gmm lowering.  This proxy has
        # the gmm's exact useful FLOPs (L·k rows through d->h->d) and reads
        # the full expert weight bank once (the .sum(0) reductions), but is
        # NOT numerically the MoE.  See EXPERIMENTS.md §Roofline.
        disp = routing.build_dispatch(g.topk_experts, E)   # keep build cost
        gates = g.topk_weights.astype(xf.dtype)
        xg = jnp.take(xf, disp.expert_token_indices, axis=0)
        w1e = p["w1"].sum(0).astype(xf.dtype)
        w3e = p["w3"].sum(0).astype(xf.dtype)
        a = xg @ w1e
        if "w2" in p:
            y_act = jax.nn.silu(a) * (xg @ p["w2"].sum(0).astype(xf.dtype))
        else:
            y_act = jax.nn.silu(a)
        p_out = y_act @ w3e
        L = xf.shape[0]
        parts = jnp.take(p_out, disp.token_index_map.reshape(-1),
                         axis=0).reshape(L, k, -1)
        y = jnp.einsum("lk,lkd->ld", gates, parts)
        return y, _aux_of(g, cfg)
    if cfg.moe_impl == "dense":
        y = moe_ffn_dense(xf, g.router_probs, g.topk_experts,
                          g.topk_weights.astype(xf.dtype),
                          p["w1"], p["w3"], p.get("w2"),
                          activation=cfg.ffn_act)
        return y, _aux_of(g, cfg)
    if cfg.moe_impl == "blaze_pallas":
        from repro.kernels.dispatch import build_dispatch_pallas
        disp = build_dispatch_pallas(g.topk_experts, E)
    else:
        disp = routing.build_dispatch(g.topk_experts, E)
    # cfg.gmm_backend enters the precedence chain at the *config* slot: an
    # explicit call-site choice or an active use_backend() scope wins,
    # env/auto fill in when the config says "auto".
    rb = GB.resolve(backend, config=cfg.gmm_backend)
    y = _moe_dispatch(xf, p, cfg, g, disp, rb)
    return y, _aux_of(g, cfg)


def _moe_proxy_ep(xf: jax.Array, p: dict, cfg, n_model: int):
    """gmm cost model under EP: ~L·k/n_model rows through one d->h->d, plus
    one read of the local expert bank.  NOT numerically the MoE."""
    k = cfg.top_k
    L = xf.shape[0]
    g = routing.top_k_gating(xf, p["wg"].astype(xf.dtype), k)
    rows = max(L * k // n_model, 1)
    xg = jnp.take(xf, jnp.arange(rows) % L, axis=0)
    a = xg @ p["w1"].sum(0).astype(xf.dtype)
    y_act = jax.nn.silu(a)
    if "w2" in p:
        y_act = y_act * (xg @ p["w2"].sum(0).astype(xf.dtype))
    p_out = y_act @ p["w3"].sum(0).astype(xf.dtype)
    y = jnp.zeros_like(xf).at[jnp.arange(rows) % L].add(p_out)
    gm = g.topk_weights.astype(xf.dtype).mean()
    return y * gm, _aux_of(g, cfg)


def _moe_ep(xf: jax.Array, p: dict, cfg, n_model: int, rb):
    """Expert-parallel shard body: this device owns ``E_loc = E / n_model``
    experts (weights arrive local via in_specs — no gather).

    Full gating + the sort-free global dispatch build run on the (model-axis
    replicated) token slab; ``routing.slice_dispatch`` compacts the result to
    this device's expert range, and the SAME ``moe_ffn_blaze`` path runs on
    it — the custom-VJP recompute, the plan-driven residual mode and the
    resolved grouped-GEMM backend all apply under EP.  ``psum`` over 'model' (outside)
    combines expert contributions.
    """
    E, k = cfg.num_experts, cfg.top_k
    E_loc = E // max(n_model, 1)
    g = routing.top_k_gating(xf, p["wg"].astype(xf.dtype), k)
    disp = routing.build_dispatch(g.topk_experts, E)
    idx = jax.lax.axis_index("model")
    loc = routing.slice_dispatch(disp, idx * E_loc, (idx + 1) * E_loc,
                                 count=E_loc)
    y = _moe_dispatch(xf, p, cfg, g, loc, rb, sliced=True)
    return y, _aux_of(g, cfg)


def _a2a_capacity(cfg, n_tokens: int, k: int, n_model: int) -> int:
    """Static per-destination-rank slot capacity: the uniform share
    ``n_tokens*k/n_model`` scaled by ``cfg.moe_a2a_capacity`` and clamped to
    the worst case (every slot routed to one rank)."""
    uniform = (n_tokens * k + n_model - 1) // n_model
    cap = int(uniform * float(cfg.moe_a2a_capacity))
    return max(1, min(cap, n_tokens * k))


def _moe_ep_a2a(xf: jax.Array, p: dict, cfg, n_model: int, rb):
    """Token-exchanged expert parallelism (the X-MoE-style padding-free
    cross-device design, capacity-bounded).

    The local (data-shard) token slab is split over 'model': each rank routes
    its ``L/n`` chunk, groups slots by destination rank with the SAME
    sort-free dispatch build (destination rank = expert // E_loc), and
    exchanges fixed-capacity row buffers with ``jax.lax.all_to_all`` — counts
    first, then rows; slots beyond a destination's capacity are dropped and
    *accounted* (returned as an overflow fraction), never padded to a dense
    ``L×E`` buffer.  Received rows (k=1 slots) run through ``moe_ffn_blaze``
    against the local expert bank — pad rows carry a trash expert id that
    ``slice_dispatch`` rotates into the dead zone — and outputs return to
    their source rank over the same all_to_all pattern.
    """
    E, k = cfg.num_experts, cfg.top_k
    n = max(n_model, 1)
    E_loc = E // n
    L, d = xf.shape
    Lc = L // n
    idx = jax.lax.axis_index("model")
    xc = jax.lax.dynamic_slice_in_dim(xf, idx * Lc, Lc, axis=0)
    g = routing.top_k_gating(xc, p["wg"].astype(xc.dtype), k)
    gates = tag(g.topk_weights.astype(xc.dtype), MOE_GATES)
    # Group this chunk's slots by destination rank (sort-free build reused).
    dest_rank = g.topk_experts // E_loc                       # (Lc, k)
    dr = routing.build_dispatch(dest_rank, n)
    C = _a2a_capacity(cfg, Lc, k, n)
    pos_in_rank = dr.token_index_map - dr.expert_token_offsets[dest_rank]
    valid = pos_in_rank < C
    # Out-of-capacity slots get an out-of-range index -> scatter-dropped.
    buf_idx = jnp.where(valid, dest_rank * C + pos_in_rank, n * C)
    flat_idx = buf_idx.reshape(-1)
    # Send-buffer rows are built as a *gather from the dispatch metadata*
    # (buffer slot ``r*C + p`` <-> dispatch slot ``offsets[r] + p``), not a
    # scatter of a materialized (Lc·k, d) routed copy.  Under a Pallas
    # backend the rows stream through the ``gather_rows`` kernel (send
    # buffer filled inside the kernel from ``expert_token_indices``); the
    # jnp path is the same gather expressed as a masked take.
    slot_rank = jnp.repeat(jnp.arange(n, dtype=jnp.int32), C)
    slot_pos = jnp.tile(jnp.arange(C, dtype=jnp.int32), n)
    slot_ok = slot_pos < jnp.minimum(dr.expert_lengths, C)[slot_rank]
    src_slot = jnp.minimum(dr.expert_token_offsets[slot_rank] + slot_pos,
                           Lc * k - 1)
    row_ids = jnp.where(slot_ok, dr.expert_token_indices[src_slot], -1)
    if rb.name in ("pallas", "pallas_fused"):
        from repro.kernels.ops import gather_rows
        send_x = gather_rows(xc, row_ids)
    else:
        send_x = jnp.where(slot_ok[:, None],
                           jnp.take(xc, jnp.maximum(row_ids, 0), axis=0),
                           jnp.zeros((), xc.dtype))
    send_g = jnp.zeros((n * C,), gates.dtype).at[flat_idx].set(
        gates.reshape(-1), mode="drop")
    e_local = (g.topk_experts % E_loc).reshape(-1).astype(jnp.int32)
    send_e = jnp.full((n * C,), E_loc, jnp.int32).at[flat_idx].set(
        e_local, mode="drop")
    # Counts first: each rank learns how many rows every peer sent it ...
    sent = jnp.minimum(dr.expert_lengths, C)
    recv_cnt = jax.lax.all_to_all(
        sent.reshape(n, 1), "model", 0, 0).reshape(n)
    # ... then the capacity-bounded row buffers.
    recv_x = jax.lax.all_to_all(
        send_x.reshape(n, C, d), "model", 0, 0).reshape(n * C, d)
    recv_g = jax.lax.all_to_all(
        send_g.reshape(n, C), "model", 0, 0).reshape(n * C)
    recv_e = jax.lax.all_to_all(
        send_e.reshape(n, C), "model", 0, 0).reshape(n * C)
    # Mask rows past each source's announced count to the trash expert
    # (belt over the sender-side pad fill).
    row_valid = (jnp.arange(C, dtype=jnp.int32)[None, :]
                 < recv_cnt[:, None]).reshape(n * C)
    recv_e = jnp.where(row_valid, recv_e, E_loc)
    recv_g = jnp.where(row_valid, recv_g, jnp.zeros((), recv_g.dtype))
    # Received rows are k=1 slots; build over E_loc+1 experts (the extra one
    # collects pads/overflow) and slice the real range — trash slots rotate
    # into the dead zone where the grouped GEMM produces zeros.
    full = routing.build_dispatch(recv_e[:, None], E_loc + 1)
    loc = routing.slice_dispatch(full, 0, E_loc)
    y_rows = moe_ffn_blaze(recv_x, recv_g[:, None], loc, p["w1"], p["w3"],
                           p.get("w2"), activation=cfg.ffn_act,
                           residuals=moe_residual_mode(cfg), backend=rb)
    # Return outputs to their source rank (all_to_all is its own inverse
    # under this split/concat pattern), gather back into (Lc, k) slots.
    back = jax.lax.all_to_all(
        y_rows.reshape(n, C, d), "model", 0, 0).reshape(n * C, d)
    parts = jnp.where(
        valid.reshape(-1)[:, None],
        jnp.take(back, jnp.minimum(flat_idx, n * C - 1), axis=0),
        jnp.zeros((), back.dtype)).reshape(Lc, k, d)
    yc = parts.sum(axis=1).astype(xf.dtype)
    y = jax.lax.dynamic_update_slice_in_dim(
        jnp.zeros_like(xf), yc, idx * Lc, axis=0)
    dropped = (dr.expert_lengths - sent).sum()
    overflow = dropped.astype(jnp.float32) / float(Lc * k)
    return y, _aux_of(g, cfg), overflow


def moe_sublayer(x: jax.Array, p: dict, cfg, *, mesh=None,
                 dp_axes=("pod", "data"), with_stats: bool = False):
    """(B, S, d) -> ((B, S, d), aux_loss) — plus a stats dict when
    ``with_stats=True`` (``a2a_overflow``: fraction of routed slots dropped
    by the ``ep_a2a`` capacity bound; 0.0 in every other mode).

    Distribution is selected by :func:`resolve_moe_parallel` (validated at
    entry) and executed by one Dispatch-driven path — see the module
    docstring and README "Distribution modes".
    """
    B, S, d = x.shape
    mode = resolve_moe_parallel(cfg, mesh)

    if mode == "single":
        y, aux = _moe_local(x.reshape(B * S, d), p, cfg)
        y = y.reshape(B, S, d)
        if with_stats:
            return y, aux, {"a2a_overflow": jnp.zeros((), jnp.float32)}
        return y, aux

    n_model = mesh.shape.get("model", 1)
    # Resolve the grouped-GEMM backend HERE, at trace time outside the
    # shard_map, and thread the ResolvedBackend into the body: use_backend
    # scopes and config pins reach the distributed path exactly like the
    # single-device one.
    rb = GB.resolve(None, config=cfg.gmm_backend)
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    batch_axes = dp_axes if (B % max(n_dp, 1) == 0 and n_dp > 1) else ()
    if mode == "ep_a2a":
        tokens_per_dev = (B // n_dp if batch_axes else B) * S
        if tokens_per_dev % max(n_model, 1) != 0:
            raise ValueError(
                f"moe_parallel='ep_a2a' splits the per-device token slab "
                f"over the 'model' axis: {tokens_per_dev} tokens/device % "
                f"n_model={n_model} != 0.  Pad the batch/sequence or use "
                "moe_parallel='ep'.")
    x_spec = P(batch_axes if batch_axes else None, None, None)
    if mode in ("ep", "ep_a2a"):
        p_specs = {"wg": P(None, None), "w1": P("model", None, None),
                   "w2": P("model", None, None), "w3": P("model", None, None)}
    else:
        p_specs = {"wg": P(None, None), "w1": P(None, None, "model"),
                   "w2": P(None, None, "model"), "w3": P(None, "model", None)}
    p_specs = {k_: v for k_, v in p_specs.items() if k_ in p}
    all_axes = tuple(mesh.axis_names)

    def body(xl, pl_):
        Bl, Sl, _ = xl.shape
        xf = xl.reshape(Bl * Sl, d)
        overflow = jnp.zeros((), jnp.float32)
        if mode in ("ep", "ep_a2a") and cfg.moe_impl == "proxy_gmm":
            y, aux = _moe_proxy_ep(xf, pl_, cfg, n_model)
        elif mode == "ep":
            y, aux = _moe_ep(xf, pl_, cfg, n_model, rb)
        elif mode == "ep_a2a":
            y, aux, overflow = _moe_ep_a2a(xf, pl_, cfg, n_model, rb)
        else:
            y, aux = _moe_local(xf, pl_, cfg, backend=rb)
        # The one collective the MoE layer adds: combine partials.
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, all_axes)
        overflow = jax.lax.pmean(overflow, all_axes)
        return y.reshape(Bl, Sl, d), aux, overflow

    y, aux, overflow = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, p_specs),
        out_specs=(x_spec, P(), P()),
        check=False,
    )(x, p)
    if with_stats:
        return y, aux, {"a2a_overflow": overflow}
    return y, aux

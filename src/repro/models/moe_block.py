"""MoE transformer sublayer: router + MoEBlaze expert FFN, with padding-free
distributed execution.

One *Dispatch-driven* path (paper §4.1) serves every expert placement — the
compact index structures from ``core/routing.py`` are built once and either
used whole (single device, TP) or compacted to a device-local expert range
(``routing.slice_dispatch``), so the fused-SwiGLU ``custom_vjp``, the
paper's residual policy, the ``checkpoint.tag`` remat tags and the resolved
grouped-GEMM backend apply identically on one device and under a mesh.

Distribution modes (``cfg.moe_parallel``, README "Distribution modes"):

  * ``ep``     — experts sharded over 'model' (weights never gathered).  Each
    device slices the global Dispatch to its expert range and runs the SAME
    ``moe_ffn_blaze`` on its local tokens; one ``psum`` combines partials.
    Non-local slots rotate into the sliced structure's dead zone, where the
    grouped GEMM produces exact zeros — no capacity padding, no dense L×E.
  * ``ep_a2a`` — tokens sharded over 'model' as well: each device routes its
    L/n chunk, groups slots by destination rank with the same sort-free
    dispatch build, and exchanges capacity-bounded row buffers with
    ``jax.lax.all_to_all`` (counts first; overflow is accounted and surfaced
    as a stat, never silently padded).  With ``cfg.moe_a2a_chunks > 1`` the
    exchange is split into double-buffered chunks so chunk i's all_to_all
    overlaps chunk i-1's grouped GEMM (the overlap knob).
  * ``ep_a2a_hier`` — two-hop hierarchical exchange for meshes that declare
    a 'node' axis (X-MoE style): a node-local hop over the fast 'model'
    axis aligns rows with their destination *lane*, then ONE cross-node
    hop over 'node' delivers them — cross-node (DCN) traffic carries only
    the rows that must actually change nodes.
  * ``tp``     — every expert's hidden dim tensor-sharded over 'model'; the
    unmodified single-device algorithm runs per shard.
  * ``auto``   — resolved by ``roofline.select_moe_parallel``: the analytic
    collective cost model ranks the feasible modes by predicted step cost
    (compute + HBM traffic + bytes-on-wire over each mesh axis's bandwidth
    tier) and breaks near-ties toward lower per-device live bytes.  The
    full decision table travels with the resolution
    (:func:`resolve_moe_parallel_ex`, mirroring ``ResolvedBackend``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import gmm_backend as GB
from repro.core import routing
from repro.core.baseline import moe_ffn_dense, moe_ffn_megablocks
from repro.core.checkpoint import MOE_GATES, moe_residual_mode, tag
from repro.core.moe_layer import moe_ffn_blaze
from repro.models.common import dense_init

MOE_PARALLEL_MODES = ("auto", "ep", "ep_a2a", "ep_a2a_hier", "tp")


def init_moe_params(key, cfg, d: int) -> dict:
    E, h = cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wg": dense_init(ks[0], (d, E), 0, pd),
        "w1": dense_init(ks[1], (E, d, h), 1, pd),
        "w3": dense_init(ks[3], (E, h, d), 1, pd),
    }
    if cfg.ffn_act == "swiglu":
        p["w2"] = dense_init(ks[2], (E, d, h), 1, pd)
    return p


def resolve_moe_parallel(cfg, mesh, n_tokens: int | None = None) -> str:
    """Concrete distribution mode for (cfg, mesh): ``single`` | ``tp`` |
    ``ep`` | ``ep_a2a`` | ``ep_a2a_hier`` — the string half of
    :func:`resolve_moe_parallel_ex`."""
    return resolve_moe_parallel_ex(cfg, mesh, n_tokens).mode


def resolve_moe_parallel_ex(cfg, mesh, n_tokens: int | None = None):
    """Resolve ``cfg.moe_parallel`` against a mesh, with provenance.

    Returns a ``roofline.ParallelDecision`` (mirroring the grouped-GEMM
    registry's ``ResolvedBackend``): the concrete mode, its source
    (``config`` forced / ``auto`` cost model / ``single``) and the full
    predicted-cost table the ``auto`` optimizer ranked.  ``n_tokens`` is the
    per-device token slab when the caller knows it (trace time, train-step
    construction); ``auto`` only ever selects a mode that is *feasible* at
    that slab.

    Validates forced modes at entry — bad factorizations raise HERE, not
    mid-trace: expert parallelism with ``E`` not divisible by the combined
    expert axes would silently drop experts; flat ``ep_a2a`` on a node mesh
    would route cross-node rows over the flat exchange; ``ep_a2a_hier``
    without a 'node' axis has no second hop to run.
    """
    from repro import roofline

    if cfg.moe_parallel not in MOE_PARALLEL_MODES:
        raise ValueError(
            f"unknown moe_parallel {cfg.moe_parallel!r}; "
            f"known: {MOE_PARALLEL_MODES}")
    decision = roofline.select_moe_parallel(cfg, mesh, n_tokens)
    if decision.mode == "single":
        return decision
    n_model = mesh.shape.get("model", 1)
    n_node = mesh.shape.get("node", 1)
    n_exp = max(n_model, 1) * max(n_node, 1)
    mode = decision.mode
    if mode in ("ep", "ep_a2a", "ep_a2a_hier") and n_exp > 1 \
            and cfg.num_experts % n_exp != 0:
        raise ValueError(
            f"moe_parallel={mode!r} requires num_experts divisible by the "
            f"expert axes, got E={cfg.num_experts} % "
            f"n_exp={n_exp} (node x model) != 0 — E_loc = E // n_exp would "
            "silently drop experts.  Use moe_parallel='tp' or resize the "
            "mesh.")
    if mode == "ep_a2a" and n_node > 1:
        raise ValueError(
            "moe_parallel='ep_a2a' is the flat single-hop exchange; this "
            f"mesh declares a 'node' axis (n_node={n_node}) — use "
            "moe_parallel='ep_a2a_hier' (two-hop) or 'ep'.")
    if mode == "ep_a2a_hier" and n_node <= 1:
        raise ValueError(
            "moe_parallel='ep_a2a_hier' needs a factored 'model' axis: the "
            "mesh must declare a 'node' axis (see "
            "launch.mesh.make_node_mesh); this mesh has none.  Use "
            "moe_parallel='ep_a2a' on flat meshes.")
    return decision


def _aux_of(g, cfg):
    return (cfg.aux_loss_weight *
            routing.load_balance_loss(g.router_probs, g.topk_experts,
                                      cfg.num_experts)
            + cfg.z_loss_weight * routing.router_z_loss(g.logits))


def _moe_dispatch(xf: jax.Array, p: dict, cfg, g, disp, rb, *,
                  sliced: bool = False):
    """The shared Dispatch-driven expert compute: gate tagging + the chosen
    implementation over an (already global or already sliced) dispatch.

    Under a sliced dispatch the fused-Pallas composition (``blaze_pallas``)
    and the GShard ``dense`` oracle fall through to ``moe_ffn_blaze`` — the
    fused kernels are a single-device composition (``cfg.use_pallas``
    contract) and the dense oracle has no dispatch to slice; the resolved
    backend still selects the grouped-GEMM kernels inside.
    """
    gates = tag(g.topk_weights.astype(xf.dtype), MOE_GATES)
    if cfg.moe_impl == "megablocks":
        return moe_ffn_megablocks(xf, gates, disp, p["w1"], p["w3"],
                                  p.get("w2"), activation=cfg.ffn_act,
                                  backend=rb)
    if cfg.moe_impl == "blaze_pallas" and not sliced:
        # The fused-Pallas composition has a fixed residual set; a plan
        # whose moe-scoped overrides ask for a different one must fail
        # loudly here, not be silently ignored.
        mode = moe_residual_mode(cfg)
        if mode != ("ab_yswi" if cfg.save_yswi else "ab"):
            raise ValueError(
                f"moe_impl='blaze_pallas' cannot honor the checkpoint "
                f"plan's moe-scoped residual mode {mode!r} (the fused "
                "kernels manage a fixed residual set); use "
                "moe_impl='blaze' or drop the moe-scoped overrides")
        from repro.kernels.ops import moe_ffn_blaze_pallas
        return moe_ffn_blaze_pallas(xf, gates, disp, p["w1"], p["w3"],
                                    p["w2"], backend=rb)
    # Residual set from the checkpoint plan's moe scope (the deprecated
    # cfg.save_yswi bool is the fallback when the plan leaves it open).
    return moe_ffn_blaze(xf, gates, disp, p["w1"], p["w3"], p.get("w2"),
                         activation=cfg.ffn_act,
                         residuals=moe_residual_mode(cfg), backend=rb)


def _moe_local(xf: jax.Array, p: dict, cfg, backend=None):
    """Single-device / tensor-parallel MoEBlaze path on a (L, d) token slab."""
    E, k = cfg.num_experts, cfg.top_k
    g = routing.top_k_gating(xf, p["wg"].astype(xf.dtype), k)
    if cfg.moe_impl == "proxy_gmm":
        # COST-MODEL STAND-IN, dry-run probes only (never executed): XLA's
        # CPU decomposition of ragged_dot is dense-per-group (E x FLOPs /
        # temps), which misrepresents the TPU gmm lowering.  This proxy has
        # the gmm's exact useful FLOPs (L·k rows through d->h->d) and reads
        # the full expert weight bank once (the .sum(0) reductions), but is
        # NOT numerically the MoE.  See EXPERIMENTS.md §Roofline.
        disp = routing.build_dispatch(g.topk_experts, E)   # keep build cost
        gates = g.topk_weights.astype(xf.dtype)
        xg = jnp.take(xf, disp.expert_token_indices, axis=0)
        w1e = p["w1"].sum(0).astype(xf.dtype)
        w3e = p["w3"].sum(0).astype(xf.dtype)
        a = xg @ w1e
        if "w2" in p:
            y_act = jax.nn.silu(a) * (xg @ p["w2"].sum(0).astype(xf.dtype))
        else:
            y_act = jax.nn.silu(a)
        p_out = y_act @ w3e
        L = xf.shape[0]
        parts = jnp.take(p_out, disp.token_index_map.reshape(-1),
                         axis=0).reshape(L, k, -1)
        y = jnp.einsum("lk,lkd->ld", gates, parts)
        return y, _aux_of(g, cfg)
    if cfg.moe_impl == "dense":
        y = moe_ffn_dense(xf, g.router_probs, g.topk_experts,
                          g.topk_weights.astype(xf.dtype),
                          p["w1"], p["w3"], p.get("w2"),
                          activation=cfg.ffn_act)
        return y, _aux_of(g, cfg)
    if cfg.moe_impl == "blaze_pallas":
        from repro.kernels.dispatch import build_dispatch_pallas
        disp = build_dispatch_pallas(g.topk_experts, E)
    else:
        disp = routing.build_dispatch(g.topk_experts, E)
    # cfg.gmm_backend enters the precedence chain at the *config* slot: an
    # explicit call-site choice or an active use_backend() scope wins,
    # env/auto fill in when the config says "auto".
    rb = GB.resolve(backend, config=cfg.gmm_backend)
    y = _moe_dispatch(xf, p, cfg, g, disp, rb)
    return y, _aux_of(g, cfg)


def _moe_proxy_ep(xf: jax.Array, p: dict, cfg, n_model: int):
    """gmm cost model under EP: ~L·k/n_model rows through one d->h->d, plus
    one read of the local expert bank.  NOT numerically the MoE."""
    k = cfg.top_k
    L = xf.shape[0]
    g = routing.top_k_gating(xf, p["wg"].astype(xf.dtype), k)
    rows = max(L * k // n_model, 1)
    xg = jnp.take(xf, jnp.arange(rows) % L, axis=0)
    a = xg @ p["w1"].sum(0).astype(xf.dtype)
    y_act = jax.nn.silu(a)
    if "w2" in p:
        y_act = y_act * (xg @ p["w2"].sum(0).astype(xf.dtype))
    p_out = y_act @ p["w3"].sum(0).astype(xf.dtype)
    y = jnp.zeros_like(xf).at[jnp.arange(rows) % L].add(p_out)
    gm = g.topk_weights.astype(xf.dtype).mean()
    return y * gm, _aux_of(g, cfg)


def _moe_ep(xf: jax.Array, p: dict, cfg, n_exp: int, rb, idx=None):
    """Expert-parallel shard body: this device owns ``E_loc = E / n_exp``
    experts (weights arrive local via in_specs — no gather).  ``n_exp`` is
    the combined expert-axis size (``n_node * n_model`` on a node mesh) and
    ``idx`` this device's flattened expert-axis index (defaults to the
    'model' axis index on flat meshes).

    Full gating + the sort-free global dispatch build run on the (expert-axis
    replicated) token slab; ``routing.slice_dispatch`` compacts the result to
    this device's expert range, and the SAME ``moe_ffn_blaze`` path runs on
    it — the custom-VJP recompute, the plan-driven residual mode and the
    resolved grouped-GEMM backend all apply under EP.  ``psum`` over the
    expert axes (outside) combines expert contributions.
    """
    E, k = cfg.num_experts, cfg.top_k
    E_loc = E // max(n_exp, 1)
    g = routing.top_k_gating(xf, p["wg"].astype(xf.dtype), k)
    disp = routing.build_dispatch(g.topk_experts, E)
    if idx is None:
        idx = jax.lax.axis_index("model")
    loc = routing.slice_dispatch(disp, idx * E_loc, (idx + 1) * E_loc,
                                 count=E_loc)
    y = _moe_dispatch(xf, p, cfg, g, loc, rb, sliced=True)
    return y, _aux_of(g, cfg)


def _a2a_capacity(cfg, n_tokens: int, k: int, n_model: int) -> int:
    """Static per-destination-rank slot capacity of the flat exchange —
    delegates to the simulator's arithmetic so predictor, peak accounting
    and the traced path can never disagree."""
    from repro.core.memsim import _a2a_capacity as cap
    return cap(cfg, n_tokens * k, n_model)


def _a2a_pack(ids: jax.Array, G: int, C: int):
    """Slot bookkeeping of one capacity-bounded exchange hop.

    ``ids`` (R,) int32 destination group per routing slot, in ``[0, G]`` —
    id ``G`` is the trash group (rows that must not travel, e.g. hop-1 pads
    regrouped in hop 2).  The same sort-free dispatch build as routing
    (group members keep ascending row order) yields a bidirectional
    slot<->buffer mapping:

      ``src_of_slot`` (G*C,)  source row per buffer slot (-1 for pads),
      ``slot_ok``     (G*C,)  buffer-slot occupancy,
      ``buf_idx``     (R,)    destination buffer slot per row (G*C = dropped),
      ``valid``       (R,)    row made it under the capacity bound,
      ``sent``        (G,)    rows packed per destination,
      ``dropped``     ()      rows lost to the capacity bound.
    """
    R = ids.shape[0]
    dr = routing.build_dispatch(ids[:, None], G + 1)
    pos = dr.token_index_map.reshape(-1) - dr.expert_token_offsets[ids]
    valid = (ids < G) & (pos < C)
    buf_idx = jnp.where(valid, ids * C + pos, G * C)
    slot_rank = jnp.repeat(jnp.arange(G, dtype=jnp.int32), C)
    slot_pos = jnp.tile(jnp.arange(C, dtype=jnp.int32), G)
    lens = dr.expert_lengths[:G]
    sent = jnp.minimum(lens, C)
    slot_ok = slot_pos < sent[slot_rank]
    src_slot = jnp.minimum(dr.expert_token_offsets[slot_rank] + slot_pos,
                           R - 1)
    src_of_slot = jnp.where(slot_ok, dr.expert_token_indices[src_slot], -1)
    dropped = (lens - sent).sum()
    return src_of_slot, slot_ok, buf_idx, valid, sent, dropped


def _a2a_gather_x(xc, src_of_slot, slot_ok, k: int, rb):
    """Fill the send buffer's x rows: buffer slot <- token ``src//k``.
    Under a Pallas backend the rows stream through the ``gather_rows``
    kernel; the jnp path is the same gather expressed as a masked take."""
    row_ids = jnp.where(slot_ok, src_of_slot // k, -1)
    if rb.name in ("pallas", "pallas_fused"):
        from repro.kernels.ops import gather_rows
        return gather_rows(xc, row_ids)
    return jnp.where(slot_ok[:, None],
                     jnp.take(xc, jnp.maximum(row_ids, 0), axis=0),
                     jnp.zeros((), xc.dtype))


def _a2a_gather(vals, src_of_slot, slot_ok, fill):
    """Fill a per-slot send buffer (gates / expert ids) by the same
    slot<->buffer gather; pad slots carry ``fill``."""
    picked = jnp.take(vals, jnp.maximum(src_of_slot, 0), axis=0)
    return jnp.where(slot_ok, picked, jnp.asarray(fill, vals.dtype))


def _a2a_unpack(back, buf_idx, valid, n_rows: int):
    """Inverse of the send-buffer build: gather each routing slot's output
    row back out of the returned buffer (dropped slots contribute zeros)."""
    parts = jnp.take(back, jnp.minimum(buf_idx, n_rows - 1), axis=0)
    return jnp.where(valid[:, None], parts, jnp.zeros((), back.dtype))


def _local_expert_ffn(rx, rg, re, E_loc: int, p: dict, cfg, rb):
    """Run received k=1 slots against the local expert bank: build over
    ``E_loc + 1`` experts (the extra one collects pads/overflow) and slice
    the real range — trash slots rotate into the dead zone where the
    grouped GEMM produces exact zeros."""
    full = routing.build_dispatch(re[:, None], E_loc + 1)
    loc = routing.slice_dispatch(full, 0, E_loc)
    return moe_ffn_blaze(rx, rg[:, None], loc, p["w1"], p["w3"],
                         p.get("w2"), activation=cfg.ffn_act,
                         residuals=moe_residual_mode(cfg), backend=rb)


def _moe_ep_a2a(xf: jax.Array, p: dict, cfg, n_model: int, rb):
    """Token-exchanged expert parallelism (the X-MoE-style padding-free
    cross-device design, capacity-bounded).

    The local (data-shard) token slab is split over 'model': each rank routes
    its ``L/n`` chunk, groups slots by destination rank with the SAME
    sort-free dispatch build (destination rank = expert // E_loc), and
    exchanges fixed-capacity row buffers with ``jax.lax.all_to_all`` — counts
    first, then rows; slots beyond a destination's capacity are dropped and
    *accounted* (returned as an overflow fraction), never padded to a dense
    ``L×E`` buffer.  Received rows (k=1 slots) run through ``moe_ffn_blaze``
    against the local expert bank — pad rows carry a trash expert id that
    ``slice_dispatch`` rotates into the dead zone — and outputs return to
    their source rank over the same all_to_all pattern.

    With ``cfg.moe_a2a_chunks > 1`` the capacity buffers are split into
    double-buffered chunks: chunk ``j+1``'s exchange is issued before chunk
    ``j``'s grouped GEMM, so the two have no data dependency and XLA's async
    collectives overlap the wire time with the dense compute.  The slot
    bookkeeping, the overflow stat and the custom-VJP residual contract are
    chunk-local but otherwise identical to the unchunked path.
    """
    E, k = cfg.num_experts, cfg.top_k
    n = max(n_model, 1)
    E_loc = E // n
    L, d = xf.shape
    Lc = L // n
    chunks = max(int(getattr(cfg, "moe_a2a_chunks", 1)), 1)
    idx = jax.lax.axis_index("model")
    xc = jax.lax.dynamic_slice_in_dim(xf, idx * Lc, Lc, axis=0)
    g = routing.top_k_gating(xc, p["wg"].astype(xc.dtype), k)
    gates = tag(g.topk_weights.astype(xc.dtype), MOE_GATES)
    # Group this chunk's slots by destination rank (sort-free build reused).
    dest_rank = (g.topk_experts // E_loc).reshape(-1).astype(jnp.int32)
    C = _a2a_capacity(cfg, Lc, k, n)
    if chunks > 1:
        C = -(-C // chunks) * chunks          # pad to a chunk multiple
    src, slot_ok, buf_idx, valid, sent, dropped = _a2a_pack(dest_rank, n, C)
    send_x = _a2a_gather_x(xc, src, slot_ok, k, rb)
    send_g = _a2a_gather(gates.reshape(-1), src, slot_ok, 0)
    e_local = (g.topk_experts % E_loc).reshape(-1).astype(jnp.int32)
    send_e = _a2a_gather(e_local, src, slot_ok, E_loc)
    # Counts first: each rank learns how many rows every peer sent it ...
    recv_cnt = jax.lax.all_to_all(
        sent.reshape(n, 1), "model", 0, 0).reshape(n)
    # ... then the (cheap) slot metadata.
    recv_g = jax.lax.all_to_all(
        send_g.reshape(n, C), "model", 0, 0).reshape(n * C)
    recv_e = jax.lax.all_to_all(
        send_e.reshape(n, C), "model", 0, 0).reshape(n * C)
    # Mask rows past each source's announced count to the trash expert
    # (belt over the sender-side pad fill).
    row_valid = (jnp.arange(C, dtype=jnp.int32)[None, :]
                 < recv_cnt[:, None]).reshape(n * C)
    recv_e = jnp.where(row_valid, recv_e, E_loc)
    recv_g = jnp.where(row_valid, recv_g, jnp.zeros((), recv_g.dtype))
    if chunks == 1:
        recv_x = jax.lax.all_to_all(
            send_x.reshape(n, C, d), "model", 0, 0).reshape(n * C, d)
        y_rows = _local_expert_ffn(recv_x, recv_g, recv_e, E_loc, p, cfg, rb)
        # Return outputs to their source rank (all_to_all is its own inverse
        # under this split/concat pattern), gather back into (Lc, k) slots.
        back = jax.lax.all_to_all(
            y_rows.reshape(n, C, d), "model", 0, 0).reshape(n * C, d)
    else:
        # Double-buffered chunked exchange: buffer position j*Cc..(j+1)*Cc
        # of every rank is chunk j, so each chunk is its own complete
        # (n, Cc) exchange and chunk j+1's all_to_all has no dependency on
        # chunk j's GEMM — issued ahead, it overlaps the compute.
        Cc = C // chunks
        sx = send_x.reshape(n, chunks, Cc, d)
        ge = recv_g.reshape(n, chunks, Cc)
        ee = recv_e.reshape(n, chunks, Cc)

        def exch(j):
            return jax.lax.all_to_all(sx[:, j], "model", 0, 0)

        cur = exch(0)
        backs = []
        for j in range(chunks):
            nxt = exch(j + 1) if j + 1 < chunks else None
            y_j = _local_expert_ffn(cur.reshape(n * Cc, d),
                                    ge[:, j].reshape(-1),
                                    ee[:, j].reshape(-1), E_loc, p, cfg, rb)
            backs.append(jax.lax.all_to_all(
                y_j.reshape(n, Cc, d), "model", 0, 0))
            cur = nxt
        back = jnp.stack(backs, axis=1).reshape(n * C, d)
    parts = _a2a_unpack(back, buf_idx, valid, n * C).reshape(Lc, k, d)
    yc = parts.sum(axis=1).astype(xf.dtype)
    y = jax.lax.dynamic_update_slice_in_dim(
        jnp.zeros_like(xf), yc, idx * Lc, axis=0)
    overflow = dropped.astype(jnp.float32) / float(Lc * k)
    return y, _aux_of(g, cfg), overflow


def _moe_ep_a2a_hier(xf: jax.Array, p: dict, cfg, n_node: int, n_model: int,
                     rb):
    """Two-hop hierarchical token exchange for node meshes (X-MoE style).

    Device ``(i, l)`` on the ('node', 'model') expert axes owns experts
    ``[g*E_loc, (g+1)*E_loc)`` with ``g = i*n_model + l``.  Each device
    routes its ``L/n`` token chunk, then:

      hop 1 (node-local, fast axis): slots regroup by destination *lane*
        ``(e // E_loc) % n_model`` and exchange over 'model' — after this
        hop every row sits on the lane of its target expert, inside its
        source node;
      hop 2 (one cross-node exchange): received rows regroup by destination
        node ``e // (E_loc * n_model)`` and exchange over 'node' — the only
        DCN traffic is rows that genuinely change nodes.

    Both hops reuse the flat path's capacity/overflow accounting
    (``_a2a_pack``); hop-1 pad rows carry the global sentinel expert ``E``,
    which lands in hop 2's trash group by construction.  Compute and the
    return path mirror the flat exchange: the local grouped GEMM runs over
    ``slice_dispatch``'s dead-zone rotation, then the two hops invert in
    reverse order (all_to_all is its own inverse under this pattern).
    """
    E, k = cfg.num_experts, cfg.top_k
    nn, nl = max(n_node, 1), max(n_model, 1)
    n = nn * nl
    E_loc = E // n
    L, d = xf.shape
    Lc = L // n
    gdev = jax.lax.axis_index("node") * nl + jax.lax.axis_index("model")
    xc = jax.lax.dynamic_slice_in_dim(xf, gdev * Lc, Lc, axis=0)
    g = routing.top_k_gating(xc, p["wg"].astype(xc.dtype), k)
    gates = tag(g.topk_weights.astype(xc.dtype), MOE_GATES)
    eg = g.topk_experts.reshape(-1).astype(jnp.int32)   # global expert ids
    # --- hop 1: align rows with their destination lane, inside the node.
    dest_lane = (eg // E_loc) % nl
    from repro.core.memsim import _a2a_capacity as _cap
    C1 = _cap(cfg, Lc * k, nl)
    R1 = nl * C1
    src1, ok1, buf1, valid1, sent1, drop1 = _a2a_pack(dest_lane, nl, C1)
    s1x = _a2a_gather_x(xc, src1, ok1, k, rb)
    s1g = _a2a_gather(gates.reshape(-1), src1, ok1, 0)
    s1e = _a2a_gather(eg, src1, ok1, E)                 # sentinel: global E
    cnt1 = jax.lax.all_to_all(
        sent1.reshape(nl, 1), "model", 0, 0).reshape(nl)
    r1x = jax.lax.all_to_all(
        s1x.reshape(nl, C1, d), "model", 0, 0).reshape(R1, d)
    r1g = jax.lax.all_to_all(
        s1g.reshape(nl, C1), "model", 0, 0).reshape(R1)
    r1e = jax.lax.all_to_all(
        s1e.reshape(nl, C1), "model", 0, 0).reshape(R1)
    rv1 = (jnp.arange(C1, dtype=jnp.int32)[None, :]
           < cnt1[:, None]).reshape(R1)
    r1e = jnp.where(rv1, r1e, E)
    r1g = jnp.where(rv1, r1g, jnp.zeros((), r1g.dtype))
    # --- hop 2: one cross-node exchange per node pair, on the slow axis.
    # Pad rows (e == E) regroup into the trash group nn automatically:
    # E // (E_loc * nl) == nn.
    dest_node = jnp.minimum(r1e // (E_loc * nl), nn)
    C2 = _cap(cfg, Lc * k, nn, clamp=R1)
    R2 = nn * C2
    src2, ok2, buf2, valid2, sent2, drop2 = _a2a_pack(dest_node, nn, C2)
    s2x = jnp.where(ok2[:, None],
                    jnp.take(r1x, jnp.maximum(src2, 0), axis=0),
                    jnp.zeros((), r1x.dtype))
    s2g = _a2a_gather(r1g, src2, ok2, 0)
    s2e = _a2a_gather(r1e, src2, ok2, E)
    cnt2 = jax.lax.all_to_all(
        sent2.reshape(nn, 1), "node", 0, 0).reshape(nn)
    r2x = jax.lax.all_to_all(
        s2x.reshape(nn, C2, d), "node", 0, 0).reshape(R2, d)
    r2g = jax.lax.all_to_all(
        s2g.reshape(nn, C2), "node", 0, 0).reshape(R2)
    r2e = jax.lax.all_to_all(
        s2e.reshape(nn, C2), "node", 0, 0).reshape(R2)
    rv2 = (jnp.arange(C2, dtype=jnp.int32)[None, :]
           < cnt2[:, None]).reshape(R2)
    r2e = jnp.where(rv2, r2e, E)
    r2g = jnp.where(rv2, r2g, jnp.zeros((), r2g.dtype))
    # --- compute against the local bank (global ids -> local range; any
    # row not owned here — pads only, by construction — hits the dead zone).
    lo = gdev * E_loc
    el = jnp.where((r2e >= lo) & (r2e < lo + E_loc), r2e - lo,
                   E_loc).astype(jnp.int32)
    y2 = _local_expert_ffn(r2x, r2g, el, E_loc, p, cfg, rb)
    # --- inverse hop 2, then inverse hop 1.
    b2 = jax.lax.all_to_all(
        y2.reshape(nn, C2, d), "node", 0, 0).reshape(R2, d)
    y1 = _a2a_unpack(b2, buf2, valid2, R2)              # (R1, d)
    b1 = jax.lax.all_to_all(
        y1.reshape(nl, C1, d), "model", 0, 0).reshape(R1, d)
    parts = _a2a_unpack(b1, buf1, valid1, R1).reshape(Lc, k, d)
    yc = parts.sum(axis=1).astype(xf.dtype)
    y = jax.lax.dynamic_update_slice_in_dim(
        jnp.zeros_like(xf), yc, gdev * Lc, axis=0)
    # Every dropped row is counted exactly once — at its source (hop 1) or
    # its relay (hop 2); the pmean outside turns this into the global
    # dropped fraction, same accounting as the flat path.
    overflow = (drop1 + drop2).astype(jnp.float32) / float(Lc * k)
    return y, _aux_of(g, cfg), overflow


def moe_sublayer(x: jax.Array, p: dict, cfg, *, mesh=None,
                 dp_axes=("pod", "data"), with_stats: bool = False):
    """(B, S, d) -> ((B, S, d), aux_loss) — plus a stats dict when
    ``with_stats=True`` (``a2a_overflow``: fraction of routed slots dropped
    by the ``ep_a2a`` / ``ep_a2a_hier`` capacity bounds; 0.0 in every other
    mode).

    Distribution is selected by :func:`resolve_moe_parallel` (validated at
    entry) and executed by one Dispatch-driven path — see the module
    docstring and README "Distribution modes".
    """
    B, S, d = x.shape
    if mesh is not None:
        dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
        n_dp = 1
        for a in dp_axes:
            n_dp *= mesh.shape[a]
        batch_axes = dp_axes if (B % max(n_dp, 1) == 0 and n_dp > 1) else ()
        tokens_per_dev = (B // n_dp if batch_axes else B) * S
    else:
        tokens_per_dev = B * S
    mode = resolve_moe_parallel(cfg, mesh, tokens_per_dev)

    if mode == "single":
        y, aux = _moe_local(x.reshape(B * S, d), p, cfg)
        y = y.reshape(B, S, d)
        if with_stats:
            return y, aux, {"a2a_overflow": jnp.zeros((), jnp.float32)}
        return y, aux

    n_model = mesh.shape.get("model", 1)
    n_node = mesh.shape.get("node", 1)
    n_exp = max(n_model, 1) * max(n_node, 1)
    # Resolve the grouped-GEMM backend HERE, at trace time outside the
    # shard_map, and thread the ResolvedBackend into the body: use_backend
    # scopes and config pins reach the distributed path exactly like the
    # single-device one.
    rb = GB.resolve(None, config=cfg.gmm_backend)
    if mode in ("ep_a2a", "ep_a2a_hier"):
        if tokens_per_dev % n_exp != 0:
            raise ValueError(
                f"moe_parallel={mode!r} splits the per-device token slab "
                f"over the expert axes: {tokens_per_dev} tokens/device % "
                f"n_exp={n_exp} != 0.  Pad the batch/sequence or use "
                "moe_parallel='ep'.")
    x_spec = P(batch_axes if batch_axes else None, None, None)
    # On a node mesh, expert banks shard over the combined (node, model)
    # axes — node-major blocks, matching gdev = node_i * n_model + lane_i.
    ep_w = ("node", "model") if n_node > 1 else "model"
    if mode in ("ep", "ep_a2a", "ep_a2a_hier"):
        p_specs = {"wg": P(None, None), "w1": P(ep_w, None, None),
                   "w2": P(ep_w, None, None), "w3": P(ep_w, None, None)}
    else:
        p_specs = {"wg": P(None, None), "w1": P(None, None, "model"),
                   "w2": P(None, None, "model"), "w3": P(None, "model", None)}
    p_specs = {k_: v for k_, v in p_specs.items() if k_ in p}
    all_axes = tuple(mesh.axis_names)
    # Partials combine over every expert axis; 'tp' shards the hidden dim
    # over 'model' only (node ranks hold identical replicas — no psum).
    psum_axes = (("node", "model") if n_node > 1 else ("model",)) \
        if mode in ("ep", "ep_a2a", "ep_a2a_hier") else ("model",)

    def body(xl, pl_):
        Bl, Sl, _ = xl.shape
        xf = xl.reshape(Bl * Sl, d)
        overflow = jnp.zeros((), jnp.float32)
        if (mode in ("ep", "ep_a2a", "ep_a2a_hier")
                and cfg.moe_impl == "proxy_gmm"):
            y, aux = _moe_proxy_ep(xf, pl_, cfg, n_exp)
        elif mode == "ep":
            idx = None
            if n_node > 1:
                idx = (jax.lax.axis_index("node") * n_model
                       + jax.lax.axis_index("model"))
            y, aux = _moe_ep(xf, pl_, cfg, n_exp, rb, idx=idx)
        elif mode == "ep_a2a":
            y, aux, overflow = _moe_ep_a2a(xf, pl_, cfg, n_model, rb)
        elif mode == "ep_a2a_hier":
            y, aux, overflow = _moe_ep_a2a_hier(xf, pl_, cfg, n_node,
                                                n_model, rb)
        else:
            y, aux = _moe_local(xf, pl_, cfg, backend=rb)
        # The one collective the MoE layer adds: combine partials.
        y = jax.lax.psum(y, psum_axes)
        aux = jax.lax.pmean(aux, all_axes)
        overflow = jax.lax.pmean(overflow, all_axes)
        return y.reshape(Bl, Sl, d), aux, overflow

    y, aux, overflow = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, p_specs),
        out_specs=(x_spec, P(), P()),
        check=False,
    )(x, p)
    if with_stats:
        return y, aux, {"a2a_overflow": overflow}
    return y, aux

"""Attention: GQA with RoPE, flash-style chunked softmax, sliding windows,
logit softcaps (gemma2), qk-norm (qwen3), bidirectional mode (hubert), and a
cache-based decode path with rolling buffers for sliding-window layers."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.checkpoint import ATTN_OUT, QKV, tag
from repro.models.common import dense_init, rms_norm, rope, softcap

NEG_INF = -1e30


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    cap: float = 0.0, q_offset: int = 0,
                    chunk: int = 512, block_skip: bool = False) -> jax.Array:
    """Chunked online-softmax attention (pure JAX; O(S·chunk) memory).

    q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh), Hq % Hkv == 0.
    ``window > 0`` restricts to a causal sliding window.
    ``block_skip`` loops q-blocks with a statically-pruned KV range so fully
    masked chunks are never computed (hillclimb optimization; exact same math).
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = Dh ** -0.5
    chunk = min(chunk, Skv)
    assert Skv % chunk == 0, (Skv, chunk)
    n_chunks = Skv // chunk
    qf = (q.reshape(B, Sq, Hkv, G, Dh) * scale).astype(jnp.float32)
    kc_all = k.reshape(B, n_chunks, chunk, Hkv, Dh)
    vc_all = v.reshape(B, n_chunks, chunk, Hkv, Dh)

    def attend_range(qf_blk, q_pos, lo: int, hi: int):
        """Online softmax over kv chunks [lo, hi) for one q block."""
        Sb = qf_blk.shape[1]
        m0 = jnp.full((B, Sb, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Sb, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, Sb, Hkv, G, Dh), jnp.float32)

        def step(carry, j):
            m, l, acc = carry
            kc = jax.lax.dynamic_index_in_dim(kc_all, j, 1, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vc_all, j, 1, keepdims=False)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qf_blk,
                           kc.astype(jnp.float32))
            s = softcap(s, cap)
            k_pos = j * chunk + jnp.arange(chunk)
            mask = jnp.ones((Sb, chunk), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vc.astype(jnp.float32))
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), jnp.arange(lo, hi))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if not block_skip or not (causal or window):
        q_pos = q_offset + jnp.arange(Sq)
        out = attend_range(qf, q_pos, 0, n_chunks)
    else:
        # Static per-q-block KV ranges: skip fully masked chunks.  The block
        # count is capped at 8 so long-sequence prefill does not unroll into
        # huge HLO (each q block is a python-level call around an inner scan).
        qb = min(max(chunk, Sq // 8), Sq)
        assert Sq % qb == 0
        outs = []
        for i in range(Sq // qb):
            q_lo, q_hi = q_offset + i * qb, q_offset + (i + 1) * qb
            hi = min(n_chunks, -(-q_hi // chunk)) if causal else n_chunks
            lo = max(0, (q_lo - window + 1) // chunk) if window else 0
            q_pos = q_lo + jnp.arange(qb)
            outs.append(attend_range(qf[:, i * qb:(i + 1) * qb],
                                     q_pos, lo, max(hi, lo + 1)))
        out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     slot_pos: jax.Array, pos: jax.Array, *,
                     window: int = 0, cap: float = 0.0) -> jax.Array:
    """One-token attention against a (possibly rolling) cache.

    q: (B, 1, Hq, Dh); caches: (B, C, Hkv, Dh); slot_pos: (B, C) the absolute
    position stored in each request's cache slot (-1 = empty).  ``pos`` is
    scalar (every request at the same position — teacher forcing) or (B,)
    per-request current positions (serving: requests decode at their own
    prefix lengths).
    """
    B, _, Hq, Dh = q.shape
    _, C, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    pos = jnp.broadcast_to(jnp.asarray(pos).reshape(-1), (B,))
    # Keep the cache in its storage dtype — accumulate in f32 inside the dot
    # (a multi-GiB f32 copy of the cache would otherwise materialize).
    qf = (q.reshape(B, Hkv, G, Dh) * Dh ** -0.5).astype(k_cache.dtype)
    s = jnp.einsum("bhgd,bchd->bhgc", qf, k_cache,
                   preferred_element_type=jnp.float32)
    s = softcap(s, cap)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])         # (B, C)
    if window:
        valid &= slot_pos > pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgc,bchd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, Dh).astype(q.dtype)


class KVCache(NamedTuple):
    k: jax.Array          # (B, C, Hkv, Dh)
    v: jax.Array          # (B, C, Hkv, Dh)
    slot_pos: jax.Array   # (B, C) int32, absolute position per slot (-1
    # empty) — per-request, so batched requests can sit at different
    # positions (the serving engine's mixed-prompt-length requirement)


def init_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
                  dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        slot_pos=jnp.full((batch, capacity), -1, jnp.int32),
    )


def init_attn_params(key, cfg, d: int) -> dict:
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads * dh), 0, pd),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads * dh), 0, pd),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads * dh), 0, pd),
        "wo": dense_init(ks[3], (cfg.num_heads * dh, d), 0, pd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), pd)
        p["k_norm"] = jnp.zeros((dh,), pd)
    return p


def _project_qkv(x: jax.Array, p: dict, cfg, positions: jax.Array,
                 num_heads: int | None = None):
    """Shared q/k/v projection + qk-norm + RoPE.  ``positions`` may be a
    scalar, an (S,) shared sequence, an (B,) per-request decode position
    (S == 1), or a full (B, S) grid."""
    B, S, _ = x.shape
    H = num_heads if num_heads is not None else cfg.num_heads
    Hkv = cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    dt = x.dtype
    q = tag((x @ p["wq"].astype(dt)).reshape(B, S, H, dh), QKV)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions.ndim == 2:
        pos_b = positions
    elif positions.ndim == 1 and S == 1 and positions.shape[0] == B:
        pos_b = positions[:, None]       # per-request decode positions
    else:
        pos_b = jnp.broadcast_to(positions, (B, S))
    q = rope(q, pos_b, cfg.rope_theta)
    k = rope(k, pos_b, cfg.rope_theta)
    return q, k, v, pos_b


def attention_sublayer(x: jax.Array, p: dict, cfg, *, is_local: bool,
                       positions: jax.Array, cache: KVCache | None = None,
                       num_heads: int | None = None):
    """(B, S, d) -> (B, S, d).  With ``cache`` (decode), S must be 1 and
    ``positions`` is the write position — scalar or per-request (B,);
    returns (out, new_cache)."""
    B, S, _ = x.shape
    H = num_heads if num_heads is not None else cfg.num_heads
    dh = cfg.resolved_head_dim
    window = cfg.sliding_window if is_local else 0
    q, k, v, pos_b = _project_qkv(x, p, cfg, positions, num_heads)

    if cache is None:
        if cfg.use_pallas:
            from repro.kernels.flash_attention import flash_attention_fused
            o = flash_attention_fused(
                q, k, v, cfg.causal, window, cfg.attn_softcap)
        else:
            o = flash_attention(
                q, k, v, causal=cfg.causal, window=window,
                cap=cfg.attn_softcap, chunk=min(cfg.attn_chunk, S),
                block_skip=cfg.block_causal_skip)
        new_cache = None
    else:
        pos = jnp.broadcast_to(pos_b[:, 0], (B,))     # per-request positions
        C = cache.k.shape[1]
        slot = (pos % C).astype(jnp.int32)
        bidx = jnp.arange(B)
        kc = cache.k.at[bidx, slot].set(k[:, 0])
        vc = cache.v.at[bidx, slot].set(v[:, 0])
        sp = cache.slot_pos.at[bidx, slot].set(pos.astype(jnp.int32))
        o = decode_attention(q, kc, vc, sp, pos, window=window,
                             cap=cfg.attn_softcap)
        new_cache = KVCache(kc, vc, sp)

    o = tag(o.reshape(B, S, H * dh) @ p["wo"].astype(x.dtype), ATTN_OUT)
    return o, new_cache


def paged_attention_sublayer(x: jax.Array, p: dict, cfg, *, is_local: bool,
                             positions: jax.Array, pages, page_table,
                             prefill: bool, offsets=None,
                             attn_impl: str = "dense"):
    """Attention sublayer against a block-paged cache (serving).

    ``prefill=True``: ``x`` is the whole right-padded prompt ``(B, S, d)``
    with shared ``positions = arange(S)``; every position's k/v is scattered
    through ``page_table`` (padded tails land on the trash page) and
    attention runs causally on the in-flight k/v — one jitted call fills the
    cache, no token-at-a-time teacher forcing.  With ``offsets`` ``(B,)``
    (prefix sharing), ``x`` is only each request's unshared SUFFIX:
    ``positions`` is the absolute ``(B, S)`` grid, k/v scatter at
    ``offsets[b] + t``, and attention gathers the request's pages — the
    shared prefix KV is READ from cache, never recomputed.
    ``prefill=False``: S == 1 and ``positions`` are per-request ``(B,)``
    write positions; the new k/v is appended and attention gathers the
    request's pages via the ``attn_impl`` implementation (``dense`` gather
    or the Pallas page-walk kernel).  Returns ``(out, new_pages)``."""
    from repro.serve import paged_cache as PC
    B, S, _ = x.shape
    H = cfg.num_heads
    dh = cfg.resolved_head_dim
    window = cfg.sliding_window if is_local else 0
    q, k, v, pos_b = _project_qkv(x, p, cfg, positions)

    if prefill and offsets is None:
        new_pages = PC.write_prefill(pages, k, v, page_table)
        if cfg.use_pallas:
            from repro.kernels.flash_attention import flash_attention_fused
            o = flash_attention_fused(q, k, v, True, window, cfg.attn_softcap)
        else:
            o = flash_attention(q, k, v, causal=True, window=window,
                                cap=cfg.attn_softcap,
                                chunk=min(cfg.attn_chunk, S),
                                block_skip=cfg.block_causal_skip)
    elif prefill:
        new_pages = PC.write_prefill_offset(pages, k, v, page_table, offsets)
        o = PC.paged_gather_attention(q, new_pages, page_table, pos_b,
                                      window=window, cap=cfg.attn_softcap)
    else:
        new_pages = PC.write_decode(pages, k, v, page_table, positions)
        o = PC.paged_attention(q, new_pages, page_table, positions,
                               window=window, cap=cfg.attn_softcap,
                               impl=attn_impl)
    o = tag(o.reshape(B, S, H * dh) @ p["wo"].astype(x.dtype), ATTN_OUT)
    return o, new_pages

"""Model assembly: block patterns, layer-scan, embeddings, train/prefill/
decode entry points, and cache management for all ten assigned architectures.

Layers are stacked per *pattern group* and iterated with ``jax.lax.scan``
(MaxText-style) so HLO size and compile time stay bounded for 46–62-layer
configs; alternating patterns (gemma2 local/global, xLSTM mLSTM/sLSTM) scan
over groups of ``cfg.pattern_period`` sublayers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from typing import NamedTuple

from repro.core import checkpoint as CK
from repro.models import ssm
from repro.models.attention import (attention_sublayer, init_attn_params,
                                    init_kv_cache, paged_attention_sublayer)
from repro.models.common import dense_init, rms_norm, softcap
from repro.models.ffn import ffn_sublayer, init_ffn_params
from repro.models.moe_block import init_moe_params, moe_sublayer

ATTN_KINDS = {"attn_ffn", "attn_local_ffn", "attn_moe", "attn_local_moe"}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_sublayer(key, kind: str, cfg) -> dict:
    d = cfg.d_model
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    zeros = lambda: jnp.zeros((d,), pd)
    if kind in ATTN_KINDS:
        p = {"ln1": zeros(), "ln2": zeros(),
             "attn": init_attn_params(ks[0], cfg, d)}
        if cfg.post_norms:
            p["ln1_post"] = zeros()
            p["ln2_post"] = zeros()
        if kind.endswith("moe"):
            p["moe"] = init_moe_params(ks[1], cfg, d)
        else:
            p["ffn"] = init_ffn_params(ks[1], cfg, d, cfg.d_ff)
        return p
    if kind == "mlstm":
        return {"ln1": zeros(), "mlstm": ssm.init_mlstm_params(ks[0], cfg, d)}
    if kind == "slstm":
        return {"ln1": zeros(), "slstm": ssm.init_slstm_params(ks[0], cfg, d)}
    if kind == "hymba":
        return {"ln1": zeros(), "ln2": zeros(),
                "attn": init_attn_params(ks[0], cfg, d),
                "mamba": ssm.init_mamba_params(ks[1], cfg, d),
                "ffn": init_ffn_params(ks[2], cfg, d, cfg.d_ff)}
    raise ValueError(kind)


def init_params(key, cfg) -> dict:
    d = cfg.d_model
    pd = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.num_groups + 4)
    pattern = cfg.block_pattern
    assert len(pattern) == cfg.pattern_period

    def init_group(k):
        sks = jax.random.split(k, len(pattern))
        return tuple(_init_sublayer(sk, kind, cfg)
                     for sk, kind in zip(sks, pattern))

    groups = [init_group(keys[i]) for i in range(cfg.num_groups)]
    layers = jax.tree.map(lambda *ls: jnp.stack(ls), *groups)
    params = {"layers": layers,
              "final_norm": jnp.zeros((d,), pd),
              "unembed": dense_init(keys[-1], (d, cfg.vocab_size), 0, pd)}
    if cfg.input_kind in ("tokens", "mixed"):
        params["embed"] = (jax.random.normal(keys[-2], (cfg.vocab_size, d))
                           * 0.02).astype(pd)
    if cfg.input_kind == "frames":
        params["frontend_proj"] = dense_init(keys[-3], (d, d), 0, pd)
    if cfg.input_kind == "mixed":
        params["img_proj"] = dense_init(keys[-3], (d, d), 0, pd)
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_sublayer(x, p, kind: str, cfg, *, mesh, positions, cache,
                    paged=None):
    """Returns (x, aux, stats, new_cache) — ``aux`` the scalar aux loss,
    ``stats`` the scalar ``ep_a2a`` routing-overflow fraction.  ``paged``
    (a :class:`PagedCtx`) switches the attention sublayers onto the
    block-paged cache path; ``cache`` then holds each sublayer's
    :class:`~repro.serve.paged_cache.PagedKV` pool."""
    aux = jnp.zeros((), jnp.float32)
    stats = jnp.zeros((), jnp.float32)
    if kind in ATTN_KINDS:
        is_local = "local" in kind and cfg.sliding_window > 0
        h = rms_norm(x, p["ln1"])
        if paged is not None:
            h, new_kv = paged_attention_sublayer(
                h, p["attn"], cfg, is_local=is_local, positions=positions,
                pages=cache[0], page_table=paged.page_table,
                prefill=paged.prefill, offsets=paged.offsets,
                attn_impl=paged.attn_impl)
        else:
            h, new_kv = attention_sublayer(
                h, p["attn"], cfg, is_local=is_local, positions=positions,
                cache=cache[0] if cache is not None else None)
        if cfg.post_norms:
            h = rms_norm(h, p["ln1_post"])
        x = x + h
        h = rms_norm(x, p["ln2"])
        if kind.endswith("moe"):
            h, aux, mstats = moe_sublayer(h, p["moe"], cfg, mesh=mesh,
                                          with_stats=True)
            stats = mstats["a2a_overflow"]
        else:
            h = ffn_sublayer(h, p["ffn"], cfg)
        if cfg.post_norms:
            h = rms_norm(h, p["ln2_post"])
        return x + h, aux, stats, (new_kv,)
    if kind == "mlstm":
        h, st = ssm.mlstm_sublayer(
            rms_norm(x, p["ln1"]), p["mlstm"], cfg,
            state=cache[0] if cache is not None else None)
        return x + h, aux, stats, (st,)
    if kind == "slstm":
        h, st = ssm.slstm_sublayer(
            rms_norm(x, p["ln1"]), p["slstm"], cfg,
            state=cache[0] if cache is not None else None)
        return x + h, aux, stats, (st,)
    if kind == "hymba":
        h = rms_norm(x, p["ln1"])
        ha, new_kv = attention_sublayer(
            h, p["attn"], cfg, is_local=cfg.sliding_window > 0,
            positions=positions, cache=cache[0] if cache is not None else None)
        hm, st = ssm.mamba_sublayer(
            h, p["mamba"], cfg,
            state=cache[1] if cache is not None else None)
        x = x + 0.5 * (ha + hm)            # parallel heads, mean-fused
        h = ffn_sublayer(rms_norm(x, p["ln2"]), p["ffn"], cfg)
        return x + h, aux, stats, (new_kv, st)
    raise ValueError(kind)


def _apply_group(x, gp, cfg, *, mesh, positions, cache_group,
                 sub_policies=None, paged=None):
    """Apply one pattern group.  ``sub_policies`` (kind -> jax.checkpoint
    policy) engages per-block-kind remat: the plan scopes some shared tag
    differently across the kinds of this pattern, so each sublayer is
    checkpointed with its own scoped policy (training path only — the
    group-level wrap in ``forward`` handles the uniform case)."""
    auxes = []
    stats = []
    new_caches = []
    for j, kind in enumerate(cfg.block_pattern):
        c = cache_group[j] if cache_group is not None else None
        if sub_policies is not None and c is None:
            sub = jax.checkpoint(
                lambda x_, p_, kind=kind: _apply_sublayer(
                    x_, p_, kind, cfg, mesh=mesh, positions=positions,
                    cache=None),
                policy=sub_policies[kind], prevent_cse=False)
            x, aux, st, nc = sub(x, gp[j])
        else:
            x, aux, st, nc = _apply_sublayer(x, gp[j], kind, cfg, mesh=mesh,
                                             positions=positions, cache=c,
                                             paged=paged)
        auxes.append(aux)
        stats.append(st)
        new_caches.append(nc)
    return x, sum(auxes), sum(stats), tuple(new_caches)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg):
    dt = jnp.dtype(cfg.dtype)
    if cfg.input_kind == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    elif cfg.input_kind == "frames":
        x = (batch["features"].astype(dt) @
             params["frontend_proj"].astype(dt))
    elif cfg.input_kind == "mixed":
        img = (batch["image_embeds"].astype(dt) @
               params["img_proj"].astype(dt))
        tok = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
        x = jnp.concatenate([img, tok], axis=1)
    else:
        raise ValueError(cfg.input_kind)
    return x * (cfg.d_model ** 0.5)


def _act_constraint(x, mesh):
    """Anchor activations batch-sharded on the data axes — without this GSPMD
    can propagate the FSDP weight shardings into batch-replicated activations
    (observed: 16x activation blow-up on prefill)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if n_dp <= 1 or x.shape[0] % n_dp:
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def forward(params, batch, cfg, *, mesh=None, last_only: bool = False,
            with_stats: bool = False):
    """Full-sequence forward (training / prefill).  Returns (logits, aux) —
    plus a stats dict (``moe_overflow``: layer-summed ``ep_a2a`` routing
    overflow fraction) when ``with_stats=True``.  ``last_only`` emits logits
    for the final position only (prefill)."""
    x = _embed_inputs(params, batch, cfg)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    x = _act_constraint(x, mesh)

    # Resolve the checkpoint plan (cfg.remat_policy: registry name or spec)
    # and pick how to apply it: one group-level jax.checkpoint when the
    # decisions are uniform across the pattern's kinds (bit-identical to the
    # legacy string path for named plans), per-sublayer policies when the
    # plan scopes a shared tag differently per block kind.
    plan = CK.resolve_plan(config=cfg.remat_policy).plan
    mode, payload = CK.plan_policies(plan, cfg.block_pattern)
    sub_policies = payload if mode == "per_kind" else None

    def group_fn(carry, gp):
        x, aux, ov = carry
        x, a, o, _ = _apply_group(x, gp, cfg, mesh=mesh, positions=positions,
                                  cache_group=None,
                                  sub_policies=sub_policies)
        return (_act_constraint(x, mesh), aux + a, ov + o), None

    if mode == "group":
        group_fn = jax.checkpoint(group_fn, policy=payload, prevent_cse=False)

    zero = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        (x, aux, ov), _ = jax.lax.scan(group_fn, (x, zero, zero),
                                       params["layers"])
    else:
        aux, ov = zero, zero
        for i in range(cfg.num_groups):
            gp = jax.tree.map(lambda l: l[i], params["layers"])
            (x, aux, ov), _ = group_fn((x, aux, ov), gp)

    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["unembed"].astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if with_stats:
        return logits, aux, {"moe_overflow": ov}
    return logits, aux


def init_cache(cfg, batch: int, capacity: int):
    """Decode cache pytree, stacked over layer groups."""
    dt = jnp.dtype(cfg.dtype)
    dh = cfg.resolved_head_dim

    def sub_cache(kind):
        if kind in ATTN_KINDS:
            cap = capacity
            if "local" in kind and cfg.sliding_window:
                cap = min(cfg.sliding_window, capacity)
            return (init_kv_cache(batch, cap, cfg.num_kv_heads, dh, dt),)
        if kind == "mlstm":
            H = cfg.num_heads
            dhh = 2 * cfg.d_model // H
            return ((jnp.zeros((batch, H, dhh, dhh), jnp.float32),
                     jnp.zeros((batch, H, dhh), jnp.float32),
                     jnp.full((batch, H), -1e30, jnp.float32)),)
        if kind == "slstm":
            d = cfg.d_model
            return ((jnp.zeros((batch, d), jnp.float32),
                     jnp.zeros((batch, d), jnp.float32),
                     jnp.full((batch, d), -1e30, jnp.float32)),)
        if kind == "hymba":
            cap = min(cfg.sliding_window, capacity) if cfg.sliding_window \
                else capacity
            return (init_kv_cache(batch, cap, cfg.num_kv_heads, dh, dt),
                    jnp.zeros((batch, cfg.ssm_heads, dh, cfg.ssm_state),
                              jnp.float32))
        raise ValueError(kind)

    one_group = tuple(sub_cache(k) for k in cfg.block_pattern)
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (cfg.num_groups,) + l.shape),
        one_group)


def decode_step(params, cache, batch, pos, cfg, *, mesh=None):
    """One-token decode.  batch['tokens']: (B, 1); pos: scalar absolute
    position.  Returns (logits (B, vocab), new_cache)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.input_kind == "frames":
        raise ValueError("encoder-only architectures do not decode")
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    x = x * (cfg.d_model ** 0.5)
    positions = jnp.full((1,), pos)

    def group_fn(x, scan_in):
        gp, cache_group = scan_in
        x, _, _, nc = _apply_group(x, gp, cfg, mesh=mesh, positions=positions,
                                   cache_group=cache_group)
        return x, nc

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(group_fn, x, (params["layers"], cache))
    else:
        ncs = []
        for i in range(cfg.num_groups):
            gp = jax.tree.map(lambda l: l[i], params["layers"])
            cg = jax.tree.map(lambda l: l[i], cache)
            x, nc = group_fn(x, (gp, cg))
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs)

    x = rms_norm(x, params["final_norm"])
    logits = x[:, 0] @ params["unembed"].astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged serving entry points (block-paged KV cache; see serve/paged_cache)
# ---------------------------------------------------------------------------


class PagedCtx(NamedTuple):
    """Static+dynamic context threaded to the paged attention sublayers.
    ``prefill`` is a Python bool (trace-static): it selects the whole-prompt
    scatter+flash path vs the single-token append+gather path.
    ``offsets`` (``(B,)``, prefill only) switches prefill to the
    prefix-sharing suffix path: tokens scatter at ``offsets[b] + t`` and
    attention gathers cached pages instead of running flash on in-flight
    k/v.  ``attn_impl`` (trace-static str) picks the registered decode
    attention implementation (``dense`` | ``pallas``)."""

    page_table: jax.Array       # (B, pages_per_seq) int32 physical pages
    prefill: bool
    offsets: jax.Array | None = None
    attn_impl: str = "dense"


def paged_supported(cfg) -> bool:
    """Paged serving covers attention block patterns (SSM carries are O(1)
    per-slot state — nothing to page)."""
    return all(k in ATTN_KINDS for k in cfg.block_pattern)


def init_paged_cache(cfg, num_pages: int, page_size: int, *,
                     quantized: bool = False):
    """Paged decode cache: per attention sublayer one
    :class:`~repro.serve.paged_cache.PagedKV` pool of ``num_pages`` pages
    (physical page 0 reserved as the trash page), stacked over layer groups
    like :func:`init_cache`.  ``quantized`` stores int8 values + f16
    per-(position, head) scales — the ``serve/kv_quant`` scheme applied at
    append time."""
    from repro.serve.paged_cache import init_paged_kv
    if not paged_supported(cfg):
        raise ValueError(
            f"paged serving needs an attention block pattern; "
            f"{cfg.name} has {cfg.block_pattern} (use T.decode_step)")
    dt = jnp.dtype(cfg.dtype)
    one_group = tuple(
        (init_paged_kv(num_pages, page_size, cfg.num_kv_heads,
                       cfg.resolved_head_dim, dt, quantized=quantized),)
        for _ in cfg.block_pattern)
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (cfg.num_groups,) + l.shape),
        one_group)


def prefill(params, tokens, lengths, cache, page_table, cfg, *, mesh=None,
            offsets=None, attn_impl: str = "dense"):
    """Whole-prompt forward that fills the paged cache in ONE call.

    tokens: (B, S) right-padded prompts; lengths: (B,) true prompt lengths;
    page_table: (B, pages_per_seq).  Every position 0..S-1 is written
    through the page table (padded tails land on the trash page or in slots
    the request will overwrite during decode — both unobservable, because
    attention masks by per-request prefix length), and attention over the
    prompt itself is causal flash on the in-flight k/v.  Returns
    ``(logits (B, vocab) at each request's last prompt token, new_cache)``.

    With ``offsets`` ``(B,)`` (prefix sharing), ``tokens`` holds only each
    request's unshared SUFFIX (``lengths`` = suffix lengths): rows write at
    absolute ``offsets[b] + t`` and attend through the page table, reading
    the shared prefix KV from cache instead of recomputing it.  The logits
    row is still each request's last real token (relative index
    ``lengths - 1``)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.input_kind != "tokens":
        raise ValueError("paged serving decodes token streams")
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * (cfg.d_model ** 0.5)
    if offsets is None:
        positions = jnp.arange(S)
    else:
        positions = offsets[:, None] + jnp.arange(S)[None, :]   # (B, S)
    paged = PagedCtx(page_table, True, offsets, attn_impl)

    def group_fn(x, scan_in):
        gp, cache_group = scan_in
        x, _, _, nc = _apply_group(x, gp, cfg, mesh=mesh, positions=positions,
                                   cache_group=cache_group, paged=paged)
        return x, nc

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(group_fn, x, (params["layers"], cache))
    else:
        ncs = []
        for i in range(cfg.num_groups):
            gp = jax.tree.map(lambda l: l[i], params["layers"])
            cg = jax.tree.map(lambda l: l[i], cache)
            x, nc = group_fn(x, (gp, cg))
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs)

    x = rms_norm(x, params["final_norm"])
    idx = jnp.clip(lengths - 1, 0, S - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = x_last @ params["unembed"].astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, new_cache


def paged_decode_step(params, cache, tokens, lengths, page_table, cfg, *,
                      mesh=None, attn_impl: str = "dense"):
    """One decode step with every request at its OWN position.

    tokens: (B, 1) the last sampled token per request; lengths: (B,) the
    absolute position that token is written at (== the request's current
    token count).  ``attn_impl`` picks the paged-attention implementation
    (``dense`` gather or the Pallas page-walk kernel).  Returns
    (logits (B, vocab), new_cache)."""
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * (cfg.d_model ** 0.5)
    paged = PagedCtx(page_table, False, None, attn_impl)

    def group_fn(x, scan_in):
        gp, cache_group = scan_in
        x, _, _, nc = _apply_group(x, gp, cfg, mesh=mesh, positions=lengths,
                                   cache_group=cache_group, paged=paged)
        return x, nc

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(group_fn, x, (params["layers"], cache))
    else:
        ncs = []
        for i in range(cfg.num_groups):
            gp = jax.tree.map(lambda l: l[i], params["layers"])
            cg = jax.tree.map(lambda l: l[i], cache)
            x, nc = group_fn(x, (gp, cg))
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs)

    x = rms_norm(x, params["final_norm"])
    logits = x[:, 0] @ params["unembed"].astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def train_loss(params, batch, cfg, *, mesh=None):
    logits, aux, stats = forward(params, batch, cfg, mesh=mesh,
                                 with_stats=True)
    labels = batch["labels"]
    if cfg.input_kind == "mixed":
        # image positions carry no next-token loss
        n_img = batch["image_embeds"].shape[1]
        logits = logits[:, n_img:]
    if cfg.causal:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux, {"ce": loss, "aux": aux,
                        "moe_overflow": stats["moe_overflow"]}

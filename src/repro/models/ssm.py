"""SSM / recurrent sublayers: mLSTM + sLSTM (xLSTM) and Mamba-style selective
heads (Hymba), all with chunked parallel scans for training/prefill and O(1)
single-step updates for decode.

TPU adaptations (DESIGN.md §2/§7):
  * mLSTM uses the chunkwise form — inter-chunk (d_k×d_v) matrix-state
    recurrence via ``lax.scan``, intra-chunk quadratic attention-like term —
    with log-space max stabilization, matching the xLSTM formulation.
  * sLSTM keeps the exponential-gating scalar memory (c, n, m states) but
    drops the dense hidden→gate recurrence R (set to 0): the max-plus
    stabilizer recurrence and the two linear recurrences then admit parallel
    associative scans.  xLSTM's block-diagonal R has no efficient parallel
    TPU form; this is recorded as a deviation.
  * Mamba heads follow the Mamba-2 scalar-A-per-head simplification; the
    causal conv is omitted (stub-adjacent simplification, noted).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

# ---------------------------------------------------------------------------
# Generic chunked associative scan
# ---------------------------------------------------------------------------


def chunked_assoc_scan(op, elems, seq_axis: int, chunk: int):
    """Prefix-aggregate scan over ``seq_axis`` in chunks of ``chunk``.

    ``op`` must be associative over pytrees whose leaves carry the time axis
    at position 0 (after normalization).  Memory stays O(chunk · state) per
    step instead of O(S · state).
    """
    elems = jax.tree.map(lambda l: jnp.moveaxis(l, seq_axis, 0), elems)
    S = jax.tree.leaves(elems)[0].shape[0]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    chunks = jax.tree.map(lambda l: l.reshape(n, chunk, *l.shape[1:]), elems)

    def step(carry, ch):
        inner = jax.lax.associative_scan(op, ch, axis=0)
        if carry is not None:
            carry_b = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (chunk,) + l.shape),
                carry)
            inner = op(carry_b, inner)
        new_carry = jax.tree.map(lambda l: l[-1], inner)
        return new_carry, inner

    first_carry = None
    # run the first chunk outside scan to build a concrete carry
    first_carry, first_out = step(first_carry, jax.tree.map(
        lambda l: l[0], chunks))
    if n == 1:
        outs = jax.tree.map(lambda l: l[None], first_out)
    else:
        rest = jax.tree.map(lambda l: l[1:], chunks)
        _, rest_out = jax.lax.scan(step, first_carry, rest)
        outs = jax.tree.map(
            lambda a, b: jnp.concatenate([a[None], b], 0), first_out, rest_out)
    outs = jax.tree.map(lambda l: l.reshape(S, *l.shape[2:]), outs)
    return jax.tree.map(lambda l: jnp.moveaxis(l, 0, seq_axis), outs)


def _decay_op(a, b):
    """Linear recurrence y_t = a_t * y_{t-1} + x_t as an associative op on
    (log_a, x) pairs — multiplicative decay kept in log space."""
    la1, x1 = a
    la2, x2 = b
    return (la1 + la2, x1 * jnp.exp(la2) + x2)


def _maxplus_op(a, b):
    """m_t = max(m_{t-1} + lf_t, li_t) as associative op on (lf, li)."""
    f1, m1 = a
    f2, m2 = b
    return (f1 + f2, jnp.maximum(m1 + f2, m2))


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory), chunkwise-parallel
# ---------------------------------------------------------------------------


def mlstm_scan(q, k, v, i_pre, f_pre, *, chunk: int = 256, state=None):
    """Chunkwise mLSTM.

    q, k, v: (B, S, H, D); i_pre, f_pre: (B, S, H) pre-activations.
    state: optional (C (B,H,D,D), n (B,H,D), m (B,H)) carry-in.
    Returns (out (B,S,H,D), state_out).
    """
    B, S, H, D = q.shape
    dt = q.dtype
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))       # (B,S,H)
    li = i_pre.astype(jnp.float32)
    k = k * (D ** -0.5)
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    resh = lambda t: t.reshape(B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, lfc, lic = map(resh, (q, k, v, lf, li))

    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, ch):
        C, n, m = carry
        qq, kk, vv, lff, lii = ch                      # (B,chunk,H,...)
        b = jnp.cumsum(lff, axis=1)                    # (B,chunk,H) incl.
        total = b[:, -1]                               # (B,H)
        # log weights
        w_inter = b + m[:, None]                       # (B,chunk,H)
        w_intra = (b[:, :, None] - b[:, None, :] +
                   lii[:, None, :])                    # (B,t,s,H)
        w_intra = jnp.where(tri[None, :, :, None], w_intra, -1e30)
        m_t = jnp.maximum(w_inter, w_intra.max(axis=2))  # (B,chunk,H)
        inter_s = jnp.exp(w_inter - m_t)
        intra_s = jnp.exp(w_intra - m_t[:, :, None])
        h_inter = jnp.einsum("bthd,bhde->bthe", qq, C) * inter_s[..., None]
        n_inter = jnp.einsum("bthd,bhd->bth", qq, n) * inter_s
        sc = jnp.einsum("bthd,bshd->btsh", qq, kk) * intra_s
        h_intra = jnp.einsum("btsh,bshe->bthe", sc, vv)
        n_intra = sc.sum(axis=2)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))
        out = (h_inter + h_intra) / denom[..., None]
        # carry update
        w_new = total[:, None] - b + lii               # (B,chunk,H)
        m_new = jnp.maximum(total + m, w_new.max(axis=1))
        kw = jnp.exp(w_new - m_new[:, None])[..., None] * kk
        C_new = jnp.exp(total + m - m_new)[..., None, None] * C + \
            jnp.einsum("bthd,bthe->bhde", kw, vv)
        n_new = jnp.exp(total + m - m_new)[..., None] * n + kw.sum(axis=1)
        return (C_new, n_new, m_new), out

    (C, n, m), outs = jax.lax.scan(step, (C0, n0, m0),
                                   (qc, kc, vc, lfc, lic))
    out = outs.swapaxes(0, 1).reshape(B, S, H, D)
    return out.astype(dt), (C, n, m)


def mlstm_step(q, k, v, i_pre, f_pre, state):
    """O(1) decode step.  q,k,v: (B,1,H,D); returns (out, new_state)."""
    out, state = mlstm_scan(q, k, v, i_pre, f_pre, chunk=1, state=state)
    return out, state


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, exponential gating, R = 0), parallel via assoc scans
# ---------------------------------------------------------------------------


def slstm_scan(z, o_pre, i_pre, f_pre, *, chunk: int = 1024, state=None):
    """z, o_pre, i_pre, f_pre: (B, S, D).  Returns (out, state)."""
    B, S, D = z.shape
    dt = z.dtype
    zf = jnp.tanh(z.astype(jnp.float32))
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    li = i_pre.astype(jnp.float32)
    if state is None:
        c0 = jnp.zeros((B, D), jnp.float32)
        n0 = jnp.zeros((B, D), jnp.float32)
        m0 = jnp.full((B, D), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state
    # stabilizer scan: m_t = max(m_{t-1} + lf_t, li_t)
    li_eff = jnp.concatenate(
        [jnp.maximum(m0 + lf[:, 0], li[:, 0])[:, None], li[:, 1:]], axis=1)
    _, m = chunked_assoc_scan(_maxplus_op, (lf, li_eff), 1, chunk)
    m_prev = jnp.concatenate([m0[:, None], m[:, :-1]], axis=1)
    a = jnp.exp(lf + m_prev - m)                        # decay coefficient
    bi = jnp.exp(li - m)                                # input coefficient
    # NB: eps must stay in the f32 *normal* range — XLA flushes subnormals
    # to zero, which would make the log -inf and its gradient non-finite.
    la = jnp.log(jnp.maximum(a, 1e-30))
    c0_term = jnp.concatenate(
        [(a[:, 0] * c0 + bi[:, 0] * zf[:, 0])[:, None],
         (bi * zf)[:, 1:]], axis=1)
    n0_term = jnp.concatenate(
        [(a[:, 0] * n0 + bi[:, 0])[:, None], bi[:, 1:]], axis=1)
    _, c = chunked_assoc_scan(_decay_op, (la, c0_term), 1, chunk)
    _, n = chunked_assoc_scan(_decay_op, (la, n0_term), 1, chunk)
    h = jax.nn.sigmoid(o_pre.astype(jnp.float32)) * c / jnp.maximum(
        jnp.abs(n), 1.0)
    return h.astype(dt), (c[:, -1], n[:, -1], m[:, -1])


# ---------------------------------------------------------------------------
# Mamba-style selective heads (Hymba)
# ---------------------------------------------------------------------------


def mamba_scan(u, dt_pre, bmat, cmat, a_log, *, chunk: int = 128,
               state=None):
    """u: (B,S,H,P); dt_pre: (B,S,H); bmat/cmat: (B,S,N); a_log: (H,).
    h_t = exp(-exp(a_log)·dt)·h_{t-1} + dt·u_t⊗B_t ;  y_t = h_t·C_t.

    The (B, chunk, H, P, N) per-position states are materialized one chunk at
    a time inside the ``lax.scan`` (never the full sequence).
    """
    B, S, H, Pd = u.shape
    N = bmat.shape[-1]
    dtp = u.dtype
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    resh = lambda t: t.astype(jnp.float32).reshape(
        B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    uc, dtc, bc, cc = map(resh, (u, dt_pre, bmat, cmat))
    a = -jnp.exp(a_log.astype(jnp.float32))
    h0 = jnp.zeros((B, H, Pd, N), jnp.float32) if state is None \
        else state.astype(jnp.float32)

    def step(h, ch):
        uf, dtp_, bm, cm = ch                              # (B,chunk,...)
        dtv = jax.nn.softplus(dtp_)                        # (B,chunk,H)
        la = a[None, None] * dtv                           # log decay
        x = dtv[..., None, None] * uf[..., :, None] * bm[:, :, None, None, :]
        la_b = jnp.broadcast_to(la[..., None, None], x.shape)
        _, hs = jax.lax.associative_scan(_decay_op, (la_b, x), axis=1)
        cum_la = jnp.cumsum(la, axis=1)
        hs = hs + jnp.exp(cum_la)[..., None, None] * h[:, None]
        y = jnp.einsum("bshpn,bsn->bshp", hs, cm)
        return hs[:, -1], y

    h, ys = jax.lax.scan(step, h0, (uc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(B, S, H, Pd)
    return y.astype(dtp), h


def mamba_step(u, dt_pre, bmat, cmat, a_log, state):
    y, state = mamba_scan(u, dt_pre, bmat, cmat, a_log, chunk=1, state=state)
    return y, state


def mamba_scan_dual(u, dt_pre, bmat, cmat, a_log, *, chunk: int = 64,
                    state=None):
    """Mamba-2 *chunked dual form* (beyond-paper §Perf optimization for the
    memory-bound SSM scan): within a chunk the output is computed through an
    attention-like (T x T) score matrix — per-position (H, P, N) states are
    NEVER materialized; across chunks only the (B, H, P, N) boundary state is
    carried.  ~4x more FLOPs per token than the state-materializing form but
    ~8x less HBM traffic at (H, P, N) = (25, 64, 16) — the right trade for a
    bandwidth-bound op.  Numerically identical (tested vs the naive
    recurrence)."""
    B, S, H, Pd = u.shape
    N = bmat.shape[-1]
    dtp = u.dtype
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    resh = lambda t: t.astype(jnp.float32).reshape(
        B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)
    uc, dtc, bc, cc = map(resh, (u, dt_pre, bmat, cmat))
    a = -jnp.exp(a_log.astype(jnp.float32))
    h0 = jnp.zeros((B, H, Pd, N), jnp.float32) if state is None \
        else state.astype(jnp.float32)
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(h, ch):
        uf, dtp_, bm, cm = ch                           # (B,T,...)
        dtv = jax.nn.softplus(dtp_)                     # (B,T,H)
        la = a[None, None] * dtv
        cum = jnp.cumsum(la, axis=1)                    # (B,T,H) inclusive
        scores = jnp.einsum("btn,bsn->bts", cm, bm)     # (B,T,T)
        decay = jnp.exp(jnp.clip(cum[:, :, None] - cum[:, None, :],
                                 -60.0, 0.0))           # (B,T,T,H)
        w = scores[..., None] * decay * dtv[:, None]    # dt_s broadcast
        w = jnp.where(tril[None, :, :, None], w, 0.0)
        y = jnp.einsum("btsh,bshp->bthp", w, uf)
        y = y + jnp.exp(cum)[..., None] * \
            jnp.einsum("btn,bhpn->bthp", cm, h)
        total = cum[:, -1]                              # (B,H)
        kw = jnp.exp(total[:, None] - cum) * dtv        # (B,T,H)
        h_new = jnp.exp(total)[..., None, None] * h + \
            jnp.einsum("bth,bthp,btn->bhpn", kw, uf, bm)
        return h_new, y

    h, ys = jax.lax.scan(step, h0, (uc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(B, S, H, Pd)
    return y.astype(dtp), h


# ---------------------------------------------------------------------------
# Block-level sublayers + params
# ---------------------------------------------------------------------------


def init_mlstm_params(key, cfg, d: int) -> dict:
    H = cfg.num_heads
    dh = 2 * d // H
    ks = jax.random.split(key, 8)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d), 0, pd),    # mLSTM branch
        "w_z": dense_init(ks[1], (d, 2 * d), 0, pd),     # gate branch
        "wq": dense_init(ks[2], (2 * d, H * dh), 0, pd),
        "wk": dense_init(ks[3], (2 * d, H * dh), 0, pd),
        "wv": dense_init(ks[4], (2 * d, H * dh), 0, pd),
        "wif": dense_init(ks[5], (2 * d, 2 * H), 0, pd),
        "f_bias": jnp.full((H,), 3.0, pd),               # open forget gates
        "w_down": dense_init(ks[6], (2 * d, d), 0, pd),
    }


def mlstm_sublayer(x, p, cfg, *, state=None, chunk=256):
    B, S, d = x.shape
    dt = x.dtype
    H = cfg.num_heads
    dh = 2 * d // H
    u = x @ p["w_up"].astype(dt)                          # (B,S,2d)
    z = x @ p["w_z"].astype(dt)
    q = (u @ p["wq"].astype(dt)).reshape(B, S, H, dh)
    k = (u @ p["wk"].astype(dt)).reshape(B, S, H, dh)
    v = (u @ p["wv"].astype(dt)).reshape(B, S, H, dh)
    i_f = (u @ p["wif"].astype(dt)).reshape(B, S, 2, H)
    i_pre = i_f[:, :, 0]
    f_pre = i_f[:, :, 1] + p["f_bias"].astype(dt)[None, None]
    if state is None and S > 1:
        out, new_state = mlstm_scan(q, k, v, i_pre, f_pre, chunk=chunk)
    else:
        out, new_state = mlstm_step(q, k, v, i_pre, f_pre, state)
    out = out.reshape(B, S, 2 * d) * jax.nn.silu(z)
    return (out @ p["w_down"].astype(dt)), new_state


def init_slstm_params(key, cfg, d: int) -> dict:
    ks = jax.random.split(key, 6)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "w_zifo": dense_init(ks[0], (d, 4 * d), 0, pd),
        "f_bias": jnp.full((d,), 3.0, pd),
        "w_up1": dense_init(ks[1], (d, 2 * d), 0, pd),   # post-GLU FFN
        "w_up2": dense_init(ks[2], (d, 2 * d), 0, pd),
        "w_down": dense_init(ks[3], (2 * d, d), 0, pd),
    }


def slstm_sublayer(x, p, cfg, *, state=None, chunk=1024):
    B, S, d = x.shape
    dt = x.dtype
    zifo = (x @ p["w_zifo"].astype(dt)).reshape(B, S, 4, d)
    z, i_pre, f_pre, o_pre = (zifo[:, :, j] for j in range(4))
    f_pre = f_pre + p["f_bias"].astype(dt)[None, None]
    h, new_state = slstm_scan(z, o_pre, i_pre, f_pre, chunk=min(chunk, S),
                              state=state)
    # post up-projection GLU (xLSTM sLSTM block)
    y = jax.nn.silu(h @ p["w_up1"].astype(dt)) * (h @ p["w_up2"].astype(dt))
    return y @ p["w_down"].astype(dt), new_state


def init_mamba_params(key, cfg, d: int) -> dict:
    H = cfg.ssm_heads
    dh = cfg.resolved_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "w_in": dense_init(ks[0], (d, H * dh), 0, pd),
        "w_dt": dense_init(ks[1], (d, H), 0, pd),
        "dt_bias": jnp.zeros((H,), pd),
        "w_b": dense_init(ks[2], (d, N), 0, pd),
        "w_c": dense_init(ks[3], (d, N), 0, pd),
        "a_log": jnp.zeros((H,), pd),
        "d_skip": jnp.ones((H, 1), pd),
        "w_out": dense_init(ks[4], (H * dh, d), 0, pd),
    }


def mamba_sublayer(x, p, cfg, *, state=None, chunk=256):
    B, S, d = x.shape
    dt = x.dtype
    H, dh = cfg.ssm_heads, cfg.resolved_head_dim
    u = (x @ p["w_in"].astype(dt)).reshape(B, S, H, dh)
    dt_pre = x @ p["w_dt"].astype(dt) + p["dt_bias"].astype(dt)
    bmat = x @ p["w_b"].astype(dt)
    cmat = x @ p["w_c"].astype(dt)
    if state is None and S > 1:
        scan = mamba_scan_dual if cfg.mamba_dual else mamba_scan
        y, new_state = scan(u, dt_pre, bmat, cmat, p["a_log"],
                            chunk=min(chunk, S))
    else:
        y, new_state = mamba_step(u, dt_pre, bmat, cmat, p["a_log"], state)
    y = y + p["d_skip"].astype(dt)[None, None] * u
    return (y.reshape(B, S, H * dh) @ p["w_out"].astype(dt)), new_state

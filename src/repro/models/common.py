"""Shared model building blocks: norms, RoPE, init, sharding hooks."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq     # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                           # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """LeCun-normal init (fan-in over ``in_axis``)."""
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def shard(x: jax.Array, spec: P | None):
    """Apply a sharding constraint when running under a mesh; no-op off-mesh."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except ValueError:
        return x  # no mesh in scope (single-device tests)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(logits / cap) if cap else logits

"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md
§Roofline).

Three terms per (arch x shape x mesh), in seconds:
  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI link bw

``cost_analysis()`` runs on the *partitioned per-device* module, so its flops
and bytes are already per-chip.  Collective bytes are not in cost_analysis —
we parse the compiled HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

The same intensity model also drives kernel tiling:
:func:`select_moe_tiles` picks the ``bl``/``bh`` work-item tile sizes for
the gather-GMM / fused-MoE kernels from the ridge point instead of
hard-coded 128s.
"""

from __future__ import annotations

import re

from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW_PER_LINK,
                               PEAK_FLOPS_BF16)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum tensor bytes per collective kind from HLO text.

    HLO operands are ``%ref``s without inline shapes, so we use the *result*
    shape (tuples summed) — the full-tensor size, which is the standard
    per-device ring-transfer proxy (~1x tensor bytes for AG/RS, ~2x for AR;
    we count 1x uniformly and note it in EXPERIMENTS.md).

    NOTE: ops inside a ``while`` body (layer scan) appear once in the text;
    callers must apply the trip-count extrapolation (see dryrun.run_one).
    """
    stats = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?%[\w.-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)"
            r"\s+([a-z-]+)\(", line)
        if not m or m.group(2) not in _COLLECTIVES:
            continue
        kind = m.group(2)
        counts[kind] += 1
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            if dt in _DTYPE_BYTES:
                stats[kind] += _shape_bytes(dt, dims)
    return {"bytes": stats, "counts": counts,
            "total_bytes": sum(stats.values()),
            "total_count": sum(counts.values())}


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) — the useful-compute yardstick."""
    import jax
    from repro.launch.specs import params_shapes
    ps = params_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(ps)[0]
    n_total = 0
    n_expert = 0
    for path, leaf in flat:
        keys = "/".join(
            str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        if keys == "embed":
            continue  # embedding lookup is a gather, not a matmul
        size = 1
        for s in leaf.shape:
            size *= s
        if cfg.is_moe and "/moe/" in f"/{keys}/".replace("//", "/"):
            n_expert += size
        else:
            n_total += size
    if cfg.is_moe and cfg.num_experts:
        n_active = n_total + n_expert * cfg.top_k / cfg.num_experts
    else:
        n_active = n_total
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd + 2x bwd
    return 2.0 * n_active * tokens * mult


def analyze_compiled(compiled, cfg, shape, *, n_chips: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    peak = arg_b + out_b + tmp_b - alias_b
    coll = collective_stats(compiled.as_text())
    mf = model_flops(cfg, shape)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hlo_bytes / HBM_BW
    t_coll = coll["total_bytes"] / ICI_BW_PER_LINK
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_dev": flops,
        "hlo_bytes_per_dev": hlo_bytes,
        "collective_bytes": coll["total_bytes"],
        "collective_counts": coll["counts"],
        "collective_bytes_by_kind": coll["bytes"],
        "arg_bytes": arg_b, "out_bytes": out_b, "temp_bytes": tmp_b,
        "peak_bytes": peak, "fits_hbm": bool(peak <= HBM_BYTES),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(flops * n_chips, 1.0),
        "n_chips": n_chips,
    }


def select_moe_tiles(n_rows: int, d: int, h: int, *, dtype_bytes: int = 2,
                     num_experts: int | None = None,
                     vmem_limit_bytes: int = 8 * 1024 * 1024
                     ) -> tuple[int, int]:
    """Arithmetic-intensity-driven ``(bl, bh)`` tile selection for the
    gather-GMM / fused-MoE work-item kernels.

    A work-item step multiplies a ``(bl, d)`` row tile against ``(d, bh)``
    weight blocks (plus the ``(bh, d)`` down-projection in the fused path).
    Its arithmetic intensity is

        AI(bl, bh) = 2·bl·bh·d / ((bl·d + 2·d·bh + bh·d)·dtype_bytes)

    and the kernel stops being HBM-bound once AI exceeds the hardware ridge
    point ``PEAK_FLOPS_BF16 / HBM_BW`` (~240 flops/byte for the modeled
    chip).  We scan MXU-aligned candidates (multiples of 128, largest first
    per axis so ties break toward squarer tiles), keep those whose per-step
    VMEM footprint — gathered rows + three weight blocks + the fp32 partial
    accumulator and elementwise temps — fits ``vmem_limit_bytes``, and pick
    the *smallest* tile pair that reaches the ridge (beyond it, bigger tiles
    only add VMEM pressure and tail waste).  If nothing reaches the ridge
    (small ``d``), pick the max-AI candidate that fits.  The kernels still
    clamp: ``bh`` to the largest divisor of ``h``, ``bl`` to the padded row
    count — the returned pair is a *request*, exactly like the literals it
    replaces.

    When ``num_experts`` is given and the active JAX backend is CPU (the
    interpret-mode CI), ``bl`` is additionally shrunk for expert-boundary
    fragmentation — see the inline comment.
    """
    ridge = PEAK_FLOPS_BF16 / HBM_BW
    cands = []
    for bl in (128, 256, 512):
        for bh in (128, 256, 512):
            vmem = ((bl * d + 2 * d * bh + bh * d) * dtype_bytes
                    + bl * d * 4          # fp32 partial accumulator
                    + 3 * bl * bh * 4)    # a / b / y_swi fp32 temps
            if vmem > vmem_limit_bytes:
                continue
            ai = (2.0 * bl * bh * d
                  / ((bl * d + 2 * d * bh + bh * d) * dtype_bytes))
            cands.append((ai, bl, bh, bl * bh))
    if not cands:
        return 128, min(128, max(8, h))
    reaching = [c for c in cands if c[0] >= ridge]
    if reaching:
        _, bl, bh, _ = min(reaching, key=lambda c: (c[3], c[1]))
    else:
        _, bl, bh, _ = max(cands, key=lambda c: (c[0], -c[3]))
    # No point tiling beyond the problem: shrink toward the actual extents
    # (the kernel would clamp anyway; doing it here keeps the request honest).
    while bl > 128 and bl // 2 >= n_rows:
        bl //= 2
    while bh > 128 and bh // 2 >= h:
        bh //= 2
    # Expert-boundary fragmentation: the work-item scheme runs one full
    # (bl, ·) tile per expert boundary even when that item covers a handful
    # of slots, so total GEMM work scales like ``n_rows + E·bl``.  On TPU
    # the memory side (weight restreaming ∝ n_tiles + E) rewards big tiles
    # regardless, but under the CPU interpreter wall time tracks flops —
    # shrink ``bl`` until the masked-tile waste stops dominating the real
    # rows.  TPU tile selection is unchanged.
    import jax                     # deferred: roofline stays importable fast
    if num_experts and jax.default_backend() == "cpu":
        while bl > 32 and num_experts * bl >= 2 * n_rows:
            bl //= 2
    return bl, bh


def bench_entries(analysis: dict, prefix: str) -> list:
    """Project an ``analyze_compiled`` dict into ``repro.bench.record``
    entries so roofline-model numbers and measured numbers land in the same
    tracked report (``BENCH_memory.json``)."""
    from repro.bench.record import entry

    meta = {"dominant": analysis["dominant"], "n_chips": analysis["n_chips"]}
    return [
        entry(f"{prefix}/flops", analysis["flops_per_dev"],
              kind="flops", unit="flop", tolerance_pct=20.0, **meta),
        entry(f"{prefix}/hlo_bytes", analysis["hlo_bytes_per_dev"],
              kind="bytes_accessed", unit="bytes", tolerance_pct=100.0),
        entry(f"{prefix}/peak_bytes", analysis["peak_bytes"],
              kind="peak_bytes", unit="bytes", tolerance_pct=100.0),
        entry(f"{prefix}/t_compute", analysis["t_compute_s"],
              kind="roofline_s", unit="s"),
        entry(f"{prefix}/t_memory", analysis["t_memory_s"],
              kind="roofline_s", unit="s"),
        entry(f"{prefix}/t_collective", analysis["t_collective_s"],
              kind="roofline_s", unit="s"),
    ]

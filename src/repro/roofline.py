"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md
§Roofline).

Three terms per (arch x shape x mesh), in seconds:
  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI link bw

``cost_analysis()`` runs on the *partitioned per-device* module, so its flops
and bytes are already per-chip.  Collective bytes are not in cost_analysis —
we parse the compiled HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

The same intensity model also drives kernel tiling:
:func:`select_moe_tiles` picks the ``bl``/``bh`` work-item tile sizes for
the gather-GMM / fused-MoE kernels from the ridge point instead of
hard-coded 128s.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW_PER_LINK,
                               PEAK_FLOPS_BF16, axis_bandwidth)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum tensor bytes per collective kind from HLO text.

    HLO operands are ``%ref``s without inline shapes, so we use the *result*
    shape (tuples summed) — the full-tensor size, which is the standard
    per-device ring-transfer proxy (~1x tensor bytes for AG/RS, ~2x for AR;
    we count 1x uniformly and note it in EXPERIMENTS.md).

    NOTE: ops inside a ``while`` body (layer scan) appear once in the text;
    callers must apply the trip-count extrapolation (see dryrun.run_one).
    """
    stats = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"(?:ROOT\s+)?%[\w.-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)"
            r"\s+([a-z-]+)\(", line)
        if not m or m.group(2) not in _COLLECTIVES:
            continue
        kind = m.group(2)
        counts[kind] += 1
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            if dt in _DTYPE_BYTES:
                stats[kind] += _shape_bytes(dt, dims)
    return {"bytes": stats, "counts": counts,
            "total_bytes": sum(stats.values()),
            "total_count": sum(counts.values())}


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) — the useful-compute yardstick."""
    import jax
    from repro.launch.specs import params_shapes
    ps = params_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(ps)[0]
    n_total = 0
    n_expert = 0
    for path, leaf in flat:
        keys = "/".join(
            str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        if keys == "embed":
            continue  # embedding lookup is a gather, not a matmul
        size = 1
        for s in leaf.shape:
            size *= s
        if cfg.is_moe and "/moe/" in f"/{keys}/".replace("//", "/"):
            n_expert += size
        else:
            n_total += size
    if cfg.is_moe and cfg.num_experts:
        n_active = n_total + n_expert * cfg.top_k / cfg.num_experts
    else:
        n_active = n_total
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd + 2x bwd
    return 2.0 * n_active * tokens * mult


def analyze_compiled(compiled, cfg, shape, *, n_chips: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    peak = arg_b + out_b + tmp_b - alias_b
    coll = collective_stats(compiled.as_text())
    mf = model_flops(cfg, shape)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hlo_bytes / HBM_BW
    t_coll = coll["total_bytes"] / ICI_BW_PER_LINK
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_dev": flops,
        "hlo_bytes_per_dev": hlo_bytes,
        "collective_bytes": coll["total_bytes"],
        "collective_counts": coll["counts"],
        "collective_bytes_by_kind": coll["bytes"],
        "arg_bytes": arg_b, "out_bytes": out_b, "temp_bytes": tmp_b,
        "peak_bytes": peak, "fits_hbm": bool(peak <= HBM_BYTES),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(flops * n_chips, 1.0),
        "n_chips": n_chips,
    }


# ---------------------------------------------------------------------------
# MoE-parallelism collective cost model (README "Distribution modes")
# ---------------------------------------------------------------------------
#
# ``moe_parallel="auto"`` is resolved by ranking the candidate modes with the
# same three-term roofline used for compiled modules, evaluated analytically
# per mode on ONE MoE layer at the per-device token slab:
#
#   compute    = grouped-GEMM + gating + dispatch-build flops / peak FLOP/s
#   memory     = working-set HBM traffic (2x the dispatch/GEMM buffers, one
#                read of the local weight bank) / HBM bw
#   collective = bytes-on-wire per axis / that axis's bandwidth — a psum ring
#                moves 2*(n-1)/n of the tensor per device; an a2a hop moves
#                (n-1)/n of each capacity buffer each way.  'node'/'pod' axes
#                are charged at DCN bandwidth, 'model' at ICI (the two tiers
#                the hierarchical two-hop a2a is built around).
#
# Buffer row counts come from ``core.memsim`` so the predictor and the peak
# simulator can never disagree about what a mode allocates.  The measured
# half of the loop is ``collective_stats`` below: dryrun parses the compiled
# HLO and prints predicted-vs-measured bytes per collective kind.

#: modes the optimizer ranks, in deterministic tie-break preference order
#: (earlier wins when predicted costs tie).
MOE_MODE_ORDER = ("ep", "ep_a2a_hier", "ep_a2a", "tp")

#: a mode within this fraction of the fastest predicted time is a candidate;
#: among candidates the lowest per-device live bytes wins (the memory wall
#: is the binding constraint the paper optimizes).
AUTO_TIME_SLACK = 0.10

#: live-bytes spread below this is noise — prefer the faster/earlier mode
#: instead (keeps tiny decode slabs on ``ep`` where a2a latency dominates).
AUTO_LIVE_EPS = 8 * 1024 * 1024

#: per-device slab used to rank modes when the caller has no token count yet
#: (construction-time resolution; trace-time calls pass the real slab).
DEFAULT_AUTO_TOKENS = 4096

#: int ops per routing slot per pass of the sort-free one-hot/cumsum
#: dispatch build, charged as flops (one-hot + cumsum + offset gather).
_DISPATCH_PASSES = 3.0


@dataclass(frozen=True)
class ParallelCost:
    """One row of the ``auto`` decision table: predicted per-layer cost of
    running the MoE sublayer under ``mode`` on this config x mesh."""

    mode: str
    feasible: bool
    why: str                    # infeasibility reason ("" when feasible)
    t_compute_s: float
    t_memory_s: float
    t_collective_s: float
    t_total_s: float
    live_bytes: int             # per-device transient working set + buffers
    a2a_bytes: int              # predicted bytes-on-wire, all_to_all
    psum_bytes: int             # predicted bytes-on-wire, psum combine
    chosen: bool = False

    def row(self) -> dict:
        """JSON-ready record row (dryrun decision table)."""
        return {
            "mode": self.mode, "feasible": self.feasible, "why": self.why,
            "t_compute_s": self.t_compute_s, "t_memory_s": self.t_memory_s,
            "t_collective_s": self.t_collective_s,
            "t_total_s": self.t_total_s, "live_bytes": self.live_bytes,
            "a2a_bytes": self.a2a_bytes, "psum_bytes": self.psum_bytes,
            "chosen": self.chosen,
        }


@dataclass(frozen=True)
class ParallelDecision:
    """Resolved MoE distribution with provenance (mirrors
    ``gmm_backend.ResolvedBackend``): the concrete mode, where it came from
    (``config`` = forced, ``auto`` = cost model, ``single`` = no mesh or a
    1-way expert axis), and the full predicted-cost table it was ranked
    from."""

    mode: str                   # single | ep | ep_a2a | ep_a2a_hier | tp
    source: str                 # "config" | "auto" | "single"
    table: tuple            # ParallelCost rows, MOE_MODE_ORDER order
    n_tokens: int               # per-device slab the table was ranked at
    mesh_axes: tuple        # ((axis, size), ...) of the mesh ranked against

    def table_rows(self) -> list:
        return [c.row() for c in self.table]


def _psum_cost(n_tokens: int, d: int, it: int, axes) -> tuple[int, float]:
    """(bytes-on-wire, seconds) of psum-combining a (L, d) partial over the
    given ``(axis_name, size)`` pairs: ring all-reduce per axis, the slow
    (cross-node) axis charged at DCN bandwidth."""
    bytes_total, t = 0, 0.0
    for axis, n in axes:
        if n <= 1:
            continue
        b = int(2 * (n - 1) / n * n_tokens * d * it)
        bytes_total += b
        t += b / axis_bandwidth(axis)
    return bytes_total, t


def _a2a_hop_cost(rows: int, n: int, d: int, it: int, axis: str
                  ) -> tuple[int, float]:
    """(bytes-on-wire, seconds) of one capacity-bounded token exchange over
    ``axis``: ``rows`` buffer rows of width d cross the wire twice (x out,
    y back), (n-1)/n of them leaving the device."""
    if n <= 1:
        return 0, 0.0
    b = int(2 * rows * (n - 1) / n * d * it)
    return b, b / axis_bandwidth(axis)


def moe_parallel_costs(cfg, *, n_model: int, n_node: int = 1,
                       n_tokens: int) -> tuple:
    """Predicted :class:`ParallelCost` rows for every rankable mode of
    (cfg, expert axes, per-device slab).  Pure arithmetic — no jax."""
    from repro.core import memsim

    E, k, d, h = cfg.num_experts, cfg.top_k, cfg.d_model, cfg.moe_d_ff
    it = memsim._itemsize(cfg.dtype)
    n_exp = max(n_model, 1) * max(n_node, 1)
    L = max(int(n_tokens), 1)
    n_mat = 3 if cfg.ffn_act == "swiglu" else 2
    chunks = max(int(getattr(cfg, "moe_a2a_chunks", 1)), 1)

    def tile_pen(width: float) -> float:
        """MXU lane quantization (same 128-lane alignment that drives
        :func:`select_moe_tiles`): a GEMM whose minor dim is ``width`` pads
        to the next 128 multiple and runs at ``width / pad`` of peak."""
        if width <= 0:
            return 1.0
        return float(-(-int(width) // 128) * 128) / float(width)

    def gemm_time(h_eff: float) -> float:
        """Per-device grouped-GEMM seconds: ep/a2a split rows expert-wise at
        full matrix widths, tp keeps every row but slices the expert hidden
        dim to ``h_eff`` — sub-tile slivers burn MXU lanes, which is what
        makes tp lose to expert parallelism at small per-device h."""
        base = 2.0 * n_mat * L * k * d * h / n_exp
        pen = ((n_mat - 1) * tile_pen(h_eff) + tile_pen(d)) / n_mat
        return base * pen / PEAK_FLOPS_BF16

    w_bytes = n_mat * E * d * h * it / n_exp       # one local-bank read

    def feas(mode: str) -> str:
        if n_exp <= 1 and mode != "tp":
            return "expert axes are 1-way"
        if mode in ("ep", "ep_a2a", "ep_a2a_hier"):
            if E % n_exp:
                return f"E={E} not divisible by {n_exp} expert ways"
        if mode in ("ep_a2a", "ep_a2a_hier") and L % n_exp:
            return f"{L} tokens/device not divisible by {n_exp} ranks"
        if mode == "ep_a2a" and n_node > 1:
            return "flat a2a on a node mesh (use ep_a2a_hier)"
        if mode == "ep_a2a_hier" and n_node <= 1:
            return "mesh declares no 'node' axis"
        if mode == "tp" and n_model > 1 and h % n_model:
            return f"moe_d_ff={h} not divisible by n_model={n_model}"
        return ""

    rows_out = []
    for mode in MOE_MODE_ORDER:
        why = feas(mode)
        t_gemm = gemm_time(h / n_model if mode == "tp" else h)
        s = memsim.moe_layer_sizes(cfg, L, mode=mode, n_model=n_model,
                                   n_node=n_node)
        # tokens this device gates/routes, and dispatch-build work
        if mode in ("ep_a2a", "ep_a2a_hier"):
            tm = max(L // n_exp, 1)
        else:
            tm = L
        if mode == "ep_a2a":
            rows = memsim._a2a_rows(cfg, L, n_exp)
            disp_ops = tm * k * n_exp + rows * (E // max(n_exp, 1) + 1)
            a2a_b, t_a2a = _a2a_hop_cost(rows, n_exp, d, it, "model")
        elif mode == "ep_a2a_hier":
            r1, r2 = memsim._a2a_hier_rows(cfg, L, n_node, n_model)
            rows = r2
            disp_ops = (tm * k * n_model + r1 * (n_node + 1)
                        + r2 * (E // max(n_exp, 1) + 1))
            b1, t1 = _a2a_hop_cost(r1, n_model, d, it, "model")
            b2, t2 = _a2a_hop_cost(r2, n_node, d, it, "node")
            a2a_b, t_a2a = b1 + b2, t1 + t2
        else:
            rows = L * k
            disp_ops = tm * k * E
            a2a_b, t_a2a = 0, 0.0
        flops_other = 2.0 * tm * d * E + _DISPATCH_PASSES * disp_ops
        # psum axes: expert modes combine over every expert axis; tp's
        # hidden-sharded partials combine over 'model' only (node replicas,
        # when present, already agree).
        if mode in ("ep", "ep_a2a", "ep_a2a_hier"):
            psum_axes = (("node", n_node), ("model", n_model))
        else:
            psum_axes = (("model", n_model),)
        psum_b, t_psum = _psum_cost(L, d, it, psum_axes)
        hbm = 2.0 * (s.moe_other + s.moe_vjp) + w_bytes
        t_compute = t_gemm + flops_other / PEAK_FLOPS_BF16
        t_memory = hbm / HBM_BW
        t_coll = t_a2a + t_psum
        if mode == "ep_a2a" and chunks > 1:
            # Double-buffered chunks let chunk i's exchange ride under
            # chunk i-1's grouped GEMM: only the pipeline-fill fraction of
            # the smaller of the two stays exposed.
            overlapped = min(t_a2a, t_gemm)
            t_total = (t_compute + t_memory + t_psum
                       + max(t_a2a, t_gemm) - t_gemm
                       + overlapped / chunks)
        else:
            t_total = t_compute + t_memory + t_coll
        live = s.moe_other + s.moe_vjp + s.moe_x + s.collective
        rows_out.append(ParallelCost(
            mode=mode, feasible=not why, why=why,
            t_compute_s=t_compute, t_memory_s=t_memory,
            t_collective_s=t_coll, t_total_s=t_total,
            live_bytes=int(live), a2a_bytes=a2a_b, psum_bytes=psum_b))
    return tuple(rows_out)


def select_moe_parallel(cfg, mesh, n_tokens: int | None = None
                        ) -> ParallelDecision:
    """Rank the MoE distribution modes for (cfg, mesh, per-device slab) and
    resolve ``cfg.moe_parallel`` to a concrete mode with provenance.

    ``auto`` picks the fastest predicted mode, except that any feasible mode
    within :data:`AUTO_TIME_SLACK` of the fastest whose per-device live
    bytes are *materially* lower (> :data:`AUTO_LIVE_EPS` spread) wins the
    tie — predicted step cost first, memory wall second, exactly the
    ordering the paper's measurements justify.  Forced modes are passed
    through (validation lives in ``resolve_moe_parallel``) with the same
    table attached for provenance.
    """
    if mesh is None or not getattr(cfg, "is_moe", False):
        return ParallelDecision(mode="single", source="single", table=(),
                                n_tokens=int(n_tokens or 0), mesh_axes=())
    n_model = mesh.shape.get("model", 1)
    n_node = mesh.shape.get("node", 1)
    L = int(n_tokens) if n_tokens else DEFAULT_AUTO_TOKENS
    table = moe_parallel_costs(cfg, n_model=n_model, n_node=n_node,
                               n_tokens=L)
    mesh_axes = tuple((a, mesh.shape[a]) for a in mesh.axis_names)
    if cfg.moe_parallel != "auto":
        mode, source = cfg.moe_parallel, "config"
    else:
        source = "auto"
        feasible = [c for c in table if c.feasible]
        ep_like = [c for c in feasible if c.mode != "tp"]
        if not ep_like and n_model * n_node > 1:
            mode = "tp"           # legacy fallback: E doesn't divide -> tp
        elif not feasible:
            mode = "tp"
        else:
            t0 = min(c.t_total_s for c in feasible)
            cands = [c for c in feasible
                     if c.t_total_s <= t0 * (1.0 + AUTO_TIME_SLACK)]
            spread = (max(c.live_bytes for c in cands)
                      - min(c.live_bytes for c in cands))
            if spread > AUTO_LIVE_EPS:
                mode = min(cands, key=lambda c: c.live_bytes).mode
            else:
                # Sub-slack, sub-material differences are noise: take the
                # earliest candidate in MOE_MODE_ORDER (ep before the a2a
                # variants — no exchange machinery for no measurable win).
                order = {m: i for i, m in enumerate(MOE_MODE_ORDER)}
                mode = min(cands, key=lambda c: order[c.mode]).mode
    import dataclasses
    table = tuple(dataclasses.replace(c, chosen=c.mode == mode)
                  for c in table)
    return ParallelDecision(mode=mode, source=source, table=table,
                            n_tokens=L, mesh_axes=mesh_axes)


def select_moe_tiles(n_rows: int, d: int, h: int, *, dtype_bytes: int = 2,
                     num_experts: int | None = None,
                     vmem_limit_bytes: int = 8 * 1024 * 1024
                     ) -> tuple[int, int]:
    """Arithmetic-intensity-driven ``(bl, bh)`` tile selection for the
    gather-GMM / fused-MoE work-item kernels.

    A work-item step multiplies a ``(bl, d)`` row tile against ``(d, bh)``
    weight blocks (plus the ``(bh, d)`` down-projection in the fused path).
    Its arithmetic intensity is

        AI(bl, bh) = 2·bl·bh·d / ((bl·d + 2·d·bh + bh·d)·dtype_bytes)

    and the kernel stops being HBM-bound once AI exceeds the hardware ridge
    point ``PEAK_FLOPS_BF16 / HBM_BW`` (~240 flops/byte for the modeled
    chip).  We scan MXU-aligned candidates (multiples of 128, largest first
    per axis so ties break toward squarer tiles), keep those whose per-step
    VMEM footprint — gathered rows + three weight blocks + the fp32 partial
    accumulator and elementwise temps — fits ``vmem_limit_bytes``, and pick
    the *smallest* tile pair that reaches the ridge (beyond it, bigger tiles
    only add VMEM pressure and tail waste).  If nothing reaches the ridge
    (small ``d``), pick the max-AI candidate that fits.  The kernels still
    clamp: ``bh`` to the largest divisor of ``h``, ``bl`` to the padded row
    count — the returned pair is a *request*, exactly like the literals it
    replaces.

    When ``num_experts`` is given and the active JAX backend is CPU (the
    interpret-mode CI), ``bl`` is additionally shrunk for expert-boundary
    fragmentation — see the inline comment.
    """
    ridge = PEAK_FLOPS_BF16 / HBM_BW
    cands = []
    for bl in (128, 256, 512):
        for bh in (128, 256, 512):
            vmem = ((bl * d + 2 * d * bh + bh * d) * dtype_bytes
                    + bl * d * 4          # fp32 partial accumulator
                    + 3 * bl * bh * 4)    # a / b / y_swi fp32 temps
            if vmem > vmem_limit_bytes:
                continue
            ai = (2.0 * bl * bh * d
                  / ((bl * d + 2 * d * bh + bh * d) * dtype_bytes))
            cands.append((ai, bl, bh, bl * bh))
    if not cands:
        return 128, min(128, max(8, h))
    reaching = [c for c in cands if c[0] >= ridge]
    if reaching:
        _, bl, bh, _ = min(reaching, key=lambda c: (c[3], c[1]))
    else:
        _, bl, bh, _ = max(cands, key=lambda c: (c[0], -c[3]))
    # No point tiling beyond the problem: shrink toward the actual extents
    # (the kernel would clamp anyway; doing it here keeps the request honest).
    while bl > 128 and bl // 2 >= n_rows:
        bl //= 2
    while bh > 128 and bh // 2 >= h:
        bh //= 2
    # Expert-boundary fragmentation: the work-item scheme runs one full
    # (bl, ·) tile per expert boundary even when that item covers a handful
    # of slots, so total GEMM work scales like ``n_rows + E·bl``.  On TPU
    # the memory side (weight restreaming ∝ n_tiles + E) rewards big tiles
    # regardless, but under the CPU interpreter wall time tracks flops —
    # shrink ``bl`` until the masked-tile waste stops dominating the real
    # rows.  TPU tile selection is unchanged.
    import jax                     # deferred: roofline stays importable fast
    if num_experts and jax.default_backend() == "cpu":
        while bl > 32 and num_experts * bl >= 2 * n_rows:
            bl //= 2
    return bl, bh


def bench_entries(analysis: dict, prefix: str) -> list:
    """Project an ``analyze_compiled`` dict into ``repro.bench.record``
    entries so roofline-model numbers and measured numbers land in the same
    tracked report (``BENCH_memory.json``)."""
    from repro.bench.record import entry

    meta = {"dominant": analysis["dominant"], "n_chips": analysis["n_chips"]}
    return [
        entry(f"{prefix}/flops", analysis["flops_per_dev"],
              kind="flops", unit="flop", tolerance_pct=20.0, **meta),
        entry(f"{prefix}/hlo_bytes", analysis["hlo_bytes_per_dev"],
              kind="bytes_accessed", unit="bytes", tolerance_pct=100.0),
        entry(f"{prefix}/peak_bytes", analysis["peak_bytes"],
              kind="peak_bytes", unit="bytes", tolerance_pct=100.0),
        entry(f"{prefix}/t_compute", analysis["t_compute_s"],
              kind="roofline_s", unit="s"),
        entry(f"{prefix}/t_memory", analysis["t_memory_s"],
              kind="roofline_s", unit="s"),
        entry(f"{prefix}/t_collective", analysis["t_collective_s"],
              kind="roofline_s", unit="s"),
    ]

"""Pallas TPU flash-attention (forward) kernel.

Online-softmax attention with explicit VMEM tiling: grid
``(batch*heads, q_blocks, kv_blocks)`` with the KV dimension innermost — TPU
grids run sequentially per core, so the running max / denominator / output
accumulator live in VMEM scratch across KV steps and the output tile is
written once on the last step.  Supports causal masking, sliding windows and
logit softcap (gemma2).  Backward uses XLA autodiff over the pure-jnp
reference (attention backward is not a paper contribution; the fwd kernel is
the serving/prefill hot spot).

Validated in interpret mode against ``ref.py``/`models.attention` on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            nk: int, bq: int, bk: int, causal: bool, window: int,
            cap: float, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32) * scale           # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                   # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if cap:
        s = cap * jnp.tanh(s / cap)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           cap: float = 0.0, bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, S, H, Dh); k, v: (B, S, Hkv, Dh) with H % Hkv == 0.
    Returns (B, S, H, Dh)."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    # fold batch and heads; repeat kv heads across their query group
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, Dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, S, Dh)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk, causal=causal,
                          window=window, cap=cap, scale=Dh ** -0.5),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, Dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, Dh), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_fused(q, k, v, causal: bool = True, window: int = 0,
                          cap: float = 0.0):
    """Differentiable wrapper: Pallas kernel forward, XLA-autodiff of the
    chunked reference for backward (flash-bwd is not a paper hot spot;
    residuals are just q/k/v — O(S·d), no score matrix saved)."""
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  cap=cap)


def _fa_fwd(q, k, v, causal, window, cap):
    return flash_attention_fused(q, k, v, causal, window, cap), (q, k, v)


def _fa_bwd(causal, window, cap, res, do):
    from repro.models.attention import flash_attention
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: flash_attention(q, k, v, causal=causal,
                                        window=window, cap=cap,
                                        chunk=min(512, q.shape[1]),
                                        block_skip=False), q, k, v)
    return vjp(do)


flash_attention_fused.defvjp(_fa_fwd, _fa_bwd)

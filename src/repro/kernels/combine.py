"""Gather-of-partials combine kernel (paper §3.1 output aggregation).

Each token gathers its ``k`` partial expert outputs through
``token_index_map`` and contracts them with its gate weights — the
deterministic, gather-based TPU rendering of the paper's on-the-fly reduction
(no scatter, no materialized (L·k, d) buffer; see DESIGN.md §2).

This standalone kernel serves the *unfused* composition
(``kernels.ops.moe_ffn_blaze_pallas``).  The fused path
(``gather_gmm.fused_moe_fwd``) folds the same combine into the grouped-GEMM
grid pass as its epilogue — there the (S, d) partials input never exists.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _combine_kernel(tim_ref, p_ref, g_ref, y_ref, *, bl: int, k: int,
                    n_rows: int):
    t = pl.program_id(0)

    def row(r, _):
        tok = t * bl + r
        valid = tok < n_rows
        acc = jnp.zeros((1, p_ref.shape[1]), jnp.float32)
        for i in range(k):                       # k is small and static
            slot = jnp.where(valid, tim_ref[tok * k + i], 0)
            part = pl.load(p_ref, (pl.ds(slot, 1), slice(None)))
            acc = acc + g_ref[r, i].astype(jnp.float32) * \
                part.astype(jnp.float32)
        y_ref[pl.ds(r, 1), :] = acc.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bl, row, 0, unroll=False)


@functools.partial(jax.jit, static_argnames=("bl", "bd", "interpret"))
def combine(p_out: jax.Array, token_index_map: jax.Array, gates: jax.Array,
            *, bl: int = 128, bd: int = 512, interpret: bool = True):
    """(S, d) partials + (L, k) map + (L, k) gates -> (L, d) output.

    ``bd`` is clamped to the largest divisor of ``d`` (same contract as the
    ``bh`` clamp in ``gather_gmm``: any width traces, non-divisible ones
    just run a narrower tile)."""
    from repro.kernels.gather_gmm import largest_divisor_tile
    S, d = p_out.shape
    L, k = token_index_map.shape
    bl = min(bl, L)
    bd = largest_divisor_tile(d, bd)
    L_pad = ((L + bl - 1) // bl) * bl
    tim = token_index_map.reshape(-1).astype(jnp.int32)
    g = jnp.pad(gates, ((0, L_pad - L), (0, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(L_pad // bl, d // bd),
        in_specs=[
            pl.BlockSpec((S, bd), lambda t, dd, tim_r: (0, dd)),
            pl.BlockSpec((bl, k), lambda t, dd, tim_r: (t, 0)),
        ],
        out_specs=pl.BlockSpec((bl, bd), lambda t, dd, tim_r: (t, dd)),
    )
    y = pl.pallas_call(
        functools.partial(_combine_kernel, bl=bl, k=k, n_rows=L),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L_pad, d), p_out.dtype),
        interpret=interpret,
    )(tim, p_out, g)
    return y[:L]

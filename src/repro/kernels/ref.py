"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.routing import Dispatch, build_dispatch


def silu(a):
    return a * jax.nn.sigmoid(a)


def fused_swiglu_fwd_ref(x, w1, w2):
    a = (x.astype(jnp.float32) @ w1.astype(jnp.float32))
    b = (x.astype(jnp.float32) @ w2.astype(jnp.float32))
    y = silu(a) * b
    return y.astype(x.dtype), a.astype(x.dtype), b.astype(x.dtype)


def fused_swiglu_bwd_x_ref(dy, a, b, w1, w2):
    dy, a, b = (t.astype(jnp.float32) for t in (dy, a, b))
    s = jax.nn.sigmoid(a)
    da = dy * b * (s * (1 + a * (1 - s)))
    db = dy * silu(a)
    dx = da.astype(w1.dtype) @ w1.T + db.astype(w2.dtype) @ w2.T
    return dx.astype(dy.dtype)


def fused_swiglu_bwd_w_ref(x, dy, a, b):
    dy, a, b = (t.astype(jnp.float32) for t in (dy, a, b))
    s = jax.nn.sigmoid(a)
    da = (dy * b * (s * (1 + a * (1 - s)))).astype(x.dtype)
    db = (dy * silu(a)).astype(x.dtype)
    xf = x.astype(jnp.float32)
    dw1 = xf.T @ da.astype(jnp.float32)
    dw2 = xf.T @ db.astype(jnp.float32)
    return dw1.astype(x.dtype), dw2.astype(x.dtype)


def gather_gmm_ref(x, idx, offsets, w1, w2=None, *, epilogue=True,
                   backend="segment"):
    """Gather rows then grouped matmul (materialized — the thing the kernel
    avoids), as the correctness oracle.  ``backend`` defaults to the pinned
    ``segment`` backend — the pure-jnp rendering that exists on every
    supported JAX — deliberately *not* the ambient precedence chain: an
    oracle must not move when ``REPRO_GMM_BACKEND`` or a ``use_backend``
    scope changes mid-process.  Pass an explicit name/``ResolvedBackend`` to
    rebase the oracle."""
    from repro.core.gmm_backend import get_backend
    seg = get_backend(backend)
    xg = jnp.take(x, idx, axis=0).astype(jnp.float32)
    lens = jnp.diff(offsets)
    a = seg.gmm(xg, w1.astype(jnp.float32), lens)
    if w2 is None:
        return a.astype(x.dtype)
    b = seg.gmm(xg, w2.astype(jnp.float32), lens)
    y = silu(a) * b if epilogue else a
    return (y.astype(x.dtype), a.astype(x.dtype), b.astype(x.dtype))


def combine_ref(p_out, token_index_map, gates):
    L, k = token_index_map.shape
    parts = jnp.take(p_out, token_index_map.reshape(-1), axis=0)
    parts = parts.reshape(L, k, -1).astype(jnp.float32)
    return jnp.einsum("lk,lkd->ld", gates.astype(jnp.float32),
                      parts).astype(p_out.dtype)


def build_dispatch_ref(topk_experts, num_experts) -> Dispatch:
    return build_dispatch(topk_experts, num_experts)

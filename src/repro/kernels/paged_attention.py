"""Pallas TPU paged-attention (decode) kernel.

One-token attention against the block-paged KV pool
(``serve/paged_cache``), walking the page table *inside* the kernel: the
grid is ``(batch, pages_per_seq)`` with the page dimension innermost, the
page table and per-request positions ride in as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``), and each KV block's index map resolves
``page_table[b, p]`` — so the kernel DMAs exactly one physical page per
step instead of materializing the dense ``(B, pages_per_seq * page_size,
Hkv, Dh)`` gather the jnp reference builds per token.  Page steps past a
request's current position are redirected to the trash page (a single
constant page — reads do not scale with the reservation) and their scores
are masked by absolute position, exactly like the reference.

Online softmax runs across page steps in VMEM scratch (f32 running max /
denominator / accumulator — TPU grids are sequential per core, the flash
kernel's idiom); causal masking is by ``t <= pos_b`` with optional sliding
window and logit softcap.  int8 pools keep the scale-on-scores contract:
the kernel loads the int8 page plus its f16 per-vector scales, multiplies
scores by ``k_scale`` rows and probabilities by ``v_scale`` rows, and never
dequantizes storage.

Validated in interpret mode against ``paged_cache.paged_gather_attention``
on CPU across {f32, bf16, int8} x {window, softcap}; on a real TPU the same
grid lowers natively (align ``page_size`` / ``Dh`` to the (8, 128) f32 /
(32, 128) int8 tile floors there — serving configs use Dh >= 64 and
page_size >= 16, test configs run interpret mode only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
TRASH_PAGE = 0


def _kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, *refs,
            n_pages: int, ps: int, Hkv: int, G: int, window: int,
            cap: float, scale: float, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_s, l_s, acc_s = refs
    else:
        (o_ref, m_s, l_s, acc_s), ks_ref, vs_ref = refs, None, None
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    pos = pos_ref[b]
    q = (q_ref[0].astype(jnp.float32) * scale).reshape(Hkv, G, -1)
    k = jnp.transpose(k_ref[0], (1, 0, 2)).astype(jnp.float32)  # (Hkv,ps,Dh)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # (Hkv,G,ps)
    if quantized:
        ksc = jnp.transpose(ks_ref[0][..., 0], (1, 0))           # (Hkv, ps)
        s = s * ksc.astype(jnp.float32)[:, None, :]
    if cap:
        s = cap * jnp.tanh(s / cap)
    t_abs = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    valid = t_abs <= pos
    if window:
        valid &= t_abs > pos - window
    s = jnp.where(valid[None, :, :], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    pr = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + pr.sum(axis=-1, keepdims=True)
    if quantized:
        vsc = jnp.transpose(vs_ref[0][..., 0], (1, 0))           # (Hkv, ps)
        pr = pr * vsc.astype(jnp.float32)[:, None, :]
    v = jnp.transpose(v_ref[0], (1, 0, 2)).astype(jnp.float32)   # (Hkv,ps,Dh)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        pr, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(p == n_pages - 1)
    def _store():
        out = acc_s[...] / jnp.maximum(l_s[...], 1e-30)
        o_ref[0] = out.reshape(Hkv * G, -1).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "cap", "interpret"))
def paged_attention_pallas(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, k_scale: jax.Array | None,
                           v_scale: jax.Array | None, page_table: jax.Array,
                           positions: jax.Array, *, window: int = 0,
                           cap: float = 0.0,
                           interpret: bool = True) -> jax.Array:
    """q: (B, 1, Hq, Dh); pools: (P, page_size, Hkv, Dh) (+ f16 scales
    ``(P, page_size, Hkv, 1)`` when int8); page_table: (B, pages_per_seq);
    positions: (B,) current written position per request.
    Returns (B, 1, Hq, Dh) — bit-compatible with the dense reference's
    contraction, f32 accumulated."""
    B, _, Hq, Dh = q.shape
    _, ps, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    pps = page_table.shape[1]
    quantized = k_scale is not None

    def page_idx(b, p, pt, pos):
        # Walk the page table: the block for step p is request b's p-th
        # physical page — unless the page starts past the request's
        # position, in which case the (constant) trash page is read and the
        # whole block masks out.
        return (jnp.where(p * ps <= pos[b], pt[b, p], TRASH_PAGE), 0, 0, 0)

    in_specs = [
        pl.BlockSpec((1, Hq, Dh), lambda b, p, pt, pos: (b, 0, 0)),
        pl.BlockSpec((1, ps, Hkv, Dh), page_idx),
        pl.BlockSpec((1, ps, Hkv, Dh), page_idx),
    ]
    inputs = [q.reshape(B, Hq, Dh), k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, ps, Hkv, 1), page_idx)] * 2
        inputs += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, pps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hq, Dh), lambda b, p, pt, pos: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G, 1), jnp.float32),
            pltpu.VMEM((Hkv, G, 1), jnp.float32),
            pltpu.VMEM((Hkv, G, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, n_pages=pps, ps=ps, Hkv=Hkv, G=G,
                          window=window, cap=cap, scale=Dh ** -0.5,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Dh), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), positions.astype(jnp.int32), *inputs)
    return out.reshape(B, 1, Hq, Dh)

"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; the kernels target
TPU and are validated in interpret mode against ``ref.py``).  On TPU, call
with ``interpret=False``.

``moe_ffn_blaze_pallas`` composes the kernels into the full MoEBlaze expert
layer — dispatch build, gather-GMM with fused SwiGLU epilogue, second grouped
GEMM, gather-of-partials combine — with a custom VJP that mirrors
Algorithm 1 (SiLU recomputed; routed buffers never materialized).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.gmm_backend import ResolvedBackend, gmm, gmm_dw, resolve
from repro.core.routing import Dispatch
from repro.kernels.combine import combine
from repro.kernels.dispatch import build_dispatch_pallas
from repro.kernels.fused_swiglu import (fused_swiglu_bwd_w, fused_swiglu_bwd_x,
                                        fused_swiglu_fwd)
from repro.kernels.gather_gmm import (fused_moe_bwd, fused_moe_fwd,
                                      gather_gmm, gather_rows_pallas)

__all__ = [
    "fused_swiglu_fwd", "fused_swiglu_bwd_x", "fused_swiglu_bwd_w",
    "gather_gmm", "combine", "build_dispatch_pallas", "swiglu",
    "moe_ffn_blaze_pallas", "moe_ffn_blaze_fused", "gather_rows",
]


# ---------------------------------------------------------------------------
# Dense fused SwiGLU with the paper's checkpoint policy, as a differentiable
# op (used by the dense-arch FFNs when kernels are enabled).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def swiglu(x, w1, w2):
    y, _, _ = fused_swiglu_fwd(x, w1, w2)
    return y


def _swiglu_fwd(x, w1, w2):
    y, a, b = fused_swiglu_fwd(x, w1, w2)
    return y, (x, w1, w2, a, b)           # checkpoint: only the GEMM outputs


def _swiglu_bwd(res, dy):
    x, w1, w2, a, b = res
    dx = fused_swiglu_bwd_x(dy, a, b, w1, w2)
    dw1, dw2 = fused_swiglu_bwd_w(x, dy, a, b)
    return dx, dw1, dw2


swiglu.defvjp(_swiglu_fwd, _swiglu_bwd)


# ---------------------------------------------------------------------------
# Full MoEBlaze expert layer out of Pallas kernels.
# ---------------------------------------------------------------------------


def _silu(a):
    return a * jax.nn.sigmoid(a)


def _dsilu(a):
    s = jax.nn.sigmoid(a)
    return s * (1.0 + a * (1.0 - s))


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _moe_pallas(backend, x, w1, w2, w3, gates, eti, off, tim, lens):
    y, _ = _moe_pallas_fwd(backend, x, w1, w2, w3, gates, eti, off, tim,
                           lens)
    return y


def _moe_pallas_fwd(backend, x, w1, w2, w3, gates, eti, off, tim, lens):
    S = eti.shape[0]
    # Fused gather + dual GEMM + SwiGLU epilogue (paper §5.2 kernel).
    y_swi, a, b = gather_gmm(x, eti, off, w1, w2, save_ab=True)
    # Second grouped GEMM (identity gather: rows already in expert order).
    p_out = gather_gmm(y_swi, jnp.arange(S, dtype=jnp.int32), off, w3,
                       epilogue=False)
    y = combine(p_out, tim, gates)
    return y, (x, w1, w2, w3, gates, eti, off, tim, lens, a, b, y_swi)


def _moe_pallas_bwd(backend, res, dy):
    (x, w1, w2, w3, gates, eti, off, tim, lens, a, b, y_swi) = res
    L, k = tim.shape
    S = eti.shape[0]
    ident = jnp.arange(S, dtype=jnp.int32)
    g_slot = jnp.zeros((S,), gates.dtype).at[tim.reshape(-1)].set(
        gates.reshape(-1))
    # Expand output grads to slots (gather through the index metadata).
    dyg = jnp.take(dy, eti, axis=0)
    # dW3 / dY_swi via grouped GEMMs (gather_gmm with identity index).
    dw3 = gmm_dw(y_swi * g_slot[:, None].astype(y_swi.dtype), dyg, lens,
                 backend=backend)
    dyu = gather_gmm(dyg, ident, off, jnp.swapaxes(w3, 1, 2), epilogue=False)
    dgates = jnp.take(jnp.sum(y_swi * dyu, -1),
                      tim.reshape(-1)).reshape(gates.shape).astype(gates.dtype)
    dy_swi = dyu * g_slot[:, None].astype(dyu.dtype)
    # Fused SwiGLU backward (SiLU recomputed inside the kernels).
    da = dy_swi * b * _dsilu(a)
    db = dy_swi * _silu(a)
    xg = jnp.take(x, eti, axis=0)
    dw1 = gmm_dw(xg, da, lens, backend=backend)
    dw2 = gmm_dw(xg, db, lens, backend=backend)
    dxg = gmm(da, jnp.swapaxes(w1, 1, 2), lens, backend=backend) + \
        gmm(db, jnp.swapaxes(w2, 1, 2), lens, backend=backend)
    dx = jnp.zeros_like(x).at[eti].add(dxg.astype(x.dtype))
    return dx, dw1, dw2, dw3, dgates, None, None, None, None


_moe_pallas.defvjp(_moe_pallas_fwd, _moe_pallas_bwd)


def moe_ffn_blaze_pallas(x: jax.Array, gates: jax.Array, dispatch: Dispatch,
                         w1: jax.Array, w3: jax.Array, w2: jax.Array,
                         *, backend: str | ResolvedBackend | None = None
                         ) -> jax.Array:
    """Kernel-composed MoEBlaze SwiGLU expert layer (single device).

    ``backend`` selects the grouped-GEMM backend for the *backward* GEMMs
    (the forward runs the fused Pallas kernels by construction); resolved
    here — through the full precedence chain, at trace time — so the
    custom-VJP static arg is stable.
    """
    d = dispatch
    return _moe_pallas(resolve(backend).name, x, w1, w2, w3,
                       gates.astype(x.dtype),
                       d.expert_token_indices, d.expert_token_offsets,
                       d.token_index_map, d.expert_lengths)


# ---------------------------------------------------------------------------
# Fully fused dispatch→GEMM→combine MoE layer (the ``pallas_fused`` backend).
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _moe_fused(bl, bh, x, w1, w2, w3, gates, eti, off, tim):
    y, _ = _moe_fused_fwd(bl, bh, x, w1, w2, w3, gates, eti, off, tim)
    return y


def _moe_fused_fwd(bl, bh, x, w1, w2, w3, gates, eti, off, tim):
    S = eti.shape[0]
    g_slot = jnp.zeros((S,), jnp.float32).at[tim.reshape(-1)].set(
        gates.reshape(-1).astype(jnp.float32))
    y = fused_moe_fwd(x, g_slot, eti, off, w1, w2, w3, bl=bl, bh=bh)
    # Residuals: inputs + the (S,) slot-gate *vector* only — no (L·k, h) /
    # (L·k, d) buffer survives the forward (strictly below even the "x"
    # residual mode of the unfused layer; the backward kernel replays the
    # gather and recomputes A/B/SiLU per h-block in VMEM).
    return y.astype(x.dtype), (x, w1, w2, w3, gates, eti, off, tim, g_slot)


def _moe_fused_bwd(bl, bh, res, dy):
    x, w1, w2, w3, gates, eti, off, tim, g_slot = res
    dx, dgs, dw1, dw2, dw3 = fused_moe_bwd(
        x, dy.astype(x.dtype), g_slot, eti, off, w1, w2, w3, bl=bl, bh=bh)
    dgates = jnp.take(dgs, tim.reshape(-1)).reshape(gates.shape)
    return (dx.astype(x.dtype), dw1.astype(w1.dtype), dw2.astype(w2.dtype),
            dw3.astype(w3.dtype), dgates.astype(gates.dtype),
            None, None, None)


_moe_fused.defvjp(_moe_fused_fwd, _moe_fused_bwd)


def moe_ffn_blaze_fused(x: jax.Array, gates: jax.Array, dispatch: Dispatch,
                        w1: jax.Array, w3: jax.Array, w2: jax.Array,
                        *, bl: int | None = None, bh: int | None = None
                        ) -> jax.Array:
    """MoEBlaze SwiGLU expert layer as ONE fused kernel pair.

    Forward: :func:`repro.kernels.gather_gmm.fused_moe_fwd` — gather, both
    first-layer GEMMs, SiLU·gate, the second grouped GEMM, and the gated
    scatter-combine in a single grid pass.  Backward:
    :func:`~repro.kernels.gather_gmm.fused_moe_bwd` replays the gather
    in-kernel.  Neither direction materializes a ``(L·k, h)`` or
    ``(L·k, d)`` buffer in HBM.

    ``bl``/``bh`` default to :func:`repro.roofline.select_moe_tiles` — the
    arithmetic-intensity model picks the tile pair at trace time from the
    static shapes (the kernels still clamp to divisors/extents).
    """
    d = dispatch
    if bl is None or bh is None:
        from repro.roofline import select_moe_tiles
        abl, abh = select_moe_tiles(
            d.expert_token_indices.shape[0], x.shape[1], w1.shape[2],
            dtype_bytes=x.dtype.itemsize, num_experts=w1.shape[0])
        bl = abl if bl is None else bl
        bh = abh if bh is None else bh
    return _moe_fused(bl, bh, x, w1, w2, w3, gates,
                      d.expert_token_indices, d.expert_token_offsets,
                      d.token_index_map)


# ---------------------------------------------------------------------------
# Differentiable row gather (the ep_a2a send-buffer builder).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def gather_rows(src, row_ids):
    """``out[i] = src[row_ids[i]]`` with ``row_ids[i] < 0`` → a zero row,
    as a Pallas kernel: builds an a2a send buffer straight from dispatch
    metadata without materializing an intermediate gathered copy.  The VJP
    scatter-adds valid rows back (dropped rows contribute nothing)."""
    return gather_rows_pallas(src, row_ids)


def _gather_rows_fwd(src, row_ids):
    return gather_rows_pallas(src, row_ids), (src, row_ids)


def _gather_rows_bwd(res, dout):
    src, row_ids = res
    valid = row_ids >= 0
    contrib = jnp.where(valid[:, None], dout, 0).astype(src.dtype)
    dsrc = jnp.zeros_like(src).at[jnp.maximum(row_ids, 0)].add(contrib)
    return dsrc, None


gather_rows.defvjp(_gather_rows_fwd, _gather_rows_bwd)

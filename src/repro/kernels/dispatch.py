"""Pallas dispatch-structure construction (paper §4.2), TPU rendering.

The paper's GPU pipeline is 3 atomic-free steps: dense token→expert bitmap,
per-expert lengths via warp reductions, then a location map from CTA-local
exclusive scans + global offsets.  On TPU the grid executes **sequentially**
per core, so a running per-expert counter carried in VMEM scratch across grid
steps *is* the exclusive scan — two single-pass kernels suffice:

  1. ``count`` — per-expert lengths (tile-local one-hot column sums,
     accumulated into the output across grid steps).
  2. ``route`` — per-slot destination = global offset (scalar input) +
     carried counter + tile-local exclusive scan; writes
     ``expert_token_indices`` via per-row dynamic stores and emits the flat
     ``token_index_map``.

Padding slots carry the sentinel expert id ``E`` and are masked everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.routing import Dispatch


def _count_kernel(tei_ref, len_ref, *, num_experts: int, bl: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        len_ref[...] = jnp.zeros_like(len_ref)

    e = tei_ref[...]                                        # (bl,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bl, num_experts), 1)
    onehot = (e[:, None] == iota).astype(jnp.int32)         # sentinel E -> 0
    len_ref[...] += onehot.sum(axis=0)


def _route_kernel(tei_ref, off_ref, dest_ref, eti_ref, counters,
                  *, num_experts: int, bl: int, k: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        counters[...] = jnp.zeros_like(counters)

    e = tei_ref[...]                                        # (bl,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bl, num_experts), 1)
    onehot = (e[:, None] == iota).astype(jnp.int32)
    local_excl = jnp.cumsum(onehot, axis=0) - onehot        # tile-local scan
    cnt = counters[...]
    off = off_ref[...]
    # Per-row base = offsets[e] + carried counter[e]; VPU-friendly one-hot
    # contractions instead of vector gathers.
    base = (onehot * (off[None, :num_experts] + cnt[None, :])).sum(axis=1)
    rank = (onehot * local_excl).sum(axis=1)
    dest = base + rank                                      # (bl,)
    valid = e < num_experts
    dest_ref[...] = jnp.where(valid, dest, 0)

    def write_row(r, _):
        slot = step * bl + r

        @pl.when(valid[r])
        def _w():
            eti_ref[pl.ds(dest[r], 1)] = (slot // k)[None].astype(jnp.int32)

        return 0

    jax.lax.fori_loop(0, bl, write_row, 0, unroll=False)
    counters[...] = cnt + onehot.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("num_experts", "k", "bl",
                                             "interpret"))
def build_dispatch_pallas(topk_experts: jax.Array, num_experts: int,
                          *, k: int | None = None, bl: int = 256,
                          interpret: bool = True) -> Dispatch:
    """Drop-in replacement for :func:`repro.core.routing.build_dispatch`."""
    L, kk = topk_experts.shape
    k = kk if k is None else k
    flat = topk_experts.reshape(L * k).astype(jnp.int32)
    n = L * k
    bl = min(bl, n)
    n_pad = ((n + bl - 1) // bl) * bl
    tei = jnp.pad(flat, (0, n_pad - n), constant_values=num_experts)
    n_tiles = n_pad // bl

    lengths = pl.pallas_call(
        functools.partial(_count_kernel, num_experts=num_experts, bl=bl),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((bl,), lambda t: (t,))],
        out_specs=pl.BlockSpec((num_experts,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_experts,), jnp.int32),
        interpret=interpret,
    )(tei)

    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(lengths)]).astype(jnp.int32)

    dest_pad, eti = pl.pallas_call(
        functools.partial(_route_kernel, num_experts=num_experts, bl=bl, k=k),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((bl,), lambda t: (t,)),
            pl.BlockSpec((num_experts + 1,), lambda t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bl,), lambda t: (t,)),
            pl.BlockSpec((n,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((num_experts,), jnp.int32)],
        interpret=interpret,
    )(tei, offsets)

    return Dispatch(
        expert_token_indices=eti,
        expert_token_offsets=offsets,
        token_expert_indices=flat,
        token_index_map=dest_pad[:n].reshape(L, k),
        expert_lengths=lengths,
    )

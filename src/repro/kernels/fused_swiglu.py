"""Fused dual-GEMM + SwiGLU epilogue Pallas kernels (paper §5.2).

The paper fuses the two first-layer projections ``a = xW1``, ``b = xW2`` with
the SwiGLU epilogue ``silu(a)·b`` so that the input is loaded **once**, both
GEMMs stream through the MXU, the epilogue runs out of VMEM, and only the
final product (plus the checkpointed ``a``, ``b``) is written to HBM —
eliminating the global-memory round trips for ``σ(a)``, ``silu(a)`` and the
product.

TPU mapping (DESIGN.md §2): grid ``(L/bl, h/bh, d/bk)`` with the contraction
dimension innermost (TPU grids execute sequentially per core, so two f32 VMEM
scratch accumulators carry the partial products across ``d``-tiles); the
epilogue fires on the last contraction step.  Block shapes default to
128×128-aligned tiles to match the MXU systolic array.

Backward kernels implement Algorithm 1's ``FusedBwdX`` / ``FusedBwdW``:
``silu(a)`` is *recomputed* from the checkpointed ``a`` (never stored), the
two branches' elementwise derivatives are formed in VMEM, and the shared-input
gradients are accumulated in-place — no temporary global buffers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _silu(a):
    return a * jax.nn.sigmoid(a)


def _dsilu(a):
    s = jax.nn.sigmoid(a)
    return s * (1.0 + a * (1.0 - s))


# ---------------------------------------------------------------------------
# Forward: (x, w1, w2) -> (y_swi, a, b)
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w1_ref, w2_ref, y_ref, a_ref, b_ref,
                acc_a, acc_b, *, nk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_a[...] = jnp.zeros_like(acc_a)
        acc_b[...] = jnp.zeros_like(acc_b)

    x = x_ref[...]
    acc_a[...] += jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    acc_b[...] += jnp.dot(x, w2_ref[...], preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _epilogue():
        a = acc_a[...]
        b = acc_b[...]
        a_ref[...] = a.astype(a_ref.dtype)
        b_ref[...] = b.astype(b_ref.dtype)
        y_ref[...] = (_silu(a) * b).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bl", "bh", "bk", "interpret"))
def fused_swiglu_fwd(x: jax.Array, w1: jax.Array, w2: jax.Array,
                     *, bl: int = 128, bh: int = 128, bk: int = 128,
                     interpret: bool = True):
    """Returns ``(y_swi, a, b)`` with a single pass over ``x``."""
    L, d = x.shape
    _, h = w1.shape
    bl, bh, bk = min(bl, L), min(bh, h), min(bk, d)
    assert L % bl == 0 and h % bh == 0 and d % bk == 0, (L, h, d, bl, bh, bk)
    nl, nh, nk = L // bl, h // bh, d // bk
    out_shapes = [jax.ShapeDtypeStruct((L, h), x.dtype)] * 3
    y, a, b = pl.pallas_call(
        functools.partial(_fwd_kernel, nk=nk),
        grid=(nl, nh, nk),
        in_specs=[
            pl.BlockSpec((bl, bk), lambda l, hh, kk: (l, kk)),
            pl.BlockSpec((bk, bh), lambda l, hh, kk: (kk, hh)),
            pl.BlockSpec((bk, bh), lambda l, hh, kk: (kk, hh)),
        ],
        out_specs=[pl.BlockSpec((bl, bh), lambda l, hh, kk: (l, hh))] * 3,
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((bl, bh), jnp.float32)] * 2,
        interpret=interpret,
    )(x, w1, w2)
    return y, a, b


# ---------------------------------------------------------------------------
# Backward dX: (dy, a, b, w1, w2) -> dx = da @ w1^T + db @ w2^T   (FusedBwdX)
# ---------------------------------------------------------------------------


def _bwd_x_kernel(dy_ref, a_ref, b_ref, w1_ref, w2_ref, dx_ref,
                  acc, *, nk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    dy = dy_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    da = dy * b * _dsilu(a)          # silu'(a) recomputed in VMEM
    db = dy * _silu(a)               # silu(a)  recomputed in VMEM
    acc[...] += jnp.dot(da.astype(dy_ref.dtype), w1_ref[...].T,
                        preferred_element_type=jnp.float32)
    acc[...] += jnp.dot(db.astype(dy_ref.dtype), w2_ref[...].T,
                        preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _store():
        dx_ref[...] = acc[...].astype(dx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bl", "bd", "bk", "interpret"))
def fused_swiglu_bwd_x(dy: jax.Array, a: jax.Array, b: jax.Array,
                       w1: jax.Array, w2: jax.Array,
                       *, bl: int = 128, bd: int = 128, bk: int = 128,
                       interpret: bool = True) -> jax.Array:
    L, h = dy.shape
    d = w1.shape[0]
    bl, bd, bk = min(bl, L), min(bd, d), min(bk, h)
    assert L % bl == 0 and d % bd == 0 and h % bk == 0
    nl, nd, nk = L // bl, d // bd, h // bk
    return pl.pallas_call(
        functools.partial(_bwd_x_kernel, nk=nk),
        grid=(nl, nd, nk),
        in_specs=[
            pl.BlockSpec((bl, bk), lambda l, dd, kk: (l, kk)),   # dy
            pl.BlockSpec((bl, bk), lambda l, dd, kk: (l, kk)),   # a
            pl.BlockSpec((bl, bk), lambda l, dd, kk: (l, kk)),   # b
            pl.BlockSpec((bd, bk), lambda l, dd, kk: (dd, kk)),  # w1
            pl.BlockSpec((bd, bk), lambda l, dd, kk: (dd, kk)),  # w2
        ],
        out_specs=pl.BlockSpec((bl, bd), lambda l, dd, kk: (l, dd)),
        out_shape=jax.ShapeDtypeStruct((L, d), dy.dtype),
        scratch_shapes=[pltpu.VMEM((bl, bd), jnp.float32)],
        interpret=interpret,
    )(dy, a, b, w1, w2)


# ---------------------------------------------------------------------------
# Backward dW: (x, dy, a, b) -> (dw1, dw2) sharing one read of x  (FusedBwdW)
# ---------------------------------------------------------------------------


def _bwd_w_kernel(x_ref, dy_ref, a_ref, b_ref, dw1_ref, dw2_ref,
                  acc1, acc2, *, nk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc1[...] = jnp.zeros_like(acc1)
        acc2[...] = jnp.zeros_like(acc2)

    x = x_ref[...]
    dy = dy_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    da = (dy * b * _dsilu(a)).astype(x.dtype)
    db = (dy * _silu(a)).astype(x.dtype)
    acc1[...] += jnp.dot(x.T, da, preferred_element_type=jnp.float32)
    acc2[...] += jnp.dot(x.T, db, preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _store():
        dw1_ref[...] = acc1[...].astype(dw1_ref.dtype)
        dw2_ref[...] = acc2[...].astype(dw2_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "bh", "bk", "interpret"))
def fused_swiglu_bwd_w(x: jax.Array, dy: jax.Array, a: jax.Array,
                       b: jax.Array,
                       *, bd: int = 128, bh: int = 128, bk: int = 128,
                       interpret: bool = True):
    L, d = x.shape
    h = dy.shape[1]
    bd, bh, bk = min(bd, d), min(bh, h), min(bk, L)
    assert d % bd == 0 and h % bh == 0 and L % bk == 0
    nd, nh, nk = d // bd, h // bh, L // bk
    return pl.pallas_call(
        functools.partial(_bwd_w_kernel, nk=nk),
        grid=(nd, nh, nk),
        in_specs=[
            pl.BlockSpec((bk, bd), lambda dd, hh, kk: (kk, dd)),  # x
            pl.BlockSpec((bk, bh), lambda dd, hh, kk: (kk, hh)),  # dy
            pl.BlockSpec((bk, bh), lambda dd, hh, kk: (kk, hh)),  # a
            pl.BlockSpec((bk, bh), lambda dd, hh, kk: (kk, hh)),  # b
        ],
        out_specs=[pl.BlockSpec((bd, bh), lambda dd, hh, kk: (dd, hh))] * 2,
        out_shape=[jax.ShapeDtypeStruct((d, h), x.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((bd, bh), jnp.float32)] * 2,
        interpret=interpret,
    )(x, dy, a, b)

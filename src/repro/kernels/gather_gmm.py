"""Gather-GMM: grouped expert GEMMs with on-the-fly token gather (paper §3.1
+ §5.2), as a Pallas TPU kernel — plus the fully fused
dispatch→GEMM→combine MoE kernels built on the same work-item grid.

This is the kernel rendering of the paper's central claim: the expert MLPs
consume **non-materialized** routed tokens.  The `(L·k, d)` routed buffer
never exists in HBM; instead the kernel is driven by the scalar-prefetched
``expert_token_indices`` and DMA-gathers the needed rows of the *unpermuted*
``x`` per tile, streams them through the expert's projections (optionally both
SwiGLU branches at once, sharing the single read of the gathered rows), and
applies the SiLU·gate epilogue in VMEM.

:func:`fused_moe_fwd` / :func:`fused_moe_bwd` take the fusion end to end
(SonicMoE-style IO-aware epilogue fusion): the second grouped GEMM
(``y_swi @ w3[e]``) runs in the same grid pass, and each slot's gated partial
is scatter-accumulated straight into the `(L, d)` output through the same
index metadata — the gather-of-partials combine of ``kernels/combine.py``
becomes the kernel's epilogue, so neither the `(L·k, h)` SwiGLU product nor
the `(L·k, d)` partials ever exist in HBM.  The backward replays the gather
in-kernel and produces dx / dgates / dw1 / dw2 / dw3 from one grid sweep,
again with no `(L·k, ·)` residual.  The fused kernels express both the
gather and the scatter-accumulate as one-hot matmuls against a per-item
``(bl, L)`` dispatch matrix built in VMEM (``sel @ x`` / ``selᵀ @ v`` — MXU
work instead of per-row dynamic slices; exact, since entries are 0/1 with at
most one hit per row).

Group-crossing tiles are handled MegaBlocks-style: the wrapper precomputes a
static work-item list (one item per (row-tile × overlapping expert); at most
``n_tiles + E`` items) whose metadata — tile id, expert id, row range inside
the tile, first-visit flags — is scalar-prefetched so that the weight
BlockSpec's ``index_map`` can select ``w[expert]`` per work item.  Output
tiles visited by several experts are accumulated in VMEM across consecutive
grid steps (TPU grids are sequential per core).

Work-item contracts (hardened; see :func:`make_work_items`):

  * every output row tile is zero-initialized in-kernel — tiles no expert
    touches get a dedicated filler item with ``first=1``, so trailing dead
    rows are exact zeros, not uninitialized memory;
  * every expert's weight-gradient block is zero-initialized in-kernel —
    empty experts get a dedicated filler item with ``efirst=1``, so callers
    no longer have to mask ``gmm_dw_pallas`` outputs;
  * the all-empty case (``n_valid == 0``, e.g. an ``ep_a2a`` shard whose
    tokens were all dropped) degenerates to pure no-op items that still
    zero-initialize every output block.

Tile sizes: ``bl``/``bh`` are *requests*; ``bh`` is clamped to the largest
divisor of ``h`` (non-multiple-of-128 FFN widths work, they just run a
narrower tile) and ``bl`` to the padded row count.  Callers that want
hardware-informed sizes ask ``repro.roofline.select_moe_tiles`` (the
arithmetic-intensity model) instead of hard-coding 128.

On this CPU container the kernels run in ``interpret=True`` mode; ``x`` is
held as a single VMEM block for kernel-scale shapes.  On a real TPU the same
grid/work-item structure applies with ``x`` in ``ANY`` (HBM) memory space and
per-row ``make_async_copy`` gathers — the row (``d`` contiguous elements) is
the natural DMA unit, see DESIGN.md §2.  (The filler items appended by the
hardened :func:`make_work_items` revisit some output blocks non-adjacently;
on a real TPU grid they must be folded into the per-block visit order —
tracked under the ROADMAP real-hardware item.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _silu(a):
    return a * jax.nn.sigmoid(a)


def _dsilu(a):
    s = jax.nn.sigmoid(a)
    return s * (1.0 + a * (1.0 - s))


def largest_divisor_tile(n: int, b: int) -> int:
    """Largest divisor of ``n`` that is ``<= b`` (static Python ints).

    The tile-size clamp for block dimensions that must divide the array
    dimension exactly: ``largest_divisor_tile(192, 128) == 96``.  Always
    >= 1, so any positive ``n`` has a valid tiling.
    """
    b = max(1, min(int(b), int(n)))
    while n % b:
        b -= 1
    return b


def make_work_items(offsets: jax.Array, n_tiles: int, bl: int,
                    num_experts: int):
    """Static-shape (tile × expert) work-item metadata.

    Returns int32 arrays of length ``W = n_tiles + num_experts``:
      (tile, expert, lo, hi, first, efirst) — ``[lo, hi)`` is the row range
    of ``expert`` inside ``tile``; ``first`` marks the first item visiting
    each *tile's* output block and ``efirst`` the first item visiting each
    *expert's* block (whichever output block a kernel accumulates into must
    be initialized on its first visit).

    The trailing (invalid) items are structured fillers, not garbage:

      1. one item per **unvisited tile** (no expert has rows there — dead
         rows past the group totals) carrying ``first=1`` and an empty row
         range, so row-tiled outputs are zero-initialized in-kernel;
      2. one item per **empty expert** carrying ``efirst=1`` and an empty
         range, so per-expert outputs (the dw kernels) are zero-initialized
         in-kernel;
      3. any remaining items are benign no-ops on already-initialized blocks
         (last tile / last valid expert, empty range, flags clear).

    Counting argument for why the fillers always fit: contiguous expert row
    ranges over ``T`` tiles give ``n_valid <= T_visited + E_nonempty - 1``
    (0 when nothing is routed), so ``W - n_valid >= #unvisited_tiles +
    #empty_experts`` always holds — including the fully degenerate
    ``n_valid == 0`` case, where the items are exactly one ``first`` filler
    per tile followed by one ``efirst`` filler per expert (all-empty input
    produces well-defined, all-zero outputs instead of self-referential
    metadata).
    """
    E = num_experts
    W = n_tiles + E
    t = jnp.arange(n_tiles, dtype=jnp.int32)[:, None]           # (T, 1)
    lo = jnp.clip(offsets[None, :E] - t * bl, 0, bl)             # (T, E)
    hi = jnp.clip(offsets[None, 1:] - t * bl, 0, bl)             # (T, E)
    valid = (hi > lo)
    flat_valid = valid.reshape(-1)
    rank = jnp.cumsum(flat_valid) - flat_valid                   # dest slot
    first = valid & (jnp.cumsum(valid, axis=1) == 1)
    efirst = valid & (jnp.cumsum(valid, axis=0) == 1)

    def scatter(vals, fill):
        out = jnp.full((W,), fill, jnp.int32)
        return out.at[jnp.where(flat_valid, rank, W)].set(
            jnp.where(flat_valid, vals.reshape(-1).astype(jnp.int32), fill),
            mode="drop")

    n_valid = flat_valid.sum()
    ex = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None, :],
                          (n_tiles, E))
    tiles = jnp.broadcast_to(t, (n_tiles, E))
    wi_tile = scatter(tiles, n_tiles - 1)
    wi_expert = scatter(ex, 0)
    wi_lo = scatter(lo, 0)
    wi_hi = scatter(hi, 0)
    wi_first = scatter(first, 0)
    wi_efirst = scatter(efirst, 0)
    # Benign filler base: empty range on the last tile, pointing at the last
    # valid item's expert (expert 0 when nothing is valid) so block revisits
    # only ever touch initialized blocks.
    fill_mask = jnp.arange(W) >= n_valid
    last_expert = wi_expert[jnp.maximum(n_valid - 1, 0)]
    wi_tile = jnp.where(fill_mask, n_tiles - 1, wi_tile)
    wi_expert = jnp.where(fill_mask, last_expert, wi_expert)
    wi_lo = jnp.where(fill_mask, 0, wi_lo)
    wi_hi = jnp.where(fill_mask, 0, wi_hi)
    wi_first = jnp.where(fill_mask, 0, wi_first)
    wi_efirst = jnp.where(fill_mask, 0, wi_efirst)
    # Filler class 1: unvisited tiles get a `first=1` item each, directly
    # after the valid items, so their output blocks are zeroed in-kernel.
    ut = ~valid.any(axis=1)                                      # (T,)
    ut_rank = n_valid + jnp.cumsum(ut) - ut
    ut_idx = jnp.where(ut, ut_rank, W)
    tile_ids = jnp.arange(n_tiles, dtype=jnp.int32)
    wi_tile = wi_tile.at[ut_idx].set(tile_ids, mode="drop")
    wi_first = wi_first.at[ut_idx].set(1, mode="drop")
    # Filler class 2: empty experts get an `efirst=1` item each (after the
    # tile fillers, so the last tile's block they sit on is initialized).
    ue = ~valid.any(axis=0)                                      # (E,)
    ue_rank = n_valid + ut.sum() + jnp.cumsum(ue) - ue
    ue_idx = jnp.where(ue, ue_rank, W)
    expert_ids = jnp.arange(E, dtype=jnp.int32)
    wi_expert = wi_expert.at[ue_idx].set(expert_ids, mode="drop")
    wi_efirst = wi_efirst.at[ue_idx].set(1, mode="drop")
    return wi_tile, wi_expert, wi_lo, wi_hi, wi_first, wi_efirst


def _kernel(idx_ref, tile_ref, expert_ref, lo_ref, hi_ref, first_ref,
            x_ref, w1_ref, w2_ref, y_ref, a_ref, b_ref, xt_ref,
            *, bl: int, dual: bool, epilogue: bool):
    wi = pl.program_id(0)
    tile = tile_ref[wi]
    lo, hi = lo_ref[wi], hi_ref[wi]
    first = first_ref[wi] == 1

    # --- on-the-fly gather of this work item's rows into VMEM -------------
    def gather_row(r, _):
        active = (r >= lo) & (r < hi)
        tok = jnp.where(active, idx_ref[tile * bl + r], 0)
        row = pl.load(x_ref, (pl.ds(tok, 1), slice(None)))
        xt_ref[pl.ds(r, 1), :] = jnp.where(active, row, 0)
        return 0

    jax.lax.fori_loop(0, bl, gather_row, 0, unroll=False)

    xt = xt_ref[...]
    a = jnp.dot(xt, w1_ref[0], preferred_element_type=jnp.float32)
    if dual:
        b = jnp.dot(xt, w2_ref[0], preferred_element_type=jnp.float32)
        y = _silu(a) * b if epilogue else a
    else:
        b = None
        y = a

    def acc(ref, val):
        @pl.when(first)
        def _init():
            ref[...] = val.astype(ref.dtype)

        @pl.when(jnp.logical_not(first))
        def _acc():
            ref[...] += val.astype(ref.dtype)

    acc(y_ref, y)
    if a_ref is not None:
        acc(a_ref, a)
    if dual and b_ref is not None:
        acc(b_ref, b)


@functools.partial(jax.jit, static_argnames=(
    "bl", "bh", "epilogue", "save_ab", "interpret"))
def gather_gmm(x: jax.Array, idx: jax.Array, offsets: jax.Array,
               w1: jax.Array, w2: jax.Array | None = None,
               *, bl: int = 128, bh: int = 128, epilogue: bool = True,
               save_ab: bool = False, interpret: bool = True):
    """Grouped matmul over gathered rows.

    Args:
      x: (L, d) unpermuted activations.
      idx: (S,) row ids grouped by expert (``expert_token_indices``).
      offsets: (E+1,) exclusive prefix sums (``expert_token_offsets``).
      w1: (E, d, h); w2: optional (E, d, h) SwiGLU gate branch.
      epilogue: apply ``silu(a)·b`` (requires w2).
      save_ab: also return the checkpointed GEMM outputs a (and b).
      bl/bh: row/hidden tile-size *requests* — ``bh`` is clamped to the
        largest divisor of ``h`` (any FFN width traces; a non-multiple of
        128 just runs a narrower tile) and ``bl`` to the padded row count.

    Returns ``y`` of shape (S, h) — or ``(y, a[, b])`` when ``save_ab``.
    Output rows past ``offsets[-1]`` belong to no group and are exact zeros
    (unvisited tiles are zero-initialized in-kernel by the filler items).
    """
    S, = idx.shape
    L, d = x.shape
    E, _, h = w1.shape
    dual = w2 is not None
    bl = min(bl, max(S, 8))
    bh = largest_divisor_tile(h, bh)
    S_pad = ((S + bl - 1) // bl) * bl
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, S_pad - S))
    n_tiles = S_pad // bl
    nh = h // bh
    wi_tile, wi_expert, wi_lo, wi_hi, wi_first, _ = make_work_items(
        offsets.astype(jnp.int32), n_tiles, bl, E)
    W = wi_tile.shape[0]

    n_out = 1 + (1 if save_ab else 0) + (1 if (save_ab and dual) else 0)
    out_shape = [jax.ShapeDtypeStruct((S_pad, h), x.dtype)] * n_out
    out_specs = [pl.BlockSpec((bl, bh), lambda wi, hh, *s: (tile_map(wi, s), hh))
                 for _ in range(n_out)]

    # index_map helpers get the scalar-prefetch refs appended.
    def tile_map(wi, scalars):
        return scalars[1][wi]          # wi_tile

    def x_map(wi, hh, *scalars):
        return (0, 0)

    def w_map(wi, hh, *scalars):
        return (scalars[2][wi], 0, hh)  # wi_expert

    in_specs = [
        pl.BlockSpec((L, d), x_map),
        pl.BlockSpec((1, d, bh), w_map),
    ]
    args = [x, w1]
    if dual:
        in_specs.append(pl.BlockSpec((1, d, bh), w_map))
        args.append(w2)

    kernel = functools.partial(
        _kernel, bl=bl, dual=dual, epilogue=epilogue and dual)

    def body(*refs):
        scalars = refs[:6]
        if dual:
            x_r, w1_r, w2_r = refs[6:9]
            outs = refs[9:9 + n_out]
            scratch = refs[9 + n_out]
        else:
            x_r, w1_r = refs[6:8]
            w2_r = None
            outs = refs[8:8 + n_out]
            scratch = refs[8 + n_out]
        y_r = outs[0]
        a_r = outs[1] if save_ab else None
        b_r = outs[2] if (save_ab and dual) else None
        kernel(*scalars, x_r, w1_r, w2_r, y_r, a_r, b_r, scratch)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(W, nh),
        in_specs=in_specs,
        out_specs=out_specs if n_out > 1 else out_specs[0],
        scratch_shapes=[pltpu.VMEM((bl, d), x.dtype)],
    )
    out = pl.pallas_call(
        body, grid_spec=grid_spec,
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )(idx_p, wi_tile, wi_expert, wi_lo, wi_hi, wi_first, *args)
    if n_out == 1:
        return out[:S]
    return tuple(o[:S] for o in out)


# ---------------------------------------------------------------------------
# Fully fused dispatch -> grouped GEMMs -> combine (forward)
# ---------------------------------------------------------------------------


def _onehot_select(idx_ref, lo, hi, n_rows: int, bl: int):
    """(bl, n_rows) one-hot dispatch matrix for this work item: row r is
    one-hot at token ``idx[r]`` when r lies in the item's [lo, hi) slot
    range, all-zero otherwise.  Gather is ``sel @ x`` and scatter-accumulate
    is ``selᵀ @ v`` — both MXU matmuls, no per-row dynamic slices (the
    classic TPU dispatch idiom; exact in f32 since entries are 0/1 and each
    row has at most one hit)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (bl, 1), 0)
    active = (rows >= lo) & (rows < hi)
    toks = jnp.where(active, idx_ref[...].astype(jnp.int32), -1)   # (bl, 1)
    return (toks == jax.lax.broadcasted_iota(jnp.int32, (bl, n_rows), 1)
            ).astype(jnp.float32)


def _fused_kernel(tile_ref, expert_ref, lo_ref, hi_ref,
                  idx_ref, x_ref, g_ref, w1_ref, w2_ref, w3_ref, y_ref,
                  xt_ref, pacc_ref, *, bl: int, nh: int):
    wi = pl.program_id(0)
    hh = pl.program_id(1)
    lo, hi = lo_ref[wi], hi_ref[wi]
    sel = _onehot_select(idx_ref, lo, hi, y_ref.shape[0], bl)

    @pl.when((wi == 0) & (hh == 0))
    def _init_out():
        # The (L, d) accumulator is one persistent block: zero it once.
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(hh == 0)
    def _gather():
        # On-the-fly dispatch: this item's rows, gathered once per work item
        # (the scratch persists across the sequential hh steps).
        xt_ref[...] = jax.lax.dot_general(
            sel, x_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(xt_ref.dtype)

    xt = xt_ref[...]
    a = jnp.dot(xt, w1_ref[0], preferred_element_type=jnp.float32)
    b = jnp.dot(xt, w2_ref[0], preferred_element_type=jnp.float32)
    y_swi = _silu(a) * b                       # (bl, bh), VMEM-only
    # Round to the I/O dtype at the GEMM boundary — the same place the
    # unfused path materializes y_swi — so fused-vs-unfused stays within
    # reduction-order noise even in bf16 (identity in f32).
    y_swi = y_swi.astype(xt_ref.dtype).astype(jnp.float32)
    # Second grouped GEMM, this h-block's contribution: (bl, bh) @ (bh, d).
    p = jax.lax.dot_general(y_swi, w3_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    @pl.when(hh == 0)
    def _p_init():
        pacc_ref[...] = p

    @pl.when(hh > 0)
    def _p_acc():
        pacc_ref[...] += p

    @pl.when(hh == nh - 1)
    def _combine():
        # Fused combine epilogue: once the h-contraction is complete,
        # scatter-accumulate each slot's gated partial into y[token] through
        # the same one-hot dispatch matrix the gather used (this is
        # kernels/combine.py folded into the grid pass — no (L*k, d)
        # partials buffer ever exists).  ``selᵀ @ gated`` routes slot r's
        # partial to y[idx[r]]; inactive rows have an all-zero sel row.
        gated = g_ref[...].astype(jnp.float32) * pacc_ref[...]
        y_ref[...] += jax.lax.dot_general(
            sel, gated, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bl", "bh", "interpret"))
def fused_moe_fwd(x: jax.Array, g_slot: jax.Array, idx: jax.Array,
                  offsets: jax.Array, w1: jax.Array, w2: jax.Array,
                  w3: jax.Array, *, bl: int = 128, bh: int = 128,
                  interpret: bool = True) -> jax.Array:
    """Fused dispatch→GEMM→combine SwiGLU MoE forward.

    One grid pass over the work items computes, per (row tile × expert ×
    h-block): the on-the-fly gather of ``x`` rows, both first-layer GEMMs,
    the SiLU·gate epilogue, the second grouped GEMM, and the gated
    scatter-accumulate of each slot's partial into the ``(L, d)`` output —
    no ``(L·k, h)`` or ``(L·k, d)`` intermediate is ever written to HBM.

    Args:
      x: (L, d) unpermuted activations.
      g_slot: (S,) per-slot gate weights in expert order (the (L, k) gates
        scattered through ``token_index_map``).
      idx: (S,) ``expert_token_indices``; offsets: (E+1,) prefix sums.
      w1, w2: (E, d, h); w3: (E, h, d).
      bl/bh: tile requests (``bh`` clamped to a divisor of ``h``); ask
        ``repro.roofline.select_moe_tiles`` for hardware-informed sizes.

    Returns the combined (L, d) output in fp32 (full-precision accumulation
    across h-blocks and the k slots; cast at the call site).
    """
    S, = idx.shape
    L, d = x.shape
    E, _, h = w1.shape
    bl = min(bl, max(S, 8))
    bh = largest_divisor_tile(h, bh)
    S_pad = ((S + bl - 1) // bl) * bl
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, S_pad - S))
    g_pad = jnp.pad(g_slot, (0, S_pad - S)).reshape(S_pad, 1)
    n_tiles = S_pad // bl
    nh = h // bh
    wi_tile, wi_expert, wi_lo, wi_hi, _, _ = make_work_items(
        offsets.astype(jnp.int32), n_tiles, bl, E)
    W = wi_tile.shape[0]

    def x_map(wi, hh, *scalars):
        return (0, 0)

    def g_map(wi, hh, *scalars):
        return (scalars[0][wi], 0)      # wi_tile

    def w12_map(wi, hh, *scalars):
        return (scalars[1][wi], 0, hh)  # wi_expert

    def w3_map(wi, hh, *scalars):
        return (scalars[1][wi], hh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(W, nh),
        in_specs=[
            pl.BlockSpec((bl, 1), g_map),   # idx, tiled like the gates
            pl.BlockSpec((L, d), x_map),
            pl.BlockSpec((bl, 1), g_map),
            pl.BlockSpec((1, d, bh), w12_map),
            pl.BlockSpec((1, d, bh), w12_map),
            pl.BlockSpec((1, bh, d), w3_map),
        ],
        out_specs=pl.BlockSpec((L, d), x_map),
        scratch_shapes=[pltpu.VMEM((bl, d), x.dtype),
                        pltpu.VMEM((bl, d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, bl=bl, nh=nh),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, d), jnp.float32),
        interpret=interpret,
    )(wi_tile, wi_expert, wi_lo, wi_hi,
      idx_p.reshape(S_pad, 1), x, g_pad, w1, w2, w3)


# ---------------------------------------------------------------------------
# Fully fused backward: replay the gather in-kernel, produce every gradient
# ---------------------------------------------------------------------------


def _fused_bwd_kernel(tile_ref, expert_ref, lo_ref, hi_ref,
                      first_ref, efirst_ref,
                      idx_ref, x_ref, dy_ref, g_ref, w1_ref, w2_ref, w3_ref,
                      dx_ref, dg_ref, dw1_ref, dw2_ref, dw3_ref,
                      xt_ref, dyt_ref, dxacc_ref, *, bl: int, nh: int):
    wi = pl.program_id(0)
    hh = pl.program_id(1)
    lo, hi = lo_ref[wi], hi_ref[wi]
    first = first_ref[wi] == 1
    efirst = efirst_ref[wi] == 1
    sel = _onehot_select(idx_ref, lo, hi, dx_ref.shape[0], bl)

    @pl.when((wi == 0) & (hh == 0))
    def _init_dx():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    @pl.when(hh == 0)
    def _gather():
        # Replay the dispatch gather for x AND expand the (L, d) output
        # grads to this item's slots — neither buffer was saved.
        rows_c = (((1,), (0,)), ((), ()))
        xt_ref[...] = jax.lax.dot_general(
            sel, x_ref[...].astype(jnp.float32), rows_c,
            preferred_element_type=jnp.float32).astype(xt_ref.dtype)
        dyt_ref[...] = jax.lax.dot_general(
            sel, dy_ref[...].astype(jnp.float32), rows_c,
            preferred_element_type=jnp.float32).astype(dyt_ref.dtype)

    xt = xt_ref[...]
    dyt = dyt_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)               # (bl, 1)
    # Recompute A, B, SiLU for this h-block (Algorithm 1's smart checkpoint,
    # taken to its deepest point: nothing but x and the weights was saved).
    a = jnp.dot(xt, w1_ref[0], preferred_element_type=jnp.float32)
    b = jnp.dot(xt, w2_ref[0], preferred_element_type=jnp.float32)
    sa = _silu(a)
    # Recomputed y_swi and the cotangent dyu are rounded to the I/O dtype,
    # matching the buffers the unfused backward reads (identity in f32).
    y_swi = (sa * b).astype(xt_ref.dtype).astype(jnp.float32)
    # dY_swi through the transposed third GEMM: (bl, d) x (bh, d) -> (bl, bh)
    dyu = jax.lax.dot_general(dyt, w3_ref[0].astype(jnp.float32),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    dyu = dyu.astype(xt_ref.dtype).astype(jnp.float32)
    dy_swi = dyu * g
    da = dy_swi * b * _dsilu(a)
    db = dy_swi * sa

    def acc(ref, val, init):
        @pl.when(init)
        def _init():
            ref[...] = val.astype(ref.dtype)

        @pl.when(jnp.logical_not(init))
        def _acc():
            ref[...] += val.astype(ref.dtype)

    # dgates, in slot order: rows outside [lo, hi) contribute exact zeros
    # (their xt/dyt rows are zeroed), so the per-tile block accumulates
    # cleanly across the tile's items and the h-blocks.
    acc(dg_ref, jnp.sum(y_swi * dyu, axis=1, keepdims=True),
        first & (hh == 0))
    rows_t = (((0,), (0,)), ((), ()))
    xt32 = xt.astype(jnp.float32)
    acc(dw1_ref, jax.lax.dot_general(
        xt32, da, rows_t, preferred_element_type=jnp.float32)[None], efirst)
    acc(dw2_ref, jax.lax.dot_general(
        xt32, db, rows_t, preferred_element_type=jnp.float32)[None], efirst)
    acc(dw3_ref, jax.lax.dot_general(
        y_swi * g, dyt, rows_t, preferred_element_type=jnp.float32)[None],
        efirst)

    # Token gradients: accumulate over h-blocks, scatter once per work item.
    dxg = (jax.lax.dot_general(da, w1_ref[0].astype(jnp.float32),
                               (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + jax.lax.dot_general(db, w2_ref[0].astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32))

    @pl.when(hh == 0)
    def _dx_init():
        dxacc_ref[...] = dxg

    @pl.when(hh > 0)
    def _dx_acc():
        dxacc_ref[...] += dxg

    @pl.when(hh == nh - 1)
    def _dx_scatter():
        # selᵀ routes each slot's accumulated dx back to its token row
        # (inactive rows have all-zero sel rows, so they contribute nothing).
        dx_ref[...] += jax.lax.dot_general(
            sel, dxacc_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bl", "bh", "interpret"))
def fused_moe_bwd(x: jax.Array, dy: jax.Array, g_slot: jax.Array,
                  idx: jax.Array, offsets: jax.Array, w1: jax.Array,
                  w2: jax.Array, w3: jax.Array, *, bl: int = 128,
                  bh: int = 128, interpret: bool = True):
    """Backward of :func:`fused_moe_fwd` in one grid sweep.

    Replays the dispatch gather in-kernel (both ``x`` rows and the slot
    expansion of ``dy``), recomputes A/B/SiLU per h-block, and accumulates
    all five gradients — no ``(L·k, ·)`` buffer is read from or written to
    HBM.  Empty experts' dw blocks and dead row tiles are zero-initialized
    by the work-item fillers.

    Returns ``(dx (L, d), dgates_slot (S,), dw1, dw2, dw3)`` in fp32.
    """
    S, = idx.shape
    L, d = x.shape
    E, _, h = w1.shape
    bl = min(bl, max(S, 8))
    bh = largest_divisor_tile(h, bh)
    S_pad = ((S + bl - 1) // bl) * bl
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, S_pad - S))
    g_pad = jnp.pad(g_slot, (0, S_pad - S)).reshape(S_pad, 1)
    n_tiles = S_pad // bl
    nh = h // bh
    wi_tile, wi_expert, wi_lo, wi_hi, wi_first, wi_efirst = make_work_items(
        offsets.astype(jnp.int32), n_tiles, bl, E)
    W = wi_tile.shape[0]

    def full_map(wi, hh, *scalars):
        return (0, 0)

    def g_map(wi, hh, *scalars):
        return (scalars[0][wi], 0)      # wi_tile

    def w12_map(wi, hh, *scalars):
        return (scalars[1][wi], 0, hh)  # wi_expert

    def w3_map(wi, hh, *scalars):
        return (scalars[1][wi], hh, 0)

    def dw12_map(wi, hh, *scalars):
        return (scalars[1][wi], 0, hh)

    def dw3_map(wi, hh, *scalars):
        return (scalars[1][wi], hh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(W, nh),
        in_specs=[
            pl.BlockSpec((bl, 1), g_map),   # idx, tiled like the gates
            pl.BlockSpec((L, d), full_map),
            pl.BlockSpec((L, d), full_map),
            pl.BlockSpec((bl, 1), g_map),
            pl.BlockSpec((1, d, bh), w12_map),
            pl.BlockSpec((1, d, bh), w12_map),
            pl.BlockSpec((1, bh, d), w3_map),
        ],
        out_specs=[
            pl.BlockSpec((L, d), full_map),
            pl.BlockSpec((bl, 1), g_map),
            pl.BlockSpec((1, d, bh), dw12_map),
            pl.BlockSpec((1, d, bh), dw12_map),
            pl.BlockSpec((1, bh, d), dw3_map),
        ],
        scratch_shapes=[pltpu.VMEM((bl, d), x.dtype),
                        pltpu.VMEM((bl, d), dy.dtype),
                        pltpu.VMEM((bl, d), jnp.float32)],
    )
    dx, dg, dw1, dw2, dw3 = pl.pallas_call(
        functools.partial(_fused_bwd_kernel, bl=bl, nh=nh),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((L, d), jnp.float32),
            jax.ShapeDtypeStruct((S_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((E, d, h), jnp.float32),
            jax.ShapeDtypeStruct((E, d, h), jnp.float32),
            jax.ShapeDtypeStruct((E, h, d), jnp.float32),
        ],
        interpret=interpret,
    )(wi_tile, wi_expert, wi_lo, wi_hi, wi_first, wi_efirst,
      idx_p.reshape(S_pad, 1), x, dy, g_pad, w1, w2, w3)
    return dx, dg[:S, 0], dw1, dw2, dw3


# ---------------------------------------------------------------------------
# Row gather (the a2a send-buffer builder)
# ---------------------------------------------------------------------------


def _gather_rows_kernel(rows_ref, src_ref, out_ref, *, bl: int):
    t = pl.program_id(0)

    def row(r, _):
        rid = rows_ref[t * bl + r]
        active = rid >= 0
        src = pl.load(src_ref, (pl.ds(jnp.maximum(rid, 0), 1), slice(None)))
        out_ref[pl.ds(r, 1), :] = jnp.where(active, src, 0)
        return 0

    jax.lax.fori_loop(0, bl, row, 0, unroll=False)


@functools.partial(jax.jit, static_argnames=("bl", "interpret"))
def gather_rows_pallas(src: jax.Array, row_ids: jax.Array, *, bl: int = 128,
                       interpret: bool = True) -> jax.Array:
    """Build an (N, d) row buffer straight from ``src`` rows: ``out[i] =
    src[row_ids[i]]``, with ``row_ids[i] < 0`` producing an exact zero row.

    This is the ``ep_a2a`` send-buffer builder: the buffer is filled from
    the dispatch metadata inside the kernel — no intermediate (L·k, d)
    gathered copy is materialized before the scatter into rank order.
    """
    N, = row_ids.shape
    L, d = src.shape
    bl = min(bl, max(N, 8))
    N_pad = ((N + bl - 1) // bl) * bl
    rows_p = jnp.pad(row_ids.astype(jnp.int32), (0, N_pad - N),
                     constant_values=-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N_pad // bl,),
        in_specs=[pl.BlockSpec((L, d), lambda t, *s: (0, 0))],
        out_specs=pl.BlockSpec((bl, d), lambda t, *s: (t, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_gather_rows_kernel, bl=bl),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N_pad, d), src.dtype),
        interpret=interpret,
    )(rows_p, src)
    return out[:N]


# ---------------------------------------------------------------------------
# Grouped weight gradient on the same work-item machinery
# ---------------------------------------------------------------------------


def _dw_kernel(tile_ref, expert_ref, lo_ref, hi_ref, efirst_ref,
               x_ref, g_ref, dw_ref, *, bl: int):
    wi = pl.program_id(0)
    lo, hi = lo_ref[wi], hi_ref[wi]
    first = efirst_ref[wi] == 1
    rows = jax.lax.broadcasted_iota(jnp.int32, (bl, 1), 0)
    mask = (rows >= lo) & (rows < hi)
    xt = jnp.where(mask, x_ref[...], 0).astype(jnp.float32)
    # Contract the row axis: (bl, d), (bl, h) -> (d, h).  Rows outside this
    # item's range are zeroed in xt, so the full-tile dot is exact.
    dwt = jax.lax.dot_general(xt, g_ref[...].astype(jnp.float32),
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    @pl.when(first)
    def _init():
        dw_ref[...] = dwt[None].astype(dw_ref.dtype)

    @pl.when(jnp.logical_not(first))
    def _acc():
        dw_ref[...] += dwt[None].astype(dw_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bl", "interpret"))
def gmm_dw_pallas(lhs: jax.Array, dout: jax.Array, offsets: jax.Array,
                  *, bl: int = 128, interpret: bool = True) -> jax.Array:
    """Per-group weight gradient (S, d), (S, h) -> (E, d, h) on the
    work-item grid.

    ``lhs``/``dout`` rows are already in expert order; each work item masks
    its expert's row range inside the tile and accumulates ``x_tile^T @
    dout_tile`` into ``dw[expert]``.  An expert's work items are consecutive
    in the tile-major item order (its row segment is contiguous), so the
    output block is only ever revisited on adjacent grid steps — the
    accumulation pattern TPU grids require.  Cross-tile partials genuinely
    overlap (unlike the forward's disjoint row ranges), so the output is
    fp32 and cast to ``lhs.dtype`` only at the end — the backend contract's
    fp32 accumulation.  Blocks of *empty* experts are zero-initialized
    in-kernel (each empty expert gets a dedicated ``efirst`` filler item) —
    callers no longer need to mask the output.
    """
    S, d = lhs.shape
    h = dout.shape[1]
    E = offsets.shape[0] - 1
    bl = min(bl, max(S, 8))
    S_pad = ((S + bl - 1) // bl) * bl
    lhs_p = jnp.pad(lhs, ((0, S_pad - S), (0, 0)))
    dout_p = jnp.pad(dout, ((0, S_pad - S), (0, 0)))
    n_tiles = S_pad // bl
    wi_tile, wi_expert, wi_lo, wi_hi, _, wi_efirst = make_work_items(
        offsets.astype(jnp.int32), n_tiles, bl, E)
    W = wi_tile.shape[0]

    def row_map(wi, *scalars):
        return (scalars[0][wi], 0)       # wi_tile

    def dw_map(wi, *scalars):
        return (scalars[1][wi], 0, 0)    # wi_expert

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(W,),
        in_specs=[pl.BlockSpec((bl, d), row_map),
                  pl.BlockSpec((bl, h), row_map)],
        out_specs=pl.BlockSpec((1, d, h), dw_map),
    )
    out = pl.pallas_call(
        functools.partial(_dw_kernel, bl=bl),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, d, h), jnp.float32),
        interpret=interpret,
    )(wi_tile, wi_expert, wi_lo, wi_hi, wi_efirst, lhs_p, dout_p)
    return out.astype(lhs.dtype)

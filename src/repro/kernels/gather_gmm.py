"""Gather-GMM: grouped expert GEMMs with on-the-fly token gather (paper §3.1
+ §5.2), as a Pallas TPU kernel.

This is the kernel rendering of the paper's central claim: the expert MLPs
consume **non-materialized** routed tokens.  The `(L·k, d)` routed buffer
never exists in HBM; instead the kernel is driven by the scalar-prefetched
``expert_token_indices`` and DMA-gathers the needed rows of the *unpermuted*
``x`` per tile, streams them through the expert's projections (optionally both
SwiGLU branches at once, sharing the single read of the gathered rows), and
applies the SiLU·gate epilogue in VMEM.

Group-crossing tiles are handled MegaBlocks-style: the wrapper precomputes a
static work-item list (one item per (row-tile × overlapping expert); at most
``n_tiles + E`` items) whose metadata — tile id, expert id, row range inside
the tile, first-visit flag — is scalar-prefetched so that the weight
BlockSpec's ``index_map`` can select ``w[expert]`` per work item.  Output
tiles visited by several experts are accumulated in VMEM across consecutive
grid steps (TPU grids are sequential per core).

On this CPU container the kernel runs in ``interpret=True`` mode; ``x`` is
held as a single VMEM block for kernel-scale shapes.  On a real TPU the same
grid/work-item structure applies with ``x`` in ``ANY`` (HBM) memory space and
per-row ``make_async_copy`` gathers — the row (``d`` contiguous elements) is
the natural DMA unit, see DESIGN.md §2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _silu(a):
    return a * jax.nn.sigmoid(a)


def make_work_items(offsets: jax.Array, n_tiles: int, bl: int,
                    num_experts: int):
    """Static-shape (tile × expert) work-item metadata.

    Returns int32 arrays of length ``W = n_tiles + num_experts``:
      (tile, expert, lo, hi, first) — ``[lo, hi)`` is the row range of
    ``expert`` inside ``tile``; ``first`` marks the first item of each tile
    (which must initialize the output block).  Invalid trailing items point at
    the last tile with an empty range (benign += 0).
    """
    E = num_experts
    W = n_tiles + E
    t = jnp.arange(n_tiles, dtype=jnp.int32)[:, None]           # (T, 1)
    lo = jnp.clip(offsets[None, :E] - t * bl, 0, bl)             # (T, E)
    hi = jnp.clip(offsets[None, 1:] - t * bl, 0, bl)             # (T, E)
    valid = (hi > lo)
    flat_valid = valid.reshape(-1)
    rank = jnp.cumsum(flat_valid) - flat_valid                   # dest slot
    first = valid & (jnp.cumsum(valid, axis=1) == 1)

    def scatter(vals, fill):
        out = jnp.full((W,), fill, jnp.int32)
        return out.at[jnp.where(flat_valid, rank, W - 1)].set(
            jnp.where(flat_valid, vals.reshape(-1).astype(jnp.int32), fill),
            mode="drop")

    n_valid = flat_valid.sum()
    ex = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None, :],
                          (n_tiles, E))
    tiles = jnp.broadcast_to(t, (n_tiles, E))
    wi_tile = scatter(tiles, n_tiles - 1)
    wi_expert = scatter(ex, 0)
    wi_lo = scatter(lo, 0)
    wi_hi = scatter(hi, 0)
    wi_first = scatter(first, 0)
    # Anything at rank >= n_valid is a filler: empty range on the last tile.
    fill_mask = jnp.arange(W) >= n_valid
    wi_tile = jnp.where(fill_mask, n_tiles - 1, wi_tile)
    wi_lo = jnp.where(fill_mask, 0, wi_lo)
    wi_hi = jnp.where(fill_mask, 0, wi_hi)
    wi_first = jnp.where(fill_mask, 0, wi_first)
    return wi_tile, wi_expert, wi_lo, wi_hi, wi_first


def _kernel(idx_ref, tile_ref, expert_ref, lo_ref, hi_ref, first_ref,
            x_ref, w1_ref, w2_ref, y_ref, a_ref, b_ref, xt_ref,
            *, bl: int, dual: bool, epilogue: bool):
    wi = pl.program_id(0)
    tile = tile_ref[wi]
    lo, hi = lo_ref[wi], hi_ref[wi]
    first = first_ref[wi] == 1

    # --- on-the-fly gather of this work item's rows into VMEM -------------
    def gather_row(r, _):
        active = (r >= lo) & (r < hi)
        tok = jnp.where(active, idx_ref[tile * bl + r], 0)
        row = pl.load(x_ref, (pl.ds(tok, 1), slice(None)))
        xt_ref[pl.ds(r, 1), :] = jnp.where(active, row, 0)
        return 0

    jax.lax.fori_loop(0, bl, gather_row, 0, unroll=False)

    xt = xt_ref[...]
    a = jnp.dot(xt, w1_ref[0], preferred_element_type=jnp.float32)
    if dual:
        b = jnp.dot(xt, w2_ref[0], preferred_element_type=jnp.float32)
        y = _silu(a) * b if epilogue else a
    else:
        b = None
        y = a

    def acc(ref, val):
        @pl.when(first)
        def _init():
            ref[...] = val.astype(ref.dtype)

        @pl.when(jnp.logical_not(first))
        def _acc():
            ref[...] += val.astype(ref.dtype)

    acc(y_ref, y)
    if a_ref is not None:
        acc(a_ref, a)
    if dual and b_ref is not None:
        acc(b_ref, b)


@functools.partial(jax.jit, static_argnames=(
    "bl", "bh", "epilogue", "save_ab", "interpret"))
def gather_gmm(x: jax.Array, idx: jax.Array, offsets: jax.Array,
               w1: jax.Array, w2: jax.Array | None = None,
               *, bl: int = 128, bh: int = 128, epilogue: bool = True,
               save_ab: bool = False, interpret: bool = True):
    """Grouped matmul over gathered rows.

    Args:
      x: (L, d) unpermuted activations.
      idx: (S,) row ids grouped by expert (``expert_token_indices``).
      offsets: (E+1,) exclusive prefix sums (``expert_token_offsets``).
      w1: (E, d, h); w2: optional (E, d, h) SwiGLU gate branch.
      epilogue: apply ``silu(a)·b`` (requires w2).
      save_ab: also return the checkpointed GEMM outputs a (and b).

    Returns ``y`` of shape (S, h) — or ``(y, a[, b])`` when ``save_ab``.
    """
    S, = idx.shape
    L, d = x.shape
    E, _, h = w1.shape
    dual = w2 is not None
    bl = min(bl, max(S, 8))
    bh = min(bh, h)
    S_pad = ((S + bl - 1) // bl) * bl
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, S_pad - S))
    n_tiles = S_pad // bl
    assert h % bh == 0
    nh = h // bh
    wi_tile, wi_expert, wi_lo, wi_hi, wi_first = make_work_items(
        offsets.astype(jnp.int32), n_tiles, bl, E)
    W = wi_tile.shape[0]

    n_out = 1 + (1 if save_ab else 0) + (1 if (save_ab and dual) else 0)
    out_shape = [jax.ShapeDtypeStruct((S_pad, h), x.dtype)] * n_out
    out_specs = [pl.BlockSpec((bl, bh), lambda wi, hh, *s: (tile_map(wi, s), hh))
                 for _ in range(n_out)]

    # index_map helpers get the scalar-prefetch refs appended.
    def tile_map(wi, scalars):
        return scalars[1][wi]          # wi_tile

    def x_map(wi, hh, *scalars):
        return (0, 0)

    def w_map(wi, hh, *scalars):
        return (scalars[2][wi], 0, hh)  # wi_expert

    in_specs = [
        pl.BlockSpec((L, d), x_map),
        pl.BlockSpec((1, d, bh), w_map),
    ]
    args = [x, w1]
    if dual:
        in_specs.append(pl.BlockSpec((1, d, bh), w_map))
        args.append(w2)

    kernel = functools.partial(
        _kernel, bl=bl, dual=dual, epilogue=epilogue and dual)

    def body(*refs):
        scalars = refs[:6]
        if dual:
            x_r, w1_r, w2_r = refs[6:9]
            outs = refs[9:9 + n_out]
            scratch = refs[9 + n_out]
        else:
            x_r, w1_r = refs[6:8]
            w2_r = None
            outs = refs[8:8 + n_out]
            scratch = refs[8 + n_out]
        y_r = outs[0]
        a_r = outs[1] if save_ab else None
        b_r = outs[2] if (save_ab and dual) else None
        kernel(*scalars, x_r, w1_r, w2_r, y_r, a_r, b_r, scratch)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(W, nh),
        in_specs=in_specs,
        out_specs=out_specs if n_out > 1 else out_specs[0],
        scratch_shapes=[pltpu.VMEM((bl, d), x.dtype)],
    )
    out = pl.pallas_call(
        body, grid_spec=grid_spec,
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )(idx_p, wi_tile, wi_expert, wi_lo, wi_hi, wi_first, *args)
    if n_out == 1:
        return out[:S]
    return tuple(o[:S] for o in out)

"""Gather-GMM: grouped expert GEMMs with on-the-fly token gather (paper §3.1
+ §5.2), as a Pallas TPU kernel.

This is the kernel rendering of the paper's central claim: the expert MLPs
consume **non-materialized** routed tokens.  The `(L·k, d)` routed buffer
never exists in HBM; instead the kernel is driven by the scalar-prefetched
``expert_token_indices`` and DMA-gathers the needed rows of the *unpermuted*
``x`` per tile, streams them through the expert's projections (optionally both
SwiGLU branches at once, sharing the single read of the gathered rows), and
applies the SiLU·gate epilogue in VMEM.

Group-crossing tiles are handled MegaBlocks-style: the wrapper precomputes a
static work-item list (one item per (row-tile × overlapping expert); at most
``n_tiles + E`` items) whose metadata — tile id, expert id, row range inside
the tile, first-visit flag — is scalar-prefetched so that the weight
BlockSpec's ``index_map`` can select ``w[expert]`` per work item.  Output
tiles visited by several experts are accumulated in VMEM across consecutive
grid steps (TPU grids are sequential per core).

On this CPU container the kernel runs in ``interpret=True`` mode; ``x`` is
held as a single VMEM block for kernel-scale shapes.  On a real TPU the same
grid/work-item structure applies with ``x`` in ``ANY`` (HBM) memory space and
per-row ``make_async_copy`` gathers — the row (``d`` contiguous elements) is
the natural DMA unit, see DESIGN.md §2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _silu(a):
    return a * jax.nn.sigmoid(a)


def make_work_items(offsets: jax.Array, n_tiles: int, bl: int,
                    num_experts: int):
    """Static-shape (tile × expert) work-item metadata.

    Returns int32 arrays of length ``W = n_tiles + num_experts``:
      (tile, expert, lo, hi, first, efirst) — ``[lo, hi)`` is the row range
    of ``expert`` inside ``tile``; ``first`` marks the first item of each tile
    and ``efirst`` the first item of each *expert* (whichever output block the
    kernel accumulates into must be initialized on its first visit).  Invalid
    trailing items point at the last tile / the last valid item's expert with
    an empty range (benign += 0, and adjacent to the block they revisit).
    """
    E = num_experts
    W = n_tiles + E
    t = jnp.arange(n_tiles, dtype=jnp.int32)[:, None]           # (T, 1)
    lo = jnp.clip(offsets[None, :E] - t * bl, 0, bl)             # (T, E)
    hi = jnp.clip(offsets[None, 1:] - t * bl, 0, bl)             # (T, E)
    valid = (hi > lo)
    flat_valid = valid.reshape(-1)
    rank = jnp.cumsum(flat_valid) - flat_valid                   # dest slot
    first = valid & (jnp.cumsum(valid, axis=1) == 1)
    efirst = valid & (jnp.cumsum(valid, axis=0) == 1)

    def scatter(vals, fill):
        out = jnp.full((W,), fill, jnp.int32)
        return out.at[jnp.where(flat_valid, rank, W - 1)].set(
            jnp.where(flat_valid, vals.reshape(-1).astype(jnp.int32), fill),
            mode="drop")

    n_valid = flat_valid.sum()
    ex = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None, :],
                          (n_tiles, E))
    tiles = jnp.broadcast_to(t, (n_tiles, E))
    wi_tile = scatter(tiles, n_tiles - 1)
    wi_expert = scatter(ex, 0)
    wi_lo = scatter(lo, 0)
    wi_hi = scatter(hi, 0)
    wi_first = scatter(first, 0)
    wi_efirst = scatter(efirst, 0)
    # Anything at rank >= n_valid is a filler: empty range on the last tile,
    # pointing at the last valid item's expert so block revisits stay
    # adjacent (TPU grids flush an output block once it stops being visited).
    fill_mask = jnp.arange(W) >= n_valid
    last_expert = wi_expert[jnp.maximum(n_valid - 1, 0)]
    wi_tile = jnp.where(fill_mask, n_tiles - 1, wi_tile)
    wi_expert = jnp.where(fill_mask, last_expert, wi_expert)
    wi_lo = jnp.where(fill_mask, 0, wi_lo)
    wi_hi = jnp.where(fill_mask, 0, wi_hi)
    wi_first = jnp.where(fill_mask, 0, wi_first)
    wi_efirst = jnp.where(fill_mask, 0, wi_efirst)
    return wi_tile, wi_expert, wi_lo, wi_hi, wi_first, wi_efirst


def _kernel(idx_ref, tile_ref, expert_ref, lo_ref, hi_ref, first_ref,
            x_ref, w1_ref, w2_ref, y_ref, a_ref, b_ref, xt_ref,
            *, bl: int, dual: bool, epilogue: bool):
    wi = pl.program_id(0)
    tile = tile_ref[wi]
    lo, hi = lo_ref[wi], hi_ref[wi]
    first = first_ref[wi] == 1

    # --- on-the-fly gather of this work item's rows into VMEM -------------
    def gather_row(r, _):
        active = (r >= lo) & (r < hi)
        tok = jnp.where(active, idx_ref[tile * bl + r], 0)
        row = pl.load(x_ref, (pl.ds(tok, 1), slice(None)))
        xt_ref[pl.ds(r, 1), :] = jnp.where(active, row, 0)
        return 0

    jax.lax.fori_loop(0, bl, gather_row, 0, unroll=False)

    xt = xt_ref[...]
    a = jnp.dot(xt, w1_ref[0], preferred_element_type=jnp.float32)
    if dual:
        b = jnp.dot(xt, w2_ref[0], preferred_element_type=jnp.float32)
        y = _silu(a) * b if epilogue else a
    else:
        b = None
        y = a

    def acc(ref, val):
        @pl.when(first)
        def _init():
            ref[...] = val.astype(ref.dtype)

        @pl.when(jnp.logical_not(first))
        def _acc():
            ref[...] += val.astype(ref.dtype)

    acc(y_ref, y)
    if a_ref is not None:
        acc(a_ref, a)
    if dual and b_ref is not None:
        acc(b_ref, b)


@functools.partial(jax.jit, static_argnames=(
    "bl", "bh", "epilogue", "save_ab", "interpret"))
def gather_gmm(x: jax.Array, idx: jax.Array, offsets: jax.Array,
               w1: jax.Array, w2: jax.Array | None = None,
               *, bl: int = 128, bh: int = 128, epilogue: bool = True,
               save_ab: bool = False, interpret: bool = True):
    """Grouped matmul over gathered rows.

    Args:
      x: (L, d) unpermuted activations.
      idx: (S,) row ids grouped by expert (``expert_token_indices``).
      offsets: (E+1,) exclusive prefix sums (``expert_token_offsets``).
      w1: (E, d, h); w2: optional (E, d, h) SwiGLU gate branch.
      epilogue: apply ``silu(a)·b`` (requires w2).
      save_ab: also return the checkpointed GEMM outputs a (and b).

    Returns ``y`` of shape (S, h) — or ``(y, a[, b])`` when ``save_ab``.
    """
    S, = idx.shape
    L, d = x.shape
    E, _, h = w1.shape
    dual = w2 is not None
    bl = min(bl, max(S, 8))
    bh = min(bh, h)
    S_pad = ((S + bl - 1) // bl) * bl
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, S_pad - S))
    n_tiles = S_pad // bl
    assert h % bh == 0
    nh = h // bh
    wi_tile, wi_expert, wi_lo, wi_hi, wi_first, _ = make_work_items(
        offsets.astype(jnp.int32), n_tiles, bl, E)
    W = wi_tile.shape[0]

    n_out = 1 + (1 if save_ab else 0) + (1 if (save_ab and dual) else 0)
    out_shape = [jax.ShapeDtypeStruct((S_pad, h), x.dtype)] * n_out
    out_specs = [pl.BlockSpec((bl, bh), lambda wi, hh, *s: (tile_map(wi, s), hh))
                 for _ in range(n_out)]

    # index_map helpers get the scalar-prefetch refs appended.
    def tile_map(wi, scalars):
        return scalars[1][wi]          # wi_tile

    def x_map(wi, hh, *scalars):
        return (0, 0)

    def w_map(wi, hh, *scalars):
        return (scalars[2][wi], 0, hh)  # wi_expert

    in_specs = [
        pl.BlockSpec((L, d), x_map),
        pl.BlockSpec((1, d, bh), w_map),
    ]
    args = [x, w1]
    if dual:
        in_specs.append(pl.BlockSpec((1, d, bh), w_map))
        args.append(w2)

    kernel = functools.partial(
        _kernel, bl=bl, dual=dual, epilogue=epilogue and dual)

    def body(*refs):
        scalars = refs[:6]
        if dual:
            x_r, w1_r, w2_r = refs[6:9]
            outs = refs[9:9 + n_out]
            scratch = refs[9 + n_out]
        else:
            x_r, w1_r = refs[6:8]
            w2_r = None
            outs = refs[8:8 + n_out]
            scratch = refs[8 + n_out]
        y_r = outs[0]
        a_r = outs[1] if save_ab else None
        b_r = outs[2] if (save_ab and dual) else None
        kernel(*scalars, x_r, w1_r, w2_r, y_r, a_r, b_r, scratch)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(W, nh),
        in_specs=in_specs,
        out_specs=out_specs if n_out > 1 else out_specs[0],
        scratch_shapes=[pltpu.VMEM((bl, d), x.dtype)],
    )
    out = pl.pallas_call(
        body, grid_spec=grid_spec,
        out_shape=out_shape if n_out > 1 else out_shape[0],
        interpret=interpret,
    )(idx_p, wi_tile, wi_expert, wi_lo, wi_hi, wi_first, *args)
    if n_out == 1:
        return out[:S]
    return tuple(o[:S] for o in out)


# ---------------------------------------------------------------------------
# Grouped weight gradient on the same work-item machinery
# ---------------------------------------------------------------------------


def _dw_kernel(tile_ref, expert_ref, lo_ref, hi_ref, efirst_ref,
               x_ref, g_ref, dw_ref, *, bl: int):
    wi = pl.program_id(0)
    lo, hi = lo_ref[wi], hi_ref[wi]
    first = efirst_ref[wi] == 1
    rows = jax.lax.broadcasted_iota(jnp.int32, (bl, 1), 0)
    mask = (rows >= lo) & (rows < hi)
    xt = jnp.where(mask, x_ref[...], 0).astype(jnp.float32)
    # Contract the row axis: (bl, d), (bl, h) -> (d, h).  Rows outside this
    # item's range are zeroed in xt, so the full-tile dot is exact.
    dwt = jax.lax.dot_general(xt, g_ref[...].astype(jnp.float32),
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)

    @pl.when(first)
    def _init():
        dw_ref[...] = dwt[None].astype(dw_ref.dtype)

    @pl.when(jnp.logical_not(first))
    def _acc():
        dw_ref[...] += dwt[None].astype(dw_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bl", "interpret"))
def gmm_dw_pallas(lhs: jax.Array, dout: jax.Array, offsets: jax.Array,
                  *, bl: int = 128, interpret: bool = True) -> jax.Array:
    """Per-group weight gradient (S, d), (S, h) -> (E, d, h) on the
    work-item grid.

    ``lhs``/``dout`` rows are already in expert order; each work item masks
    its expert's row range inside the tile and accumulates ``x_tile^T @
    dout_tile`` into ``dw[expert]``.  An expert's work items are consecutive
    in the tile-major item order (its row segment is contiguous), so the
    output block is only ever revisited on adjacent grid steps — the
    accumulation pattern TPU grids require.  Cross-tile partials genuinely
    overlap (unlike the forward's disjoint row ranges), so the output is
    fp32 and cast to ``lhs.dtype`` only at the end — the backend contract's
    fp32 accumulation.  Blocks of *empty* experts are never visited and
    must be zeroed by the caller.
    """
    S, d = lhs.shape
    h = dout.shape[1]
    E = offsets.shape[0] - 1
    bl = min(bl, max(S, 8))
    S_pad = ((S + bl - 1) // bl) * bl
    lhs_p = jnp.pad(lhs, ((0, S_pad - S), (0, 0)))
    dout_p = jnp.pad(dout, ((0, S_pad - S), (0, 0)))
    n_tiles = S_pad // bl
    wi_tile, wi_expert, wi_lo, wi_hi, _, wi_efirst = make_work_items(
        offsets.astype(jnp.int32), n_tiles, bl, E)
    W = wi_tile.shape[0]

    def row_map(wi, *scalars):
        return (scalars[0][wi], 0)       # wi_tile

    def dw_map(wi, *scalars):
        return (scalars[1][wi], 0, 0)    # wi_expert

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(W,),
        in_specs=[pl.BlockSpec((bl, d), row_map),
                  pl.BlockSpec((bl, h), row_map)],
        out_specs=pl.BlockSpec((1, d, h), dw_map),
    )
    out = pl.pallas_call(
        functools.partial(_dw_kernel, bl=bl),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, d, h), jnp.float32),
        interpret=interpret,
    )(wi_tile, wi_expert, wi_lo, wi_hi, wi_efirst, lhs_p, dout_p)
    return out.astype(lhs.dtype)

"""Logical-axis sharding rules -> PartitionSpecs, divisibility-aware.

Strategy (DESIGN.md §5, README "Distribution modes"):
  * params: FSDP x TP — input-side matrices P('data', 'model'), output-side
    (projections back to d_model) P('model', 'data'); MoE expert tensors
    shard the *expert* dim over the expert axes — 'model', or the factored
    ('node', 'model') pair when the mesh declares a node tier — under expert
    parallelism (``moe_parallel`` 'ep'/'ep_a2a'/'ep_a2a_hier', or 'auto'
    when the expert count divides the axes) and otherwise tensor-shard the
    per-expert hidden dim on 'model' (matching the shard_map specs in
    models/moe_block.py).
  * every rule checks divisibility and falls back to replication for that dim
    (never uneven padding) — e.g. hubert's vocab=504 vs a 16-way axis.
  * activations/batches: batch on ('pod','data'); decode caches shard batch
    on data axes and capacity/state dims on 'model' (sequence/context
    parallelism for long_500k).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# names of leaves that project back down to d_model (row-parallel / "out")
_OUT_PROJ = {"wo", "w3", "w_down", "w_out"}
# MoE expert tensors (leading expert dim)
_MOE_IN = {"w1", "w2"}          # (E, d, h)
_MOE_OUT = {"w3"}               # (E, h, d)


def _axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fit(dim: int, mesh, axis) -> str | tuple | None:
    """Return ``axis`` if ``dim`` divides evenly over it, else None."""
    if axis is None:
        return None
    sizes = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        if a not in mesh.axis_names:
            return None
        sizes *= mesh.shape[a]
    return axis if sizes > 1 and dim % sizes == 0 else None


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _leaf_spec(path_keys: list[str], shape: tuple, mesh,
               moe_parallel: str = "auto") -> P:
    name = path_keys[-1]
    stacked = path_keys[0] == "layers"
    dims = shape[1:] if stacked else shape
    prefix = (None,) if stacked else ()

    def two_d(in_dim, out_dim, in_ax, out_ax):
        return prefix + (_fit(in_dim, mesh, in_ax), _fit(out_dim, mesh, out_ax))

    if len(dims) == 3 and name in (_MOE_IN | _MOE_OUT):
        # Expert-parallel when the expert count divides the expert axes
        # (qwen3-moe: 8 experts/device, no weight gather in the MoE body);
        # tensor-parallel on the expert hidden dim otherwise (mixtral).
        # The a2a modes keep the EP weight layout — only token placement
        # differs.  A mesh with a 'node' tier factors the expert dim over
        # ('node', 'model'): node-major blocks, matching the flattened
        # device index node_i * n_model + lane_i in moe_block.
        ep_ax = ("node", "model") if "node" in mesh.axis_names else "model"
        ep = _fit(dims[0], mesh, ep_ax) if moe_parallel == "auto" \
            else (moe_parallel in ("ep", "ep_a2a", "ep_a2a_hier"))
        if ep:
            return prefix + (ep_ax, _fit(dims[1], mesh, "data"), None)
        if name in _MOE_IN:                          # (E, d, h)
            return prefix + (None, _fit(dims[1], mesh, "data"),
                             _fit(dims[2], mesh, "model"))
        return prefix + (None, _fit(dims[1], mesh, "model"),  # (E, h, d)
                         _fit(dims[2], mesh, "data"))
    if len(dims) == 2:
        if name == "embed":                          # (V, d)
            return two_d(dims[0], dims[1], "model", "data")
        if name in _OUT_PROJ:                        # (f, d)
            return two_d(dims[0], dims[1], "model", "data")
        return two_d(dims[0], dims[1], "data", "model")  # (d, f) in-proj
    return prefix + (None,) * len(dims)


def param_specs(params_shapes, mesh, *, fsdp: bool = True,
                moe_parallel: str = "auto"):
    """PartitionSpec tree congruent with the params tree (of shapes or
    arrays).  ``fsdp=False`` drops the 'data' axis from every param spec —
    weights replicated across data replicas (the right choice for decode,
    where ZeRO-style gathers would run once per layer per token)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        keys = [str(k) for k in keys if k is not None]
        axes = _leaf_spec(keys, tuple(leaf.shape), mesh, moe_parallel)
        if not fsdp:
            axes = tuple(
                None if ax == "data" or
                (isinstance(ax, tuple) and "data" in ax) else ax
                for ax in axes)
        specs.append(P(*axes))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(pspecs):
    """AdamW state specs: step replicated, moments mirror params."""
    from repro.train.optimizer import AdamWState
    return AdamWState(step=P(), mu=pspecs, nu=jax.tree.map(lambda s: s,
                                                           pspecs))


def batch_specs(cfg, batch_shapes: dict, mesh) -> dict:
    dp = dp_axes(mesh)
    out = {}
    for k, v in batch_shapes.items():
        b = v.shape[0]
        bax = _fit(b, mesh, dp) or _fit(b, mesh, ("data",))
        out[k] = P(*((bax,) + (None,) * (len(v.shape) - 1)))
    return out


def cache_specs(cfg, cache_shapes, mesh):
    """Decode-cache specs: (groups, B, capacity/state...) leaves.
    Batch -> data axes when divisible; the largest remaining dim (KV capacity
    or SSM state dim) -> 'model' (plus 'data' for context-parallel long
    caches when batch could not be sharded)."""
    dp = dp_axes(mesh)

    def spec(leaf):
        shape = tuple(leaf.shape)
        ndim = len(shape)
        if ndim == 0:
            return P()
        if ndim == 1:            # 1-D metadata — replicate.  (slot_pos is
            return P(None)       # (groups, B, C) now: batch + capacity
                                 # sharded below, like the k/v it indexes)
        axes = [None] * ndim     # axes[0] = groups dim
        b_ax = _fit(shape[1], mesh, dp) or _fit(shape[1], mesh, ("data",))
        axes[1] = b_ax
        if ndim >= 3:
            # shard the biggest remaining dim; prefer model, add data axes
            # for context parallelism when batch is unsharded
            big = max(range(2, ndim), key=lambda i: shape[i])
            if b_ax is None:
                cand = _fit(shape[big], mesh, ("data", "model")) \
                    or _fit(shape[big], mesh, ("model",)) \
                    or _fit(shape[big], mesh, ("data",))
            else:
                cand = _fit(shape[big], mesh, ("model",))
            axes[big] = cand
        return P(*axes)

    return jax.tree.map(spec, cache_shapes)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

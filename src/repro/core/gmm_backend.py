"""Pluggable grouped-GEMM (gmm) backend registry.

Every grouped GEMM in the MoEBlaze core funnels through two primitives:

  * ``gmm(lhs, rhs, group_sizes)``    — (S, d) @ (E, d, h) -> (S, h), rows of
    ``lhs`` grouped by expert (``group_sizes`` sums to <= S; trailing rows
    belong to no group and produce zeros);
  * ``gmm_dw(lhs, dout, group_sizes)``— (S, d), (S, h) -> (E, d, h), the
    per-group weight gradient (contract the grouped row axis).

Both accumulate in fp32 and return ``lhs.dtype``.  The paper's fast path is
``jax.lax.ragged_dot[_general]``, but those symbols only exist on newer JAX —
this registry makes the primitive swappable per target (MegaBlocks-style)
instead of a hard import:

  * ``ragged``  — ``jax.lax.ragged_dot`` / ``ragged_dot_general``.  The XLA
    fast path; auto-disabled when either symbol is absent (e.g. JAX 0.4.37
    ships ``ragged_dot`` but not ``ragged_dot_general``).
  * ``segment`` — portable pure-``jnp`` fallback: per-group row mask + dense
    dot with fp32 accumulation.  Runs on any JAX >= 0.4.x, any device.
    Compute is O(E·S·d·h) like XLA's own CPU decomposition of ragged_dot;
    it exists for correctness/portability, not speed.
  * ``pallas``  — the ``kernels/gather_gmm.py`` work-item kernels (identity
    gather; ``interpret=True`` on CPU, real lowering on TPU).
  * ``pallas_fused`` — same kernels as a backend, plus the ``fused_moe``
    capability flag: ``moe_ffn_blaze`` routes whole SwiGLU layers through
    the fused dispatch→GEMM→combine kernel pair (no ``(L·k, ·)``
    intermediates in HBM, forward or backward).

Selection precedence (``resolve``):

  1. explicit ``backend=`` call-site argument,
  2. the active :func:`use_backend` context,
  3. a config field (``ModelConfig.gmm_backend`` / ``TrainConfig.gmm_backend``,
     passed via ``resolve(..., config=...)``),
  4. the ``REPRO_GMM_BACKEND`` environment variable,
  5. auto (first available of ``ragged``, ``segment``).

``pallas`` / ``pallas_fused`` are never auto-selected: in interpret mode they
are orders of magnitude slower than the XLA paths and exist as explicitly
requested kernel-validation targets.

    REPRO_GMM_BACKEND=segment python -m pytest -q          # force portable
    gmm(lhs, rhs, sizes, backend="ragged")                  # force fast path
    with use_backend("segment"):                            # scope, not env
        y = moe_ffn_blaze(...)

Resolution happens at *trace time* (inside jit it runs while the Python
function is being traced, so the chosen backend is baked into the jaxpr) and
is recorded in a :class:`ResolvedBackend` carrying the name plus jax-version
provenance.  Long-lived objects (``ServeEngine``, train steps) resolve once
at construction and hold the ``ResolvedBackend`` — mutating the environment
afterwards cannot retarget them.

The JAX-version support matrix lives in README.md; ``available_backends()``
reports what works on the running install.
"""

from __future__ import annotations

import contextlib
import os
from contextvars import ContextVar
from dataclasses import dataclass

import jax
import jax.numpy as jnp

ENV_VAR = "REPRO_GMM_BACKEND"

# Auto-selection order: fast XLA path first, portable fallback second.
_AUTO_PRIORITY = ("ragged", "segment")

#: the innermost active ``use_backend`` scope (None when outside any scope).
_ACTIVE: ContextVar[str | None] = ContextVar("repro_gmm_backend", default=None)


def _offsets_of(group_sizes: jax.Array) -> jax.Array:
    """(E,) group sizes -> (E+1,) exclusive prefix-sum offsets."""
    gs = group_sizes.astype(jnp.int32)
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(gs)])


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


class RaggedBackend:
    """``jax.lax.ragged_dot[_general]`` — the XLA grouped-GEMM fast path."""

    name = "ragged"

    @staticmethod
    def available() -> bool:
        return (hasattr(jax.lax, "ragged_dot")
                and hasattr(jax.lax, "ragged_dot_general")
                and hasattr(jax.lax, "RaggedDotDimensionNumbers"))

    @staticmethod
    def gmm(lhs, rhs, group_sizes):
        out = jax.lax.ragged_dot(lhs, rhs, group_sizes.astype(jnp.int32),
                                 preferred_element_type=jnp.float32)
        return out.astype(lhs.dtype)

    @staticmethod
    def gmm_dw(lhs, dout, group_sizes):
        dims = jax.lax.RaggedDotDimensionNumbers(
            dot_dimension_numbers=(((0,), (0,)), ((), ())),  # contract rows
            lhs_ragged_dimensions=[0],
            rhs_group_dimensions=[])
        out = jax.lax.ragged_dot_general(
            lhs, dout, group_sizes.astype(jnp.int32), dims,
            preferred_element_type=jnp.float32)
        return out.astype(lhs.dtype)


class SegmentBackend:
    """Portable pure-``jnp`` grouped GEMM: per-group mask + dense dot.

    A ``fori_loop`` over experts keeps the lowered program O(1) in E; each
    step masks the rows of the current group and runs one dense fp32 GEMM.
    Mathematically exact (no approximation), so it doubles as the oracle the
    parity tests compare every other backend against.
    """

    name = "segment"

    @staticmethod
    def available() -> bool:
        return True

    @staticmethod
    def gmm(lhs, rhs, group_sizes):
        S = lhs.shape[0]
        E, _, h = rhs.shape
        off = _offsets_of(group_sizes)
        rows = jnp.arange(S, dtype=jnp.int32)[:, None]

        def body(e, acc):
            w = jax.lax.dynamic_index_in_dim(rhs, e, 0, keepdims=False)
            mask = (rows >= off[e]) & (rows < off[e + 1])
            xm = jnp.where(mask, lhs, 0).astype(jnp.float32)
            return acc + xm @ w.astype(jnp.float32)

        acc = jnp.zeros((S, h), jnp.float32)
        return jax.lax.fori_loop(0, E, body, acc).astype(lhs.dtype)

    @staticmethod
    def gmm_dw(lhs, dout, group_sizes):
        E = group_sizes.shape[0]
        d, h = lhs.shape[1], dout.shape[1]
        off = _offsets_of(group_sizes)
        rows = jnp.arange(lhs.shape[0], dtype=jnp.int32)[:, None]

        def body(e, acc):
            mask = (rows >= off[e]) & (rows < off[e + 1])
            xm = jnp.where(mask, lhs, 0).astype(jnp.float32)
            dw = xm.T @ dout.astype(jnp.float32)
            return acc.at[e].set(dw)

        acc = jnp.zeros((E, d, h), jnp.float32)
        return jax.lax.fori_loop(0, E, body, acc).astype(lhs.dtype)


def _pallas_gmm_impl(lhs, rhs, group_sizes):
    from repro.kernels.gather_gmm import gather_gmm
    S = lhs.shape[0]
    # Backend contract: rows past the group-size total belong to no group and
    # are exact zeros.  The kernel now guarantees this itself: rows inside a
    # visited tile are zeroed by the in-tile gather mask, and tiles no work
    # item visits are zero-initialized in-kernel by make_work_items' filler
    # items (``bh`` is likewise clamped to a divisor of h in-kernel).
    return gather_gmm(lhs, jnp.arange(S, dtype=jnp.int32),
                      _offsets_of(group_sizes), rhs,
                      epilogue=False, interpret=True)


def _pallas_dw_impl(lhs, dout, group_sizes):
    from repro.kernels.gather_gmm import gmm_dw_pallas
    # Empty experts' (1, d, h) blocks are zero-initialized in-kernel (each
    # empty expert gets a dedicated efirst filler item) — no caller-side
    # masking needed.
    return gmm_dw_pallas(lhs, dout, _offsets_of(group_sizes), interpret=True)


# ``pallas_call`` has no JVP rule, so the kernels are wrapped in custom VJPs
# built from each other (the grouped GEMM is linear: d_lhs flows through the
# transposed weights, d_rhs is exactly the grouped weight gradient).  This
# keeps the backend contract uniform — every backend is differentiable by
# plain autodiff, not just inside the MoE layer's hand-written VJP.


@jax.custom_vjp
def _pallas_gmm(lhs, rhs, group_sizes):
    return _pallas_gmm_impl(lhs, rhs, group_sizes)


def _pallas_gmm_fwd(lhs, rhs, group_sizes):
    return _pallas_gmm_impl(lhs, rhs, group_sizes), (lhs, rhs, group_sizes)


def _pallas_gmm_bwd(res, dout):
    lhs, rhs, gs = res
    dlhs = _pallas_gmm_impl(dout, jnp.swapaxes(rhs, 1, 2), gs)
    drhs = _pallas_dw_impl(lhs, dout, gs)
    return dlhs.astype(lhs.dtype), drhs.astype(rhs.dtype), None


_pallas_gmm.defvjp(_pallas_gmm_fwd, _pallas_gmm_bwd)


@jax.custom_vjp
def _pallas_dw(lhs, dout, group_sizes):
    return _pallas_dw_impl(lhs, dout, group_sizes)


def _pallas_dw_fwd(lhs, dout, group_sizes):
    return _pallas_dw_impl(lhs, dout, group_sizes), (lhs, dout, group_sizes)


def _pallas_dw_bwd(res, ddw):
    lhs, dout, gs = res
    dlhs = _pallas_gmm_impl(dout, jnp.swapaxes(ddw, 1, 2), gs)
    ddout = _pallas_gmm_impl(lhs, ddw, gs)
    return dlhs.astype(lhs.dtype), ddout.astype(dout.dtype), None


_pallas_dw.defvjp(_pallas_dw_fwd, _pallas_dw_bwd)


class PallasBackend:
    """The ``kernels/gather_gmm.py`` work-item kernels with an identity
    gather (rows already in expert order).  ``interpret=True`` on CPU; on a
    real TPU the same grid/work-item structure lowers natively."""

    name = "pallas"

    @staticmethod
    def available() -> bool:
        try:
            import repro.kernels.gather_gmm  # noqa: F401
        except Exception:  # pragma: no cover - import guard
            return False
        return True

    @staticmethod
    def gmm(lhs, rhs, group_sizes):
        return _pallas_gmm(lhs, rhs, group_sizes)

    @staticmethod
    def gmm_dw(lhs, dout, group_sizes):
        return _pallas_dw(lhs, dout, group_sizes)


class PallasFusedBackend(PallasBackend):
    """Fully fused dispatch→GEMM→combine Pallas path (SonicMoE-style).

    As a grouped-GEMM backend it behaves exactly like ``pallas`` (same
    work-item kernels — the parity suite covers it for free); the extra
    ``fused_moe`` capability flag makes ``moe_ffn_blaze`` route SwiGLU
    layers to ``kernels.ops.moe_ffn_blaze_fused``, where the second grouped
    GEMM and the gated combine run inside the same grid pass and the
    backward replays the gather in-kernel — no ``(L·k, h)`` / ``(L·k, d)``
    intermediate exists in HBM in either direction.  Tile sizes come from
    ``repro.roofline.select_moe_tiles``.  Never auto-selected (interpret
    mode on CPU); request it explicitly like ``pallas``.
    """

    name = "pallas_fused"

    #: capability flag: ``moe_ffn_blaze`` routes whole SwiGLU MoE layers
    #: through the fused kernel pair instead of composing gmm/gmm_dw calls.
    fused_moe = True


# ---------------------------------------------------------------------------
# Registry + selection
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, object] = {
    b.name: b for b in (RaggedBackend, SegmentBackend, PallasBackend,
                        PallasFusedBackend)
}


def backend_names() -> list[str]:
    """All registered backend names (available or not)."""
    return list(_REGISTRY)


def available_backends() -> list[str]:
    """Backends that work on the running JAX install."""
    return [n for n, b in _REGISTRY.items() if b.available()]


@dataclass(frozen=True)
class ResolvedBackend:
    """A concrete, validated backend choice with provenance.

    ``name`` is always a registered, available backend; ``source`` records
    which precedence slot won (``arg`` | ``context`` | ``config`` | ``env`` |
    ``auto``); ``jax_version`` is the install the resolution was made on —
    together they make a BENCH record / step metric self-describing in mixed
    fleets where two hosts resolve the same config differently.  Frozen and
    hashable, so it can ride through jit static arguments unchanged."""

    name: str
    source: str
    jax_version: str

    def __str__(self) -> str:                   # pragma: no cover - trivial
        return self.name


def _unset(name) -> bool:
    """True when a precedence slot holds no explicit choice."""
    return name in (None, "", "auto")


def _validate(name: str) -> str:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown gmm backend {name!r}; known: {backend_names()}")
    if not _REGISTRY[name].available():
        raise RuntimeError(
            f"gmm backend {name!r} is not available on jax "
            f"{jax.__version__}; available: {available_backends()}")
    return name


@contextlib.contextmanager
def use_backend(name: str | None):
    """Scope the grouped-GEMM backend for everything traced inside the block.

    Sits between the call-site argument and config fields in the precedence
    chain, so ``with use_backend("segment"):`` retargets a whole train step /
    engine batch without touching configs or the process environment.  The
    name is validated eagerly (entering the scope raises on an unknown or
    unavailable backend); ``None``/"auto" makes the scope fully transparent —
    it neither selects nor masks an enclosing scope, so helpers can forward
    an optional pin via ``with use_backend(maybe_none):`` safely.  Scopes
    nest — the innermost non-transparent one wins."""
    if _unset(name):
        yield                       # transparent: inherit enclosing scope
        return
    _validate(name)
    token = _ACTIVE.set(name)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def active_backend() -> str | None:
    """The innermost ``use_backend`` scope's name, or None outside any."""
    return _ACTIVE.get()


def resolve(backend: str | ResolvedBackend | None = None, *,
            config: str | None = None) -> ResolvedBackend:
    """Resolve a backend request to a concrete :class:`ResolvedBackend`.

    Precedence: ``backend`` call-site argument > active :func:`use_backend`
    context > ``config`` (a ``gmm_backend`` config field) > the
    ``REPRO_GMM_BACKEND`` environment variable > auto priority.  A
    ``ResolvedBackend`` passed as ``backend`` is returned unchanged (already
    resolved upstream — threading it is free of re-resolution surprises)."""
    if isinstance(backend, ResolvedBackend):
        return backend
    chain = (("arg", backend),
             ("context", _ACTIVE.get()),
             ("config", config),
             ("env", os.environ.get(ENV_VAR, "").strip() or None))
    for source, cand in chain:
        if not _unset(cand):
            return ResolvedBackend(_validate(cand), source, jax.__version__)
    for cand in _AUTO_PRIORITY:
        if _REGISTRY[cand].available():
            return ResolvedBackend(cand, "auto", jax.__version__)
    raise RuntimeError(
        "no grouped-GEMM backend available on this JAX install "
        f"(jax {jax.__version__})")


def resolve_backend_name(name: str | ResolvedBackend | None = None, *,
                         config: str | None = None) -> str:
    """Resolve to a concrete, available backend *name* (:func:`resolve`
    without the provenance — kept for call sites that only need the str)."""
    return resolve(name, config=config).name


def get_backend(name: str | ResolvedBackend | None = None):
    """Return the backend object for ``name`` (or the resolved default)."""
    return _REGISTRY[resolve(name).name]


def gmm(lhs: jax.Array, rhs: jax.Array, group_sizes: jax.Array,
        *, backend: str | ResolvedBackend | None = None) -> jax.Array:
    """Grouped matmul: rows of ``lhs`` (grouped by ``group_sizes``) times the
    matching ``rhs[g]``.  (S, d) @ (E, d, h) -> (S, h)."""
    return get_backend(backend).gmm(lhs, rhs, group_sizes)


def gmm_dw(lhs: jax.Array, dout: jax.Array, group_sizes: jax.Array,
           *, backend: str | ResolvedBackend | None = None) -> jax.Array:
    """Per-group weight gradient: (S, d), (S, h) -> (E, d, h)."""
    return get_backend(backend).gmm_dw(lhs, dout, group_sizes)

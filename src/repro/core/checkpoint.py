"""Smart activation-checkpoint policies (paper §5.2), framework-wide.

The MoEBlaze layer's custom VJP already enforces the paper's residual set for
the expert FFN.  For the *rest* of the transformer layer (attention, norms,
dense FFNs) the same principle — "save GEMM outputs, recompute cheap
elementwise work" — is expressed as `jax.checkpoint` policies applied to the
scanned layer body.  Tensors are tagged with `checkpoint_name` at creation.
"""

from __future__ import annotations

import jax
from jax import checkpoint_policies as cp
from jax.ad_checkpoint import checkpoint_name

# Canonical tag names used across the model zoo.
FFN_A = "ffn_a"          # first-projection GEMM output (SiLU branch)
FFN_B = "ffn_b"          # gate-branch GEMM output
FFN_YSWI = "ffn_yswi"    # SwiGLU product
ATTN_OUT = "attn_out"    # attention output projection input
QKV = "qkv"              # fused QKV projection output
SSM_STATE = "ssm_state"  # recurrent-scan carry snapshots
MOE_GATES = "moe_gates"  # router top-k weights

# Tag sets per name-based policy.  ``repro.bench.memory`` derives its static
# activation estimator from these, so they are data, not just policy args.
POLICY_TAGS = {
    "none": (),
    # Paper policy: save the GEMM outputs (A, B, attention projections) and
    # Y_swi (Algorithm 1 line 11); recompute all other elementwise work.
    "paper": (FFN_A, FFN_B, FFN_YSWI, ATTN_OUT, QKV),
    # Beyond-paper: also drop Y_swi (recompute SiLU(A)·B in backward).
    "paper_min": (FFN_A, FFN_B, ATTN_OUT, QKV),
}

POLICIES = {
    # Save nothing; recompute the whole layer in backward (max memory saving).
    "none": cp.nothing_saveable,
    # Save everything (baseline — what plain autodiff of a scanned layer does).
    "full": cp.everything_saveable,
    # Classic: save all matmul outputs.
    "dots": cp.dots_with_no_batch_dims_saveable,
    "paper": cp.save_only_these_names(*POLICY_TAGS["paper"]),
    "paper_min": cp.save_only_these_names(*POLICY_TAGS["paper_min"]),
}


def apply_policy(fn, policy: str, prevent_cse: bool = False):
    """Wrap a layer function with the named checkpoint policy."""
    if policy == "full":
        return fn
    return jax.checkpoint(fn, policy=POLICIES[policy], prevent_cse=prevent_cse)


def tag(x, name: str):
    return checkpoint_name(x, name)


def tag_bytes_per_group(cfg, n_tokens: int) -> dict:
    """Bytes of each tagged tensor per scanned layer group, from shapes alone.

    Mirrors the ``tag(...)`` call sites in ``models/``: the q projection
    (QKV), the attention output projection (ATTN_OUT), the dense-FFN GEMM
    outputs and SwiGLU product (FFN_A/B/YSWI — the MoE expert FFN manages its
    own residuals inside the custom VJP), and the router top-k weights
    (MOE_GATES)."""
    import jax.numpy as jnp

    item = jnp.dtype(cfg.dtype).itemsize
    sizes = dict.fromkeys(
        (FFN_A, FFN_B, FFN_YSWI, ATTN_OUT, QKV, MOE_GATES), 0)
    for kind in cfg.block_pattern:
        has_attn = "attn" in kind or kind == "hymba"
        if has_attn:
            sizes[QKV] += n_tokens * cfg.num_heads * cfg.resolved_head_dim
            sizes[ATTN_OUT] += n_tokens * cfg.d_model
        if kind.endswith("moe"):
            sizes[MOE_GATES] += n_tokens * cfg.top_k
        elif has_attn:                     # dense FFN sublayer
            n = 3 if cfg.ffn_act == "swiglu" else 1
            for t in (FFN_A, FFN_B, FFN_YSWI)[:n]:
                sizes[t] += n_tokens * cfg.d_ff
    return {t: b * item for t, b in sizes.items()}


def estimate_saved_bytes(cfg, policy: str, n_tokens: int) -> int | None:
    """Static activation-residual estimate for a name-based policy, whole
    stack (``num_groups`` scanned groups).  Returns ``None`` for policies not
    expressible as tag sets (``full``, ``dots``)."""
    if policy not in POLICY_TAGS:
        return None
    per_group = tag_bytes_per_group(cfg, n_tokens)
    tags = POLICY_TAGS[policy]
    return cfg.num_groups * sum(per_group[t] for t in tags)

"""Smart activation-checkpoint policies (paper §5.2), framework-wide.

The MoEBlaze layer's custom VJP already enforces the paper's residual set for
the expert FFN.  For the *rest* of the transformer layer (attention, norms,
dense FFNs) the same principle — "save GEMM outputs, recompute cheap
elementwise work" — is expressed as `jax.checkpoint` policies applied to the
scanned layer body.  Tensors are tagged with `checkpoint_name` at creation.
"""

from __future__ import annotations

import jax
from jax import checkpoint_policies as cp
from jax.ad_checkpoint import checkpoint_name

# Canonical tag names used across the model zoo.
FFN_A = "ffn_a"          # first-projection GEMM output (SiLU branch)
FFN_B = "ffn_b"          # gate-branch GEMM output
FFN_YSWI = "ffn_yswi"    # SwiGLU product
ATTN_OUT = "attn_out"    # attention output projection input
QKV = "qkv"              # fused QKV projection output
SSM_STATE = "ssm_state"  # recurrent-scan carry snapshots
MOE_GATES = "moe_gates"  # router top-k weights

POLICIES = {
    # Save nothing; recompute the whole layer in backward (max memory saving).
    "none": cp.nothing_saveable,
    # Save everything (baseline — what plain autodiff of a scanned layer does).
    "full": cp.everything_saveable,
    # Classic: save all matmul outputs.
    "dots": cp.dots_with_no_batch_dims_saveable,
    # Paper policy: save the GEMM outputs (A, B, attention projections) and
    # Y_swi (Algorithm 1 line 11); recompute all other elementwise work.
    "paper": cp.save_only_these_names(FFN_A, FFN_B, FFN_YSWI, ATTN_OUT, QKV),
    # Beyond-paper: also drop Y_swi (recompute SiLU(A)·B in backward).
    "paper_min": cp.save_only_these_names(FFN_A, FFN_B, ATTN_OUT, QKV),
}


def apply_policy(fn, policy: str, prevent_cse: bool = False):
    """Wrap a layer function with the named checkpoint policy."""
    if policy == "full":
        return fn
    return jax.checkpoint(fn, policy=POLICIES[policy], prevent_cse=prevent_cse)


def tag(x, name: str):
    return checkpoint_name(x, name)

"""Smart activation-checkpoint *plans* (paper §5.2), framework-wide.

The checkpointing surface is a first-class :class:`CheckpointPlan`: a frozen
mapping from each canonical tensor tag (``FFN_A`` … ``MOE_GATES``) to a
decision (``save`` | ``recompute``), optionally scoped per block kind
(``attn_ffn``, ``*moe``, ``ssm``, …).  One plan drives every consumer:

  * the ``jax.checkpoint`` policy applied to the scanned layer body
    (``plan_policies`` — group-level when the decisions are uniform across
    the block pattern, per-sublayer when a tag is decided differently in two
    kinds that both materialize it);
  * the MoE layer's custom-VJP residual set (``moe_residual_mode`` — the
    paper's A/B/Y_swi policy, Algorithm 1), via *explicit* ``moe``-scoped
    decisions; the deprecated ``ModelConfig.save_yswi`` bool remains the
    fallback alias;
  * the static activation estimator (``CheckpointPlan.estimate_saved_bytes``)
    that ``repro.bench.memory`` gates against and that
    :meth:`CheckpointPlan.fit` walks for budget-driven auto-selection.

Plans are named (``"paper"``, ``"paper_min"``, ``"none"``, ``"full"``,
``"dots"`` — the registry) or spelled as specs::

    save=ffn_a,ffn_b,qkv;moe:recompute=ffn_yswi

i.e. ``;``-separated segments of ``[scope:]save|recompute=tag,...``.
Unscoped segments build the default decision set (everything starts
``recompute``); scoped segments override single tags for the block kinds the
scope matches.  ``ModelConfig.remat_policy`` accepts either form;
``resolve_plan`` follows the same precedence discipline as
``repro.core.gmm_backend.resolve`` (call-site arg > config field > default)
and returns provenance.

Tensors are tagged with ``checkpoint_name`` at creation (``tag``); the MoE
expert FFN manages its residuals inside the custom VJP instead (see
``core/moe_layer.py``).
"""

from __future__ import annotations

import fnmatch
from dataclasses import dataclass
from functools import lru_cache

import jax
from jax import checkpoint_policies as cp
from jax.ad_checkpoint import checkpoint_name

# ---------------------------------------------------------------------------
# Canonical tags + block-kind scopes
# ---------------------------------------------------------------------------

# Canonical tag names used across the model zoo.
FFN_A = "ffn_a"          # first-projection GEMM output (SiLU branch)
FFN_B = "ffn_b"          # gate-branch GEMM output
FFN_YSWI = "ffn_yswi"    # SwiGLU product
ATTN_OUT = "attn_out"    # attention output projection input
QKV = "qkv"              # fused QKV projection output
SSM_STATE = "ssm_state"  # recurrent-scan carry snapshots
MOE_GATES = "moe_gates"  # router top-k weights

CANON_TAGS = (FFN_A, FFN_B, FFN_YSWI, ATTN_OUT, QKV, SSM_STATE, MOE_GATES)

SAVE = "save"
RECOMPUTE = "recompute"
_DECISIONS = (SAVE, RECOMPUTE)

#: block kinds the model zoo assembles (``ModelConfig.block_pattern``).
BLOCK_KINDS = ("attn_ffn", "attn_local_ffn", "attn_moe", "attn_local_moe",
               "mlstm", "slstm", "hymba")

#: convenience scope aliases -> the block kinds they cover.  Exact kind names
#: and fnmatch patterns (``*moe``) are also accepted as scopes.
SCOPE_ALIASES = {
    "moe": ("attn_moe", "attn_local_moe"),
    "ffn": ("attn_ffn", "attn_local_ffn", "hymba"),
    "attn": ("attn_ffn", "attn_local_ffn", "attn_moe", "attn_local_moe",
             "hymba"),
    "ssm": ("mlstm", "slstm", "hymba"),
}

#: the kinds whose scoped decisions drive the MoE custom-VJP residual set.
MOE_SCOPE_KINDS = SCOPE_ALIASES["moe"]


def scope_matches(scope: str, kind: str) -> bool:
    """Whether a spec scope covers a block kind (alias, exact, or glob)."""
    if scope in SCOPE_ALIASES:
        return kind in SCOPE_ALIASES[scope]
    if any(ch in scope for ch in "*?["):
        return fnmatch.fnmatchcase(kind, scope)
    return scope == kind


def _validate_scope(scope: str) -> str:
    if scope in SCOPE_ALIASES or scope in BLOCK_KINDS:
        return scope
    if any(ch in scope for ch in "*?["):
        if any(fnmatch.fnmatchcase(k, scope) for k in BLOCK_KINDS):
            return scope
        raise ValueError(
            f"checkpoint-plan scope pattern {scope!r} matches no block kind; "
            f"kinds: {BLOCK_KINDS}")
    raise ValueError(
        f"unknown checkpoint-plan scope {scope!r}; known kinds "
        f"{BLOCK_KINDS}, aliases {tuple(SCOPE_ALIASES)}, or a glob pattern")


def kind_tags(kind: str) -> tuple[str, ...]:
    """Tags actually materialized in a block kind — mirrors the ``tag(...)``
    call sites in ``models/`` plus the MoE/SSM internal residuals.  Drives
    scope semantics, the group-vs-per-kind policy choice, and the static
    estimator."""
    if kind in ("mlstm", "slstm"):
        return (SSM_STATE,)
    if kind == "hymba":
        return (QKV, ATTN_OUT, SSM_STATE, FFN_A, FFN_B, FFN_YSWI)
    if kind.endswith("moe"):
        return (QKV, ATTN_OUT, MOE_GATES)
    return (QKV, ATTN_OUT, FFN_A, FFN_B, FFN_YSWI)


# ---------------------------------------------------------------------------
# CheckpointPlan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CheckpointPlan:
    """A per-tag, per-block-kind activation-checkpoint decision map.

    ``saved`` is the default-scope save set (every tag not listed is
    ``recompute``); ``overrides`` are explicit scoped decisions
    ``(scope, tag, decision)`` applied in order (later wins) on top of the
    default for the block kinds the scope matches.  ``special`` marks the two
    policies not expressible as tag sets (``full``, ``dots``).  Frozen and
    hashable, so plans ride through jit static arguments and dict keys."""

    saved: tuple[str, ...] = ()
    overrides: tuple[tuple[str, str, str], ...] = ()
    name: str = ""
    special: str = ""               # "" | "full" | "dots"

    def __post_init__(self):
        if self.special not in ("", "full", "dots"):
            raise ValueError(f"unknown special policy {self.special!r}")
        if self.special and self.saved:
            raise ValueError(
                f"special policy {self.special!r} cannot carry a default "
                "save set (its save decisions are not tag-based); scoped "
                "overrides are allowed and reach the MoE custom VJP")
        for t in self.saved:
            if t not in CANON_TAGS:
                raise ValueError(
                    f"unknown checkpoint tag {t!r}; known: {CANON_TAGS}")
        norm = tuple(t for t in CANON_TAGS if t in self.saved)
        object.__setattr__(self, "saved", norm)
        for scope, t, d in self.overrides:
            _validate_scope(scope)
            if t not in CANON_TAGS:
                raise ValueError(
                    f"unknown checkpoint tag {t!r}; known: {CANON_TAGS}")
            if d not in _DECISIONS:
                raise ValueError(
                    f"unknown decision {d!r}; known: {_DECISIONS}")
        # Dedupe identical (scope, tag, decision) triples keeping the LAST
        # occurrence: decisions are last-match-wins, so dropping a repeated
        # final directive in favour of its first occurrence would silently
        # resurrect an intervening opposite decision.
        seen, kept = set(), []
        for item in reversed(self.overrides):
            if item not in seen:
                seen.add(item)
                kept.append(item)
        object.__setattr__(self, "overrides", tuple(reversed(kept)))

    # -- decisions ----------------------------------------------------------

    def decision(self, tag: str, kind: str | None = None) -> str:
        """``save`` | ``recompute`` for a tag (in a block kind's scope)."""
        if self.special == "full":
            dec = SAVE
        elif self.special == "dots":    # matmul outputs are what dots saves
            dec = SAVE if tag in (FFN_A, FFN_B, ATTN_OUT, QKV) else RECOMPUTE
        else:
            dec = SAVE if tag in self.saved else RECOMPUTE
        if kind is not None:
            for scope, t, d in self.overrides:
                if t == tag and scope_matches(scope, kind):
                    dec = d
        return dec

    def override_for(self, tag: str, kinds: tuple[str, ...]) -> str | None:
        """The explicit scoped decision for ``tag`` over any of ``kinds``
        (last matching override wins), or None when the plan leaves it to
        the default scope / legacy config aliases."""
        dec = None
        for scope, t, d in self.overrides:
            if t == tag and any(scope_matches(scope, k) for k in kinds):
                dec = d
        return dec

    def scoped_saved(self, kind: str) -> tuple[str, ...]:
        """The effective save set for one block kind."""
        return tuple(t for t in CANON_TAGS
                     if self.decision(t, kind) == SAVE)

    # -- rendering ----------------------------------------------------------

    def spec(self) -> str:
        """Canonical spec string; ``parse_plan(p.spec()) == p``."""
        if self.name:
            return self.name
        head = self.special or "save=" + ",".join(self.saved)
        segs = [head]
        segs += [f"{scope}:{d}={t}" for scope, t, d in self.overrides]
        return ";".join(segs)

    def __str__(self) -> str:                   # pragma: no cover - trivial
        return self.spec()

    # -- estimation + budget fit -------------------------------------------

    def estimate_saved_bytes(self, cfg, n_tokens: int, *,
                             batch: int = 1) -> int | None:
        """Static activation-residual estimate for the whole stack
        (``cfg.num_groups`` scanned groups), from shapes + decisions alone.
        ``batch`` (the sequence count inside ``n_tokens``) only refines the
        SSM_STATE carry-snapshot floor — all other tags scale with tokens.
        Returns ``None`` for the special policies (``full``, ``dots``) —
        they are not expressible as tag sets."""
        if self.special:
            return None
        total = 0
        for kind, sizes in tag_bytes_by_kind(cfg, n_tokens, batch=batch):
            saved = self.scoped_saved(kind)
            total += sum(sizes[t] for t in kind_tags(kind) if t in saved)
        return cfg.num_groups * total

    @classmethod
    def fit(cls, cfg, n_tokens: int, hbm_budget: int, *, batch: int = 1,
            candidates: list["CheckpointPlan"] | None = None,
            prefer: "CheckpointPlan | None" = None, rank: str = "peak",
            mode: str | None = None, n_model: int = 1, n_node: int = 1,
            base: str = "train") -> "FitResult":
        """Budget-driven auto-selection.

        ``rank="peak"`` (default) walks every candidate through the
        per-phase liveness simulator (:mod:`repro.core.memsim`) and picks
        the cheapest-*recompute* plan whose simulated per-device **peak**
        (transient spikes, a2a capacity buffers and optimizer state
        included — what actually OOMs) fits under ``hbm_budget`` bytes.
        ``mode``/``n_model``/``n_node`` select the MoE distribution being
        simulated
        and ``base`` what sits under the activation timeline (see
        :func:`memsim.simulate`; the default ``"train"`` budgets the full
        train step: params + grads + AdamW m/v + activations).

        ``rank="residual"`` is the PR-5 accountant: rank by
        :meth:`estimate_saved_bytes` and compare *resident residuals* to
        the budget.  It is blind to transient peaks — kept for comparison
        (and regression-pinned by the test suite).

        ``candidates`` defaults to :func:`fit_candidates` — the registry
        plans plus, on MoE configs, ``full``-seeded scoped specs like
        ``full;moe:recompute=ffn_yswi`` that trade the custom-VJP residuals
        for replay GEMMs.  ``prefer`` (e.g. an explicit ``--remat-policy``
        next to ``--hbm-budget``) is tried first and wins whenever it fits.
        When nothing fits, the lowest-peak (or least-saving) candidate is
        chosen — the budget is a target, not a hard guarantee, and the
        caller can read ``fits`` off the table."""
        if rank not in ("peak", "residual"):
            raise ValueError(f"unknown fit rank {rank!r}; peak|residual")
        if rank == "residual":
            return cls._fit_residual(cfg, n_tokens, hbm_budget, batch=batch,
                                     candidates=candidates, prefer=prefer)
        from repro.core import memsim
        if candidates is None:
            candidates = fit_candidates(cfg)

        def sim(p):
            return memsim.simulate(cfg, n_tokens, batch=batch, plan=p,
                                   mode=mode, n_model=n_model,
                                   n_node=n_node, base=base)

        rows = [(p, sim(p)) for p in candidates]
        rows.sort(key=lambda pt: (pt[1].recompute_bytes, pt[1].peak_bytes))
        if prefer is not None:
            rows = [(prefer, sim(prefer))] + \
                [r for r in rows if r[0] != prefer]
        chosen = next((p for p, t in rows if t.peak_bytes <= hbm_budget),
                      None)
        if chosen is None:
            chosen = min(rows, key=lambda pt: pt[1].peak_bytes)[0]
        table = tuple(
            FitRow(spec=p.spec(),
                   est_saved_bytes=p.estimate_saved_bytes(
                       cfg, n_tokens, batch=batch),
                   fits=t.peak_bytes <= hbm_budget, chosen=p == chosen,
                   sim_peak_bytes=t.peak_bytes, peak_phase=t.peak_phase)
            for p, t in rows)
        timeline = next(t for p, t in rows if p == chosen)
        return FitResult(plan=chosen, budget_bytes=int(hbm_budget),
                         table=table, rank="peak", base=base,
                         timeline=timeline)

    @classmethod
    def _fit_residual(cls, cfg, n_tokens: int, hbm_budget: int, *,
                      batch: int = 1, candidates=None,
                      prefer=None) -> "FitResult":
        if candidates is None:
            candidates = [p for p in PLAN_REGISTRY.values() if not p.special]
        rows = [(p, p.estimate_saved_bytes(cfg, n_tokens, batch=batch))
                for p in candidates]
        rows = [(p, e) for p, e in rows if e is not None]
        if not rows:
            raise ValueError("no estimable candidate plans to fit")
        rows.sort(key=lambda pe: -pe[1])
        if prefer is not None:
            e = prefer.estimate_saved_bytes(cfg, n_tokens, batch=batch)
            if e is None:
                raise ValueError(
                    f"preferred plan {prefer.spec()!r} is not statically "
                    "estimable and cannot enter a residual-rank budget fit")
            rows = [(prefer, e)] + [r for r in rows if r[0] != prefer]
        chosen = next((p for p, e in rows if e <= hbm_budget), None)
        if chosen is None:
            chosen = min(rows, key=lambda pe: pe[1])[0]
        table = tuple(
            FitRow(spec=p.spec(), est_saved_bytes=int(e),
                   fits=e <= hbm_budget, chosen=p == chosen)
            for p, e in rows)
        return FitResult(plan=chosen, budget_bytes=int(hbm_budget),
                         table=table, rank="residual")


def fit_candidates(cfg) -> list[CheckpointPlan]:
    """The default candidate set of a peak-ranked fit: every registry plan
    (the simulator makes ``full``/``dots`` rankable), plus — when the block
    pattern has an MoE kind — ``full``-seeded scoped specs that peel the MoE
    custom-VJP residuals off one step at a time (``ffn_yswi`` recomputed,
    then A/B too, replaying two grouped GEMMs in backward).  Scoped variants
    of the *wrapped* plans are not enumerated: under ``jax.checkpoint`` the
    VJP residuals are transient, so those specs simulate identically to
    their seeds.  Per-layer-depth scoping is likewise out: layers execute
    under one ``lax.scan``, which cannot apply a different policy per
    depth."""
    plans = [PLAN_REGISTRY[n] for n in plan_order()]
    if any(k.endswith("moe") for k in cfg.block_pattern):
        plans += [parse_plan("full;moe:recompute=ffn_yswi"),
                  parse_plan("full;moe:recompute=ffn_a,ffn_b,ffn_yswi")]
    return plans


@dataclass(frozen=True)
class FitRow:
    spec: str
    est_saved_bytes: int | None
    fits: bool
    chosen: bool
    sim_peak_bytes: int | None = None
    peak_phase: str = ""


@dataclass(frozen=True)
class FitResult:
    """Outcome of :meth:`CheckpointPlan.fit` — the chosen plan plus the full
    decision table (every candidate's estimate, simulated peak and fit
    verdict).  ``timeline`` is the chosen plan's simulated phase timeline
    (None under ``rank="residual"``)."""

    plan: CheckpointPlan
    budget_bytes: int
    table: tuple[FitRow, ...]
    rank: str = "peak"
    base: str = "train"
    timeline: "object | None" = None

    @property
    def resolved(self) -> "ResolvedPlan":
        return ResolvedPlan(self.plan, "fit")


# ---------------------------------------------------------------------------
# Registry + spec parser
# ---------------------------------------------------------------------------

PLAN_REGISTRY: dict[str, CheckpointPlan] = {
    # Save nothing; recompute the whole layer in backward (max saving).
    "none": CheckpointPlan(name="none"),
    # Paper policy: save the GEMM outputs (A, B, attention projections) and
    # Y_swi (Algorithm 1 line 11); recompute all other elementwise work.
    "paper": CheckpointPlan(
        saved=(FFN_A, FFN_B, FFN_YSWI, ATTN_OUT, QKV), name="paper"),
    # Beyond-paper: also drop Y_swi (recompute SiLU(A)·B in backward).
    "paper_min": CheckpointPlan(
        saved=(FFN_A, FFN_B, ATTN_OUT, QKV), name="paper_min"),
    # Save everything (what plain autodiff of a scanned layer does).
    "full": CheckpointPlan(name="full", special="full"),
    # Classic: save all matmul outputs.
    "dots": CheckpointPlan(name="dots", special="dots"),
}


def plan_order() -> tuple[str, ...]:
    """Registry plan names ordered by how much they save: tag plans by
    ascending save-set size, then the special policies.  The bench suites'
    sweep order (``repro.bench.memory.POLICY_ORDER``) derives from this."""
    tags = sorted((p for p in PLAN_REGISTRY.values() if not p.special),
                  key=lambda p: (len(p.saved), p.name))
    spec = sorted((p for p in PLAN_REGISTRY.values() if p.special),
                  key=lambda p: p.name)
    return tuple(p.name for p in tags + spec)


@lru_cache(maxsize=None)
def parse_plan(spec: str) -> CheckpointPlan:
    """Parse a plan spec (or registry name) to a :class:`CheckpointPlan`.

    Grammar: ``spec := segment (';' segment)*``;
    ``segment := [scope ':'] ('save'|'recompute') '=' tag (',' tag)*``, or a
    bare registry name as a *seed* segment — ``"paper;moe:recompute=
    ffn_yswi"`` starts from the paper save set, ``"full;moe:recompute=
    ffn_a,ffn_b"`` keeps save-everything for the scanned stack while
    shrinking the MoE custom-VJP residuals.  Unscoped ``save``/``recompute``
    segments build the default save set (starting empty — all-recompute);
    scoped segments become per-kind overrides.  Raises ``ValueError`` on
    anything unknown."""
    if not isinstance(spec, str):
        raise ValueError(f"checkpoint plan spec must be a str, got {spec!r}")
    if spec in PLAN_REGISTRY:
        return PLAN_REGISTRY[spec]
    if "=" not in spec and ";" not in spec:
        raise ValueError(
            f"unknown checkpoint plan {spec!r}: not a registered name "
            f"({tuple(PLAN_REGISTRY)}) and not a spec "
            "('[scope:]save|recompute=tag,...' segments joined by ';')")
    saved: list[str] = []
    overrides: list[tuple[str, str, str]] = []
    special = ""
    for seg in spec.split(";"):
        seg = seg.strip()
        if not seg:
            continue
        if "=" not in seg:                      # seed segment: registry name
            if seg not in PLAN_REGISTRY:
                raise ValueError(
                    f"bad plan segment {seg!r}: not a registry name "
                    f"({tuple(PLAN_REGISTRY)}) and not "
                    "'[scope:]save|recompute=tag,...'")
            seed = PLAN_REGISTRY[seg]
            if seed.special:
                special = seed.special
            for t in seed.saved:
                if t not in saved:
                    saved.append(t)
            continue
        head, _, tail = seg.partition("=")
        scope = None
        directive = head.strip()
        if ":" in directive:
            scope, _, directive = directive.partition(":")
            scope = _validate_scope(scope.strip())
            directive = directive.strip()
        if directive not in _DECISIONS:
            raise ValueError(
                f"bad plan segment {seg!r}: directive {directive!r} "
                f"not in {_DECISIONS}")
        tags = [t.strip() for t in tail.split(",") if t.strip()]
        for t in tags:
            if t not in CANON_TAGS:
                raise ValueError(
                    f"bad plan segment {seg!r}: unknown tag {t!r}; "
                    f"known: {CANON_TAGS}")
            if scope is None:
                if directive == SAVE and t not in saved:
                    saved.append(t)
                elif directive == RECOMPUTE and t in saved:
                    saved.remove(t)
            else:
                overrides.append((scope, t, directive))
    return CheckpointPlan(saved=tuple(saved), overrides=tuple(overrides),
                          special=special)


def get_plan(name_or_spec) -> CheckpointPlan:
    """Registry name, spec string, plan, or resolved plan ->
    :class:`CheckpointPlan`."""
    if isinstance(name_or_spec, ResolvedPlan):
        return name_or_spec.plan
    if isinstance(name_or_spec, CheckpointPlan):
        return name_or_spec
    return parse_plan(name_or_spec)


# ---------------------------------------------------------------------------
# Resolution (provenance discipline mirrors core/gmm_backend.resolve)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ResolvedPlan:
    """A concrete plan choice with provenance: which precedence slot won
    (``arg`` | ``config`` | ``default`` | ``fit``).  ``spec`` is the
    canonical rendering — what BENCH records, dryrun output, and train
    ``step_hook`` metrics stamp."""

    plan: CheckpointPlan
    source: str

    @property
    def spec(self) -> str:
        return self.plan.spec()

    def __str__(self) -> str:                   # pragma: no cover - trivial
        return self.spec


def resolve_plan(policy: "str | CheckpointPlan | ResolvedPlan | None" = None,
                 *, config: "str | None" = None) -> ResolvedPlan:
    """Resolve a checkpoint-plan request to a :class:`ResolvedPlan`.

    Precedence: ``policy`` call-site argument > ``config`` (the
    ``ModelConfig.remat_policy`` field, name or spec) > the ``"none"``
    default.  A ``ResolvedPlan`` passed as ``policy`` is returned unchanged
    (already resolved upstream)."""
    if isinstance(policy, ResolvedPlan):
        return policy
    for source, cand in (("arg", policy), ("config", config)):
        if cand is None or cand in ("", "auto"):
            continue
        return ResolvedPlan(get_plan(cand), source)
    return ResolvedPlan(PLAN_REGISTRY["none"], "default")


# ---------------------------------------------------------------------------
# Execution: jax.checkpoint policies from plans
# ---------------------------------------------------------------------------


def _names_policy(tags: tuple[str, ...]):
    return cp.save_only_these_names(*tags) if tags else cp.nothing_saveable


def _flat_policy(plan: CheckpointPlan):
    """The scope-blind policy object (default-scope decisions only)."""
    if plan.special == "full":
        return cp.everything_saveable
    if plan.special == "dots":
        return cp.dots_with_no_batch_dims_saveable
    return _names_policy(plan.saved)


def plan_policies(plan: CheckpointPlan, block_pattern: tuple[str, ...]):
    """How to apply a plan to a scanned group of ``block_pattern`` sublayers.

    Returns ``(mode, payload)``:

      * ``("full", None)`` — no remat wrap at all;
      * ``("group", policy)`` — one ``jax.checkpoint`` around the whole
        group.  Chosen whenever no tag is decided differently in two kinds
        that both materialize it — then the union name set is *exactly*
        equivalent to per-kind application (tags are kind-unique otherwise),
        and for uniform named plans it is bit-identical to the legacy string
        path;
      * ``("per_kind", {kind: policy})`` — the plan scopes a shared tag
        (e.g. QKV saved in ``attn_ffn`` but recomputed in ``attn_moe``)
        differently across kinds present in the pattern: each sublayer gets
        its own ``jax.checkpoint`` with its kind's scoped policy.
    """
    if plan.special == "full":
        return "full", None
    if plan.special == "dots":
        return "group", cp.dots_with_no_batch_dims_saveable
    per_kind = {k: tuple(t for t in kind_tags(k)
                         if t in plan.scoped_saved(k))
                for k in dict.fromkeys(block_pattern)}
    decided: dict[str, bool] = {}
    conflict = False
    for k, saved in per_kind.items():
        for t in kind_tags(k):
            d = t in saved
            if decided.setdefault(t, d) != d:
                conflict = True
    if not conflict:
        union = tuple(t for t in CANON_TAGS
                      if any(t in s for s in per_kind.values()))
        return "group", _names_policy(union)
    return "per_kind", {k: _names_policy(s) for k, s in per_kind.items()}


def apply_policy(fn, policy, prevent_cse: bool = False):
    """Wrap a layer function with a named/spec plan's *default-scope* policy
    (legacy helper; ``models/transformer.py`` uses :func:`plan_policies` for
    scope-aware application)."""
    plan = resolve_plan(policy).plan
    if plan.special == "full":
        return fn
    return jax.checkpoint(fn, policy=_flat_policy(plan),
                          prevent_cse=prevent_cse)


def tag(x, name: str):
    return checkpoint_name(x, name)


# ---------------------------------------------------------------------------
# MoE custom-VJP residual mode
# ---------------------------------------------------------------------------

#: residual modes of the MoE custom VJP (see core/moe_layer.py):
#:   ab_yswi — save A, B and Y_swi (paper-faithful Algorithm 1 line 11);
#:   ab      — save A, B; recompute Y_swi = SiLU(A)·B in backward;
#:   x       — save neither: recompute A, B (two extra grouped GEMMs) and
#:             Y_swi from the unpermuted input in backward (max saving).
MOE_RESIDUAL_MODES = ("ab_yswi", "ab", "x")


def moe_residual_mode(cfg) -> str:
    """The MoE custom-VJP residual set under ``cfg``'s resolved plan.

    Only *explicit* ``moe``-scoped decisions override the deprecated
    ``cfg.save_yswi`` alias — the default (unscoped) save set governs the
    checkpoint-name remat of the scanned layer, never the hand-written VJP,
    so legacy configs keep their exact behavior.  FFN_A/FFN_B are coupled
    residuals in the VJP (both sides of the SwiGLU first layer); deciding
    them apart raises."""
    plan = resolve_plan(config=cfg.remat_policy).plan
    oa = plan.override_for(FFN_A, MOE_SCOPE_KINDS)
    ob = plan.override_for(FFN_B, MOE_SCOPE_KINDS)
    oy = plan.override_for(FFN_YSWI, MOE_SCOPE_KINDS)
    if oa != ob:
        raise ValueError(
            "FFN_A and FFN_B are coupled residuals in the MoE custom VJP; "
            f"plan {plan.spec()!r} decides them apart "
            f"(ffn_a={oa}, ffn_b={ob})")
    save_ab = oa != RECOMPUTE                   # default: save (paper)
    save_y = cfg.save_yswi if oy is None else oy == SAVE
    if not save_ab:
        if oy == SAVE:
            raise ValueError(
                "FFN_YSWI cannot be saved while FFN_A/FFN_B are recomputed "
                f"in the MoE scope (plan {plan.spec()!r}): the backward "
                "needs A and B regardless, so saving Y_swi is pure waste")
        return "x"
    return "ab_yswi" if save_y else "ab"


# ---------------------------------------------------------------------------
# Static byte accounting
# ---------------------------------------------------------------------------

#: chunk sizes of the recurrent scans in models/ssm.py — one f32 carry
#: snapshot survives per chunk under autodiff of the lax.scan.
_SSM_SCAN_CHUNK = {"mlstm": 256, "slstm": 1024, "hymba": 256}


def _ssm_state_bytes(cfg, kind: str, n_tokens: int, batch: int = 1) -> int:
    """SSM_STATE bytes per scanned group: the per-chunk carry snapshots of
    the recurrent scans (always f32, independent of ``cfg.dtype``).  The
    scans clamp ``chunk = min(chunk, S)``, so even a sub-chunk sequence
    holds one carry per batch row — ``batch`` is the snapshot floor."""
    snaps = max(n_tokens // _SSM_SCAN_CHUNK[kind], batch, 1)
    if kind == "mlstm":
        H = cfg.num_heads
        dhh = 2 * cfg.d_model // H
        elems = H * (dhh * dhh + dhh + 1)       # C (D,D) + n (D,) + m ()
    elif kind == "slstm":
        elems = 3 * cfg.d_model                 # c, n, m
    else:                                       # hymba mamba heads
        elems = cfg.ssm_heads * cfg.resolved_head_dim * cfg.ssm_state
    return snaps * elems * 4


def tag_bytes_by_kind(cfg, n_tokens: int, *,
                      batch: int = 1) -> tuple[tuple[str, dict], ...]:
    """Bytes of each tagged tensor per block-pattern slot, from shapes alone.

    One ``(kind, {tag: bytes})`` per entry of ``cfg.block_pattern``, mirroring
    the ``tag(...)`` call sites in ``models/``: the q projection (QKV), the
    attention output projection (ATTN_OUT), the dense-FFN GEMM outputs and
    SwiGLU product (FFN_A/B/YSWI — the MoE expert FFN manages its own
    residuals inside the custom VJP), the router top-k weights (MOE_GATES),
    and the recurrent-scan carry snapshots (SSM_STATE) of the ssm/hybrid
    kinds."""
    import jax.numpy as jnp

    item = jnp.dtype(cfg.dtype).itemsize
    out = []
    for kind in cfg.block_pattern:
        sizes = dict.fromkeys(CANON_TAGS, 0)
        has_attn = "attn" in kind or kind == "hymba"
        if has_attn:
            sizes[QKV] = n_tokens * cfg.num_heads * cfg.resolved_head_dim
            sizes[ATTN_OUT] = n_tokens * cfg.d_model
        if kind.endswith("moe"):
            sizes[MOE_GATES] = n_tokens * cfg.top_k
        elif has_attn:                          # dense FFN sublayer
            n = 3 if cfg.ffn_act == "swiglu" else 1
            for t in (FFN_A, FFN_B, FFN_YSWI)[:n]:
                sizes[t] = n_tokens * cfg.d_ff
        sizes = {t: b * item for t, b in sizes.items()}
        if kind in _SSM_SCAN_CHUNK:
            sizes[SSM_STATE] = _ssm_state_bytes(cfg, kind, n_tokens, batch)
        out.append((kind, sizes))
    return tuple(out)


def tag_bytes_per_group(cfg, n_tokens: int, *, batch: int = 1) -> dict:
    """Summed-over-pattern view of :func:`tag_bytes_by_kind` (back-compat)."""
    totals = dict.fromkeys(CANON_TAGS, 0)
    for _, sizes in tag_bytes_by_kind(cfg, n_tokens, batch=batch):
        for t, b in sizes.items():
            totals[t] += b
    return totals


def estimate_saved_bytes(cfg, policy, n_tokens: int, *,
                         batch: int = 1) -> int | None:
    """Static activation-residual estimate for a plan (name, spec, or
    object), whole stack.  Returns ``None`` for plans not expressible as tag
    sets (``full``, ``dots``).  Thin wrapper over
    :meth:`CheckpointPlan.estimate_saved_bytes`."""
    return resolve_plan(policy).plan.estimate_saved_bytes(cfg, n_tokens,
                                                          batch=batch)


def parse_size(s: "str | int | float") -> int:
    """Parse a byte size: plain numbers or ``KiB/MiB/GiB/KB/MB/GB`` suffixes
    (``--hbm-budget 3.5GiB``)."""
    if isinstance(s, (int, float)):
        return int(s)
    t = s.strip().lower()
    units = {"kib": 2**10, "mib": 2**20, "gib": 2**30,
             "kb": 1e3, "mb": 1e6, "gb": 1e9, "b": 1}
    for suf, mul in units.items():
        if t.endswith(suf):
            return int(float(t[:-len(suf)]) * mul)
    return int(float(t))


# ---------------------------------------------------------------------------
# Deprecated string-policy views (derived from the registry, never drifting)
# ---------------------------------------------------------------------------

#: tag sets per name-based policy (deprecated alias of the registry).
POLICY_TAGS = {n: p.saved for n, p in PLAN_REGISTRY.items() if not p.special}

#: jax.checkpoint policy objects per registry name (deprecated alias).
POLICIES = {n: _flat_policy(p) for n, p in PLAN_REGISTRY.items()}

"""Gating + the MoEBlaze dispatch data structures (paper §2.1, §4).

The four index structures (paper §4.1):

  expert_token_indices : (L*k,) int32 — token ids grouped by expert, within a
      group ordered by token id.  Expert ``e`` owns the slice
      ``[expert_token_offsets[e], expert_token_offsets[e+1])``.
  expert_token_offsets : (E+1,) int32 — exclusive prefix sums of counts.
  token_expert_indices : (L*k,) int32 — the chosen expert ids in token order
      (row-major flatten of the (L, k) top-k result).
  token_index_map      : (L, k) int32 — for each token, the positions of its k
      slots inside ``expert_token_indices`` (the inverse permutation).  Used by
      the combine step to *gather* its k partial outputs.

Two builders are provided:

  * :func:`build_dispatch` — the MoEBlaze **sort-free** build.  On GPU the
    paper replaces a radix sort with a 3-step atomic-free bitmap/scan pipeline
    (§4.2); the TPU-native analogue is a one-hot + cumulative-sum formulation
    that the VPU vectorizes directly (and `kernels/dispatch.py` provides the
    Pallas single-pass variant with a carried per-expert counter).
  * :func:`build_dispatch_sort` — the sort-based baseline the paper argues
    against (flatten → global stable sort by expert id → index recovery).

Both produce bit-identical structures (tested), so everything downstream is
agnostic to the builder.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Dispatch(NamedTuple):
    """The MoEBlaze routing metadata (paper Fig. 2)."""

    expert_token_indices: jax.Array  # (L*k,) int32
    expert_token_offsets: jax.Array  # (E+1,) int32
    token_expert_indices: jax.Array  # (L*k,) int32
    token_index_map: jax.Array       # (L, k) int32
    expert_lengths: jax.Array        # (E,)   int32

    @property
    def num_slots(self) -> int:
        return self.expert_token_indices.shape[0]


class GatingOut(NamedTuple):
    topk_experts: jax.Array  # (L, k) int32
    topk_weights: jax.Array  # (L, k) float — renormalized softmax scores
    router_probs: jax.Array  # (L, E) float — full softmax, for aux losses
    logits: jax.Array        # (L, E) float — for z-loss


def top_k_gating(x: jax.Array, w_gate: jax.Array, k: int,
                 *, renormalize: bool = True) -> GatingOut:
    """``TopK(softmax(W_g x))`` (paper §2.1).

    Args:
      x: (L, d) token activations.
      w_gate: (d, E) gating weights.
      k: experts per token.
    """
    logits = (x.astype(jnp.float32) @ w_gate.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topk_weights, topk_experts = jax.lax.top_k(probs, k)
    if renormalize:
        topk_weights = topk_weights / jnp.sum(topk_weights, -1, keepdims=True)
    return GatingOut(topk_experts.astype(jnp.int32), topk_weights, probs, logits)


def load_balance_loss(router_probs: jax.Array, topk_experts: jax.Array,
                      num_experts: int) -> jax.Array:
    """Switch/Mixtral-style auxiliary load-balance loss."""
    L = router_probs.shape[0]
    assign = jax.nn.one_hot(topk_experts, num_experts, dtype=jnp.float32)  # (L,k,E)
    frac_tokens = assign.sum(axis=(0, 1)) / (L * topk_experts.shape[1])
    frac_probs = router_probs.mean(axis=0)
    return num_experts * jnp.sum(frac_tokens * frac_probs)


def router_z_loss(logits: jax.Array) -> jax.Array:
    """ST-MoE z-loss: penalizes large router logits for stability."""
    return jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)


def build_dispatch(topk_experts: jax.Array, num_experts: int) -> Dispatch:
    """Sort-free dispatch-structure construction (paper §4.2, TPU rendering).

    Step 1 (dense token→expert map): one-hot encode the (L, k) assignments —
      the analogue of the paper's ``dense_token_map`` bitmap.
    Step 2 (expert lengths): column sums of the map + exclusive prefix sum —
      the analogue of the CTA-per-expert warp reductions.
    Step 3 (route indices to gates): within-expert ranks via an exclusive
      cumulative sum down the token axis (the paper's tile-level scans), added
      to the expert's global offset, yielding each slot's destination — then a
      single scatter writes ``expert_token_indices``.

    No sort is performed and no atomics are needed (TPU has none; XLA emits a
    vectorized cumsum).
    """
    L, k = topk_experts.shape
    flat = topk_experts.reshape(L * k)
    # (L*k, E) dense map.  int32 keeps the cumsum on the fast VPU path.
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)
    # Step 2: per-expert totals and exclusive offsets.
    expert_lengths = onehot.sum(axis=0)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(expert_lengths)]
    ).astype(jnp.int32)
    # Step 3: rank of each slot within its expert = exclusive scan of the map.
    ranks_all = jnp.cumsum(onehot, axis=0) - onehot          # (L*k, E)
    rank = jnp.take_along_axis(ranks_all, flat[:, None], axis=1)[:, 0]
    dest = offsets[flat] + rank                               # (L*k,)
    token_ids = (jnp.arange(L * k, dtype=jnp.int32) // k)
    expert_token_indices = (
        jnp.zeros((L * k,), jnp.int32).at[dest].set(token_ids)
    )
    return Dispatch(
        expert_token_indices=expert_token_indices,
        expert_token_offsets=offsets,
        token_expert_indices=flat.astype(jnp.int32),
        token_index_map=dest.reshape(L, k).astype(jnp.int32),
        expert_lengths=expert_lengths.astype(jnp.int32),
    )


def slice_dispatch(d: Dispatch, e_lo, e_hi, *,
                   count: int | None = None) -> Dispatch:
    """Compact a global :class:`Dispatch` to the expert range ``[e_lo, e_hi)``
    (the device-local view under expert parallelism).

    The slot space is *rotated*, not truncated: globally each of the ``L*k``
    slots has a unique destination in ``[0, L*k)``, and subtracting the
    range's first offset modulo ``L*k`` is a bijection, so

      * slots of the local experts land contiguously at ``[0, n_loc)``
        (``n_loc = offsets[e_hi] - offsets[e_lo]``) in global expert order —
        exactly the prefix a grouped GEMM with the rebased ``expert_lengths``
        consumes;
      * every *non-local* slot lands uniquely in the dead zone
        ``[n_loc, L*k)``.  Grouped-GEMM backends define rows past the
        group-size total as belonging to no group (output zero), so a combine
        gathering through the sliced ``token_index_map`` picks up exact zeros
        for non-local slots — summing the per-range outputs (one ``psum``)
        reassembles the global combine with no padding and no dense ``L×E``
        buffer.

    ``expert_token_offsets``/``expert_lengths`` are rebased to the local
    range; ``token_expert_indices`` is rebased by ``-e_lo`` (out-of-range
    values mark non-local slots).  ``e_lo``/``e_hi`` may be traced (e.g.
    ``axis_index * E_loc`` inside ``shard_map``); the local expert *count*
    must be static — pass ``count=`` when the bounds are traced.
    """
    if count is None:
        count = int(e_hi) - int(e_lo)
    if count <= 0:
        raise ValueError(f"empty expert range [{e_lo}, {e_hi})")
    e_lo = jnp.asarray(e_lo, jnp.int32)
    S = d.expert_token_indices.shape[0]
    off = jax.lax.dynamic_slice_in_dim(d.expert_token_offsets, e_lo, count + 1)
    lens = jax.lax.dynamic_slice_in_dim(d.expert_lengths, e_lo, count)
    start = off[0]
    # Rotate the slot axis so the local range starts at 0 (explicit gather —
    # works with a traced start index on every backend).
    src = (jnp.arange(S, dtype=jnp.int32) + start) % S
    return Dispatch(
        expert_token_indices=jnp.take(d.expert_token_indices, src, axis=0),
        expert_token_offsets=(off - start).astype(jnp.int32),
        token_expert_indices=(d.token_expert_indices - e_lo).astype(jnp.int32),
        token_index_map=((d.token_index_map - start) % S).astype(jnp.int32),
        expert_lengths=lens.astype(jnp.int32),
    )


def build_dispatch_sort(topk_experts: jax.Array, num_experts: int) -> Dispatch:
    """Sort-based baseline (paper §4.2's strawman): global stable sort by
    expert id, then index recovery.  Produces identical structures."""
    L, k = topk_experts.shape
    flat = topk_experts.reshape(L * k).astype(jnp.int32)
    order = jnp.argsort(flat, stable=True).astype(jnp.int32)   # (L*k,)
    token_ids = (jnp.arange(L * k, dtype=jnp.int32) // k)
    expert_token_indices = token_ids[order]
    # index recovery: dest[slot] = position of `slot` in `order`
    dest = jnp.zeros((L * k,), jnp.int32).at[order].set(
        jnp.arange(L * k, dtype=jnp.int32))
    expert_lengths = jnp.bincount(flat, length=num_experts).astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(expert_lengths)]
    ).astype(jnp.int32)
    return Dispatch(
        expert_token_indices=expert_token_indices,
        expert_token_offsets=offsets,
        token_expert_indices=flat,
        token_index_map=dest.reshape(L, k),
        expert_lengths=expert_lengths,
    )

"""Static per-phase peak-memory simulator for one train step.

PR 5's ``CheckpointPlan.estimate_saved_bytes`` accounts only for residuals
held across fwd/bwd — but real OOMs happen at *transient* peaks: the
backward recompute spike of a checkpointed layer, the a2a send/recv
capacity buffers of ``moe_parallel="ep_a2a"``, the optimizer m/v update.
This module walks the train step as a sequence of phases (fwd per
block-kind x layer, loss, bwd per layer in reverse with plan-driven
recompute including the MoE custom-VJP ``x``-mode replay GEMMs, optimizer
update) and emits a per-device peak-bytes timeline, so
:meth:`CheckpointPlan.fit` can rank candidates by simulated *peak*.

The model is calibrated against XLA ``memory_analysis()`` peaks measured
by ``repro.bench.memory`` (the ``peak_sim/*`` BENCH entries gate the
agreement at 20% for every registry plan x {single, ep, ep_a2a} on the
bench MoE config).  Two calibrated constants encode what shape arithmetic
alone cannot see:

* ``GRAD_FACTOR`` — the backward's cotangent working set mirrors the
  forward working set of the layer being differentiated (~1.0x).
* ``FULL_SAVE_FACTOR`` — under ``full`` (no rematerialization) XLA keeps
  elementwise intermediates beyond the tagged tensors; the held set is
  ~1.9x the enumerable forward working set.

Everything is shape arithmetic on the config — no tracing, no arrays, no
jax import — so a simulation costs microseconds and is bit-deterministic
across hosts (the property the CI parity gate relies on).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core import checkpoint as CK

# -- calibrated constants (see module docstring + bench/memory.py) ----------

#: cotangent working set per layer-bwd, as a fraction of the layer's
#: forward working set.
GRAD_FACTOR = 1.0

#: held-residual multiplier under ``special="full"``: XLA saves elementwise
#: intermediates (norm stats, silu inputs, residual adds) beyond the
#: enumerable tagged tensors.
FULL_SAVE_FACTOR = 1.9

#: how many logits-sized buffers are live around the loss phase: the f32
#: logits, the log-softmax statistics, and the logits cotangent.
LOSS_FACTOR = 3


@dataclass(frozen=True)
class Phase:
    """One step of the simulated timeline.  ``live_bytes`` excludes the
    timeline's ``base_bytes`` (params/grads/optimizer — constant over the
    step); the timeline's ``peak_bytes`` adds it back."""

    name: str                   # "fwd/attn_moe[0]", "loss", "bwd/...", ...
    held_bytes: int             # residuals held across this phase
    transient_bytes: int        # working set materialized during the phase
    collective_bytes: int = 0   # a2a capacity buffers live in the phase

    @property
    def live_bytes(self) -> int:
        return self.held_bytes + self.transient_bytes + self.collective_bytes


@dataclass(frozen=True)
class MemTimeline:
    """The simulated per-device timeline of one train step."""

    phases: tuple[Phase, ...]
    base_bytes: int             # params (+grads, +opt state) per device
    base: str                   # "acts" | "grad" | "train"
    mode: str             # "single" | "ep" | "ep_a2a" | "ep_a2a_hier" | "tp"
    n_model: int
    recompute_bytes: int        # total plan-driven recompute across bwd

    @property
    def peak_bytes(self) -> int:
        return self.base_bytes + max(p.live_bytes for p in self.phases)

    @property
    def peak_phase(self) -> str:
        return max(self.phases, key=lambda p: p.live_bytes).name

    def table(self, limit: int | None = None) -> str:
        """Human-readable phase table (README / dryrun records / examples).
        ``limit`` keeps the ``limit`` highest-live phases (peak first)."""
        rows = sorted(self.phases, key=lambda p: -p.live_bytes)
        if limit is not None:
            rows = rows[:limit]
        peak = self.peak_phase
        lines = [f"{'phase':18s} {'held':>12s} {'transient':>12s} "
                 f"{'collective':>12s} {'live':>12s}"]
        for p in rows:
            mark = " *" if p.name == peak else ""
            lines.append(
                f"{p.name:18s} {p.held_bytes:12,d} {p.transient_bytes:12,d} "
                f"{p.collective_bytes:12,d} {p.live_bytes:12,d}{mark}")
        lines.append(f"base (params/opt) {self.base_bytes:12,d}   "
                     f"peak {self.peak_bytes:,d} @ {peak}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# shape arithmetic
# ---------------------------------------------------------------------------


def _itemsize(dtype) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}.get(str(dtype), 4)


def _layer_kinds(cfg) -> list:
    period = max(len(cfg.block_pattern), 1)
    return [cfg.block_pattern[i % period] for i in range(cfg.num_layers)]


def param_bytes(cfg, *, n_model: int = 1) -> int:
    """Analytic per-device parameter bytes (embed + untied head + per-layer
    projections; expert weights divide by ``n_model`` under ep modes)."""
    p = _itemsize(cfg.param_dtype)
    d, V = cfg.d_model, cfg.vocab_size
    total = 2 * V * d * p + d * p          # embed + head + final norm
    for kind in _layer_kinds(cfg):
        b = 2 * d * p                                 # pre-norms
        if "attn" in kind or kind == "hymba":
            h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
            b += (2 * h + 2 * kv) * d * hd * p
        if kind.endswith("moe"):
            E = cfg.num_experts
            b += d * E * p                            # router
            b += 3 * (E // max(n_model, 1)) * d * cfg.moe_d_ff * p
        elif "attn" in kind or kind == "hymba":
            n_ffn = 3 if cfg.ffn_act == "swiglu" else 2
            b += n_ffn * d * cfg.d_ff * p
        if kind in ("mlstm", "slstm"):
            b += 4 * d * d * p                        # recurrent projections
        total += b
    return total


def _a2a_capacity(cfg, slots: int, n: int, clamp: int | None = None) -> int:
    """Per-destination slot capacity of one a2a hop over ``n`` ranks:
    uniform share of ``slots`` scaled by ``cfg.moe_a2a_capacity``, clamped
    (the traced path in ``models.moe_block`` delegates here)."""
    n = max(n, 1)
    uniform = (slots + n - 1) // n
    cap = int(uniform * float(cfg.moe_a2a_capacity))
    return max(min(cap, clamp if clamp is not None else slots), 1)


def _a2a_rows(cfg, n_tokens: int, n_model: int) -> int:
    """Total rows of the flat ep_a2a send/recv buffers on one device:
    ``n_model * C`` with C the per-destination capacity (mirrors
    ``models.moe_block`` on the L/n_model token chunk).  With
    ``cfg.moe_a2a_chunks > 1`` the capacity rounds up to a chunk multiple,
    exactly as the chunked-overlap path pads it."""
    n = max(n_model, 1)
    chunk = max(n_tokens // n, 1)
    c = _a2a_capacity(cfg, chunk * cfg.top_k, n)
    ch = max(int(getattr(cfg, "moe_a2a_chunks", 1)), 1)
    if ch > 1:
        c = -(-c // ch) * ch
    return n * c


def _a2a_hier_rows(cfg, n_tokens: int, n_node: int, n_lane: int
                   ) -> tuple[int, int]:
    """(hop-1 rows, hop-2 rows) of the two-hop ``ep_a2a_hier`` buffers:
    hop 1 groups this device's ``L/n`` chunk's slots by destination lane
    over the ``n_lane`` intra-node ranks; hop 2 regroups the received rows
    by destination node over ``n_node`` ranks."""
    n = max(n_node, 1) * max(n_lane, 1)
    chunk = max(n_tokens // n, 1)
    slots = chunk * cfg.top_k
    c1 = _a2a_capacity(cfg, slots, n_lane)
    r1 = max(n_lane, 1) * c1
    c2 = _a2a_capacity(cfg, slots, n_node, clamp=r1)
    return r1, max(n_node, 1) * c2


@dataclass(frozen=True)
class _KindSizes:
    """Forward working-set components of one layer of one block kind."""

    attn: int = 0           # q/k/v, scores, attention out, o-proj, norms
    ffn: int = 0            # dense-FFN a, b, y_swi, y
    moe_other: int = 0      # router logits, dispatch indices, x_g, y_g, y
    moe_vjp: int = 0        # grouped-GEMM interior: a, b, y_swi (slot rows)
    moe_vjp_held: int = 0   # ditto at the rows XLA actually keeps live
    moe_x: int = 0          # the MoE sublayer input (custom-VJP residual x)
    ssm: int = 0            # recurrent-scan carries + gate temps
    collective: int = 0     # a2a send/recv/return row buffers
    dots_extra: int = 0     # matmul outputs beyond the canonical tags

    @property
    def core(self) -> int:
        return (self.attn + self.ffn + self.moe_other + self.moe_vjp
                + self.ssm)


def _kind_sizes(cfg, kind: str, n_tokens: int, batch: int,
                mode: str, n_model: int, n_node: int = 1) -> _KindSizes:
    it = _itemsize(cfg.dtype)
    d = cfg.d_model
    x_b = n_tokens * d * it
    seq = max(n_tokens // max(batch, 1), 1)
    n_exp = max(n_model, 1) * max(n_node, 1)          # expert-parallel ways
    attn = ffn = moe_other = moe_vjp = moe_vjp_held = moe_x = ssm = 0
    collective = dots_extra = 0
    if "attn" in kind or kind == "hymba":
        h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        qkv = n_tokens * (h + 2 * kv) * hd * it
        scores = batch * h * seq * seq * it
        attn = qkv + scores + 2 * x_b + 2 * x_b      # av+o out, 2 norms
        dots_extra += scores
    if kind.endswith("moe"):
        E, k, ff = cfg.num_experts, cfg.top_k, cfg.moe_d_ff
        E_loc = E // n_exp if mode in ("ep", "ep_a2a", "ep_a2a_hier") else E
        if mode == "ep_a2a" and n_exp > 1:
            tm = max(n_tokens // n_exp, 1)            # this device's chunk
            rows = _a2a_rows(cfg, n_tokens, n_exp)    # capacity-padded
            rows_held = tm * k                        # rows actually routed
            ch = max(int(getattr(cfg, "moe_a2a_chunks", 1)), 1)
            if ch > 1:
                # Double-buffered chunks: the full send buffer and the full
                # return buffer stay live, but only two Cc-row exchange
                # chunks (current + prefetched next) are in flight at once.
                collective = (2 * rows + 2 * (rows // ch)) * d * it
            else:
                collective = 3 * rows * d * it        # send_x/recv_x/back
        elif mode == "ep_a2a_hier" and n_exp > 1:
            tm = max(n_tokens // n_exp, 1)
            r1, r2 = _a2a_hier_rows(cfg, n_tokens, n_node, n_model)
            rows = r2                                 # rows the GEMMs run on
            rows_held = tm * k
            # hop-1 send/recv + hop-2 send/recv + the return buffer of the
            # hop live at the peak (the two inverse hops reuse the same
            # footprint on the way back).
            collective = (2 * r1 + 3 * r2) * d * it
        else:
            tm = n_tokens
            rows = rows_held = n_tokens * k           # full slot count
        ff_loc = ff // max(n_model, 1) if mode == "tp" else ff
        moe_other = (tm * E * it                      # router logits
                     + 3 * rows * 4                   # eti/tim/dest indices
                     + 2 * rows * d * it              # x_g, y_g
                     + x_b)                           # combined output y
        moe_vjp = 3 * rows * ff_loc * it              # a, b, y_swi
        moe_vjp_held = 3 * rows_held * ff_loc * it
        moe_x = tm * d * it
        # The segment grouped-GEMM backend's per-expert full-slot dots —
        # what ``dots`` ends up saving on MoE layers (see bench data).
        dots_extra += E_loc * (2 * rows * ff_loc + rows * d) * it
    elif "attn" in kind or kind == "hymba":
        n_ffn = 3 if cfg.ffn_act == "swiglu" else 2
        ffn = n_ffn * n_tokens * cfg.d_ff * it + x_b
    if kind in ("mlstm", "slstm", "hymba"):
        ssm = 3 * CK._ssm_state_bytes(cfg, kind, n_tokens, batch) + 2 * x_b
    return _KindSizes(attn=attn, ffn=ffn, moe_other=moe_other,
                      moe_vjp=moe_vjp, moe_vjp_held=moe_vjp_held,
                      moe_x=moe_x, ssm=ssm, collective=collective,
                      dots_extra=dots_extra)


def moe_layer_sizes(cfg, n_tokens: int, *, mode: str, n_model: int = 1,
                    n_node: int = 1) -> _KindSizes:
    """Forward working-set components of ONE MoE layer under ``mode`` —
    the per-device live-bytes half of ``roofline.select_moe_parallel``'s
    ranking (the simulator stays the single source of buffer arithmetic)."""
    return _kind_sizes(cfg, "moe", n_tokens, 1, mode, n_model, n_node)


def _held_bytes(plan, kind: str, sizes: _KindSizes, tag_sizes: dict,
                wrapped: bool) -> int:
    """Residual bytes one layer of ``kind`` holds across fwd->bwd under
    ``plan``.  ``wrapped`` is False for ``full`` (no jax.checkpoint around
    the layer): the MoE custom-VJP residuals then persist; under any
    wrapped plan they are transient (rebuilt by the bwd replay)."""
    if plan.special == "full":
        held = int(FULL_SAVE_FACTOR
                   * (sizes.attn + sizes.ffn + sizes.moe_other + sizes.ssm))
        held += _vjp_resid_bytes(plan, kind, sizes)
        return held
    if plan.special == "dots":
        saved = sum(tag_sizes.get(t, 0)
                    for t in (CK.QKV, CK.ATTN_OUT, CK.FFN_A, CK.FFN_B))
        return saved + sizes.dots_extra
    saved = sum(tag_sizes.get(t, 0) for t in CK.kind_tags(kind)
                if t in plan.scoped_saved(kind))
    return saved


def _vjp_resid_bytes(plan, kind: str, sizes: _KindSizes) -> int:
    """Persistent MoE custom-VJP residual bytes under an unwrapped plan,
    by residual mode (ab_yswi / ab / x)."""
    if not kind.endswith("moe"):
        return 0
    mode = _vjp_mode(plan)
    if mode == "ab_yswi":
        return sizes.moe_vjp_held + sizes.moe_x
    if mode == "ab":
        return sizes.moe_vjp_held * 2 // 3 + sizes.moe_x
    return sizes.moe_x                                # "x": replay in bwd


def _vjp_mode(plan, save_yswi: bool = True) -> str:
    """Plan-level mirror of :func:`checkpoint.moe_residual_mode` (which
    reads the plan off a config): the MoE custom-VJP residual set."""
    oa = plan.override_for(CK.FFN_A, CK.MOE_SCOPE_KINDS)
    oy = plan.override_for(CK.FFN_YSWI, CK.MOE_SCOPE_KINDS)
    if oa == CK.RECOMPUTE:
        return "x"
    save_y = save_yswi if oy is None else oy == CK.SAVE
    return "ab_yswi" if save_y else "ab"


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------


def simulate(cfg, n_tokens: int, *, batch: int = 1, plan=None,
             mode: str | None = None, n_model: int = 1, n_node: int = 1,
             base: str = "grad") -> MemTimeline:
    """Simulate one train step's per-device memory timeline.

    ``n_tokens`` / ``batch`` are the *per-device* token and sequence counts
    (the caller divides the global batch by its data-parallel shards and
    microbatches, exactly as :func:`train.loop.make_train_step` does for the
    residual estimate).  ``mode`` / ``n_model`` / ``n_node`` pick the MoE
    distribution (``single`` | ``ep`` | ``ep_a2a`` | ``ep_a2a_hier`` |
    ``tp``; ``n_node`` is the factored cross-node tier of a node mesh, 1
    when absent); ``base`` selects what constant state sits under the
    activation timeline:

    * ``"acts"``  — activations only (plan comparisons in isolation);
    * ``"grad"``  — params + grads + batch: matches what
      ``bench.memory.activation_memory_report`` measures off XLA's
      ``memory_analysis()`` (the parity-gated quantity);
    * ``"train"`` — adds AdamW m/v and an optimizer-update phase: the
      budget-relevant per-device train-step peak.
    """
    if base not in ("acts", "grad", "train"):
        raise ValueError(f"unknown base {base!r}; use acts|grad|train")
    if isinstance(plan, CK.CheckpointPlan):
        plan = plan
    else:
        plan = CK.resolve_plan(plan, config=cfg.remat_policy).plan
    if mode is None:
        mode = "single" if n_model * n_node <= 1 else (
            cfg.moe_parallel
            if cfg.moe_parallel in ("ep", "ep_a2a", "ep_a2a_hier", "tp")
            else "ep")
    if mode not in ("single", "ep", "ep_a2a", "ep_a2a_hier", "tp"):
        raise ValueError(f"unknown moe-parallel mode {mode!r}")

    it = _itemsize(cfg.dtype)
    x_b = n_tokens * cfg.d_model * it
    logits_b = n_tokens * cfg.vocab_size * 4          # f32 log_softmax
    kinds = _layer_kinds(cfg)
    tag_by_kind = {k: s for k, s in
                   CK.tag_bytes_by_kind(cfg, n_tokens, batch=batch)}
    sizes_of = {k: _kind_sizes(cfg, k, n_tokens, batch, mode, n_model,
                               n_node)
                for k in set(kinds)}
    wrapped = plan.special != "full"
    vjp_mode = _vjp_mode(plan, cfg.save_yswi)

    held, spikes, recs = [], [], []
    for k in kinds:
        s = sizes_of[k]
        h = _held_bytes(plan, k, s, tag_by_kind.get(k, {}), wrapped)
        if wrapped:
            rec = max(s.core - h, 0)
        else:
            rec = 0
        replay = 0
        if k.endswith("moe") and not wrapped:
            if vjp_mode == "x":                       # rebuild A, B, Y_swi
                replay = s.moe_vjp
            elif vjp_mode == "ab":                    # rebuild Y_swi only
                replay = s.moe_vjp // 3
        held.append(h)
        spikes.append(rec + replay + int(GRAD_FACTOR * s.core))
        recs.append(rec + replay)

    phases = []
    for i, k in enumerate(kinds):
        s = sizes_of[k]
        phases.append(Phase(
            name=f"fwd/{k}[{i}]",
            held_bytes=(i + 2) * x_b + sum(held[:i]),
            transient_bytes=s.core,
            collective_bytes=s.collective))
    all_held = (len(kinds) + 2) * x_b + sum(held)
    phases.append(Phase(name="loss", held_bytes=all_held,
                        transient_bytes=LOSS_FACTOR * logits_b))
    for i in reversed(range(len(kinds))):
        k = kinds[i]
        s = sizes_of[k]
        phases.append(Phase(
            name=f"bwd/{k}[{i}]",
            held_bytes=(i + 2) * x_b + sum(held[:i + 1]),
            transient_bytes=spikes[i],
            collective_bytes=s.collective))

    # Expert weights per device: ep modes shard the expert dim over the
    # combined node x model axes; tp shards the per-expert hidden dim over
    # 'model' — either way the bank divides by that many ways.
    ep_ways = (n_model * n_node
               if mode in ("ep", "ep_a2a", "ep_a2a_hier") else n_model)
    pb = param_bytes(cfg, n_model=max(ep_ways, 1))
    n_params = pb // _itemsize(cfg.param_dtype)
    grads_b = n_params * 4
    tok_b = 2 * n_tokens * 4
    base_b = 0
    if base in ("grad", "train"):
        base_b = pb + grads_b + tok_b
    if base == "train":
        base_b += 2 * n_params * 4                    # AdamW m, v
        phases.append(Phase(name="optimizer", held_bytes=0,
                            transient_bytes=n_params * 4))
    return MemTimeline(phases=tuple(phases), base_bytes=base_b, base=base,
                       mode=mode, n_model=n_model,
                       recompute_bytes=sum(recs))


# ---------------------------------------------------------------------------
# serve mode: paged KV cache + inference activations
# ---------------------------------------------------------------------------


def _kv_kinds(cfg) -> list:
    """Layer kinds that carry a KV cache."""
    return [k for k in _layer_kinds(cfg) if "attn" in k or k == "hymba"]


def kv_bytes_per_token(cfg, *, quantized: bool = False,
                       dtype: str | None = None) -> int:
    """KV-cache bytes ONE cached token costs across all layers.  ``dtype``
    overrides the storage dtype for the unquantized case (e.g. compare a
    bf16 dense baseline against an int8 paged pool on an f32 config);
    ``quantized`` prices the int8 + f16-scale layout of
    ``serve/paged_cache`` / ``serve/kv_quant``."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if quantized:
        per_layer = 2 * kv * hd + 2 * kv * 2          # int8 k/v + f16 scales
    else:
        per_layer = 2 * kv * hd * _itemsize(dtype or cfg.dtype)
    return per_layer * len(_kv_kinds(cfg))


def kv_page_bytes(cfg, num_pages: int, page_size: int, *,
                  quantized: bool = False) -> int:
    """Total bytes of the block-paged KV pools (``T.init_paged_cache``):
    every page of every layer, allocated up front — the serve-mode
    equivalent of the training residual base."""
    return num_pages * page_size * kv_bytes_per_token(cfg,
                                                      quantized=quantized)


def dense_slot_bytes(cfg, batch_slots: int, capacity: int, *,
                     dtype: str | None = None) -> int:
    """The seed engine's dense per-slot cache (``T.init_cache``): every slot
    pins ``capacity`` positions whether or not a request ever reaches them —
    the baseline the paged pool is gated against."""
    return batch_slots * capacity * kv_bytes_per_token(cfg, dtype=dtype)


def simulate_serve(cfg, *, batch_slots: int, num_pages: int, page_size: int,
                   prefill_tokens: int, prefill_batch: int = 1,
                   quantized: bool = False, shared_pages: int = 0,
                   n_model: int = 1) -> MemTimeline:
    """Simulate the serving engine's per-device memory timeline.

    Two phases — ``prefill`` (whole-prompt forward at ``prefill_tokens``
    total tokens over ``prefill_batch`` sequences) and ``decode`` (one
    single-token step over the full slot array).  The paged KV pool is the
    *held* set of both phases (allocated once, resident for the engine's
    life); transients are the largest single layer's forward working set —
    inference holds no residuals, so layers reuse their buffers — plus, for
    decode, the per-request page-gather views ``(B, pages_per_seq *
    page_size, Hkv, Dh)`` that ``paged_attention`` materializes.  Same
    jax-free shape arithmetic as :func:`simulate`.

    ``shared_pages`` models prefix-cache hits (``prefix_cache=True``
    engines): each sequence in the prefill batch maps that many full prompt
    pages read-only from the cache, so only the unshared suffix is
    forwarded — the prefill transient shrinks by ``shared_pages *
    page_size`` tokens per sequence.  The pool's held bytes do NOT shrink
    (the pool is sized at construction); sharing shows up as fewer pages
    *consumed* per request, i.e. headroom, which the engine reports as
    ``stats['shared_pages_mapped']``.
    """
    it = _itemsize(cfg.dtype)
    prefill_tokens = max(
        prefill_tokens - shared_pages * page_size * prefill_batch,
        prefill_batch)
    pool_b = kv_page_bytes(cfg, num_pages, page_size, quantized=quantized)
    mode = "single" if n_model <= 1 else "ep"
    kinds = set(_layer_kinds(cfg))

    def layer_transient(n_tokens: int, batch: int) -> int:
        x_b = n_tokens * cfg.d_model * it
        return max(_kind_sizes(cfg, k, n_tokens, batch, mode, n_model).core
                   + 2 * x_b for k in kinds)

    logits_b = batch_slots * cfg.vocab_size * 4
    # page-table width: the engine's default budget is full occupancy
    # (num_pages = 1 + slots * pages_per_seq), so invert that here
    pages_per_seq = -(-(num_pages - 1) // max(batch_slots, 1))
    gather_tokens = batch_slots * pages_per_seq * page_size
    gather_b = 2 * gather_tokens * cfg.num_kv_heads * cfg.resolved_head_dim \
        * (1 if quantized else it)
    if quantized:
        gather_b += 2 * gather_tokens * cfg.num_kv_heads * 2   # f16 scales
    phases = (
        Phase(name="prefill", held_bytes=pool_b,
              transient_bytes=layer_transient(prefill_tokens, prefill_batch)
              + prefill_batch * cfg.vocab_size * 4),
        Phase(name="decode", held_bytes=pool_b,
              transient_bytes=layer_transient(batch_slots, batch_slots)
              + gather_b + logits_b),
    )
    return MemTimeline(phases=phases,
                       base_bytes=param_bytes(cfg, n_model=n_model),
                       base="acts", mode=mode, n_model=n_model,
                       recompute_bytes=0)


def simulate_peak(cfg, n_tokens: int, *, batch: int = 1, plan=None,
                  mode: str | None = None, n_model: int = 1,
                  n_node: int = 1, base: str = "grad") -> int:
    """Peak bytes of :func:`simulate` (the fit/bench/step-hook scalar)."""
    return simulate(cfg, n_tokens, batch=batch, plan=plan, mode=mode,
                    n_model=n_model, n_node=n_node, base=base).peak_bytes

"""Baseline MoE implementations MoEBlaze is compared against (paper §6.2).

* :func:`moe_ffn_megablocks` — a MegaBlocks-style **materialized** dispatch:
  tokens are permuted into a compacted (L·k, d) routed buffer, grouped GEMMs
  run on the buffer, and outputs are scatter-added back.  Differentiated with
  plain autodiff, so XLA saves the routed buffer and every elementwise
  intermediate for the backward — exactly the activation footprint the paper
  attributes to conventional systems (§2.1, §2.2).

* :func:`moe_ffn_dense` — a GShard-style dense-dispatch einsum (every expert
  processes every token, masked).  O(L·E) compute; used only as a tiny-scale
  oracle in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gmm_backend import ResolvedBackend, gmm, resolve
from repro.core.moe_layer import _ACTS, _silu
from repro.core.routing import Dispatch


def moe_ffn_megablocks(x: jax.Array, gates: jax.Array, dispatch: Dispatch,
                       w1: jax.Array, w3: jax.Array,
                       w2: jax.Array | None = None,
                       *, activation: str = "swiglu",
                       backend: str | ResolvedBackend | None = None
                       ) -> jax.Array:
    """Materialized-dispatch baseline (plain autodiff, no smart checkpoint)."""
    # One trace-time resolution shared by all three grouped GEMMs (and their
    # autodiff transposes) — the precedence chain is never consulted mid-op.
    backend = resolve(backend)
    L, k = dispatch.token_index_map.shape
    # Materialize the routed-token buffer — the (L*k, d) allocation the paper
    # eliminates (§2.1 example: ~94 GB at DeepSeek scale).
    xg = jnp.take(x, dispatch.expert_token_indices, axis=0)
    a = gmm(xg, w1, dispatch.expert_lengths, backend=backend)
    if activation == "swiglu":
        assert w2 is not None
        b = gmm(xg, w2, dispatch.expert_lengths, backend=backend)
        y_act = _silu(a) * b
    else:
        y_act = _ACTS[activation][0](a)
    p_out = gmm(y_act, w3, dispatch.expert_lengths, backend=backend)
    g_slot = jnp.zeros((L * k,), gates.dtype).at[
        dispatch.token_index_map.reshape(-1)].set(gates.reshape(-1))
    # Scatter-add combine on the materialized buffer.
    return jnp.zeros_like(x).at[dispatch.expert_token_indices].add(
        (p_out * g_slot[:, None].astype(p_out.dtype)).astype(x.dtype))


def moe_ffn_dense(x: jax.Array, router_probs: jax.Array,
                  topk_experts: jax.Array, topk_weights: jax.Array,
                  w1: jax.Array, w3: jax.Array,
                  w2: jax.Array | None = None,
                  *, activation: str = "swiglu") -> jax.Array:
    """GShard-style dense dispatch: O(L·E·d·h) masked compute (test oracle)."""
    E = w1.shape[0]
    # (L, E) combine weights: topk gate weight where chosen, else 0.
    cw = jnp.zeros((x.shape[0], E), topk_weights.dtype)
    cw = cw.at[jnp.arange(x.shape[0])[:, None], topk_experts].set(topk_weights)
    a = jnp.einsum("ld,edh->leh", x, w1)
    if activation == "swiglu":
        assert w2 is not None
        b = jnp.einsum("ld,edh->leh", x, w2)
        y_act = _silu(a) * b
    else:
        y_act = _ACTS[activation][0](a)
    p = jnp.einsum("leh,ehd->led", y_act, w3)
    return jnp.einsum("le,led->ld", cw.astype(p.dtype), p).astype(x.dtype)

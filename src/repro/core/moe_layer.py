"""The MoEBlaze expert layer (paper §3, §5, Algorithm 1).

Forward (paper §3.1): tokens are *never* permuted into per-expert buffers.
The expert GEMMs consume rows gathered on the fly through
``dispatch.expert_token_indices``; the SwiGLU epilogue is applied to the
grouped GEMM outputs; the combine step *gathers* each token's k partial
outputs through ``dispatch.token_index_map`` and contracts them with the gate
weights (the TPU-idiomatic rendering of the paper's on-the-fly reduction —
see DESIGN.md §2).

Backward (paper §3.2 + Algorithm 1): a custom VJP that
  1. expands the (L, d) output gradient to the (L·k, d) slot gradients via the
     same index metadata (no materialized forward buffer is needed for this),
  2. **recomputes SiLU(A)** instead of saving it (paper's smart checkpoint),
  3. recomputes the input gather ``x[expert_token_indices]`` instead of saving
     the (L·k, d) routed buffer,
  4. accumulates token gradients with a scatter-add over the index list.

The residual set is a per-plan decision (``repro.core.checkpoint``
``moe``-scoped tags), expressed as one of three modes:

  * ``"ab_yswi"`` — save ``A``, ``B`` (the two first-layer GEMM outputs)
    and, faithful to Algorithm 1 line 11, ``Y_swi``;
  * ``"ab"``      — recompute ``Y_swi = SiLU(A)·B`` in the backward as well,
    trading one elementwise multiply for an (L·k, h) buffer (the legacy
    ``save_yswi=False``);
  * ``"x"``       — save neither: the backward re-runs the two first-layer
    grouped GEMMs from the (recomputed) input gather, trading two grouped
    GEMMs for *both* (L·k, h) buffers — the deepest-recompute point a
    ``moe:recompute=ffn_a,ffn_b`` plan can ask for.

The grouped GEMMs go through the pluggable backend registry in
``repro.core.gmm_backend`` (``ragged`` = ``jax.lax.ragged_dot[_general]``
where available, ``segment`` = portable pure-jnp fallback, ``pallas`` = the
``repro.kernels`` work-item kernels); select per call via ``backend=`` or
globally via ``REPRO_GMM_BACKEND``.  The ``pallas_fused`` backend short-
circuits the whole SwiGLU layer into the fused dispatch→GEMM→combine kernel
pair (``repro.kernels.ops.moe_ffn_blaze_fused``) — no ``(L·k, ·)``
intermediate exists in HBM in either direction.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.checkpoint import MOE_RESIDUAL_MODES
from repro.core.gmm_backend import ResolvedBackend, gmm, gmm_dw, resolve
from repro.core.routing import Dispatch

__all__ = ["moe_ffn_blaze", "gmm", "gmm_dw"]


def _silu(a):
    return a * jax.nn.sigmoid(a)


def _dsilu(a):
    s = jax.nn.sigmoid(a)
    return s * (1.0 + a * (1.0 - s))


_ACTS = {
    "silu": (_silu, _dsilu),
    "relu": (jax.nn.relu, lambda a: (a > 0).astype(a.dtype)),
    "gelu": (jax.nn.gelu,
             lambda a: jax.vmap(jax.grad(lambda t: jax.nn.gelu(t)))(
                 a.reshape(-1)).reshape(a.shape)),
}


def _gate_per_slot(gates: jax.Array, token_index_map: jax.Array,
                   num_slots: int) -> jax.Array:
    """Scatter the (L, k) gate weights into expert-order slots (L*k,)."""
    return jnp.zeros((num_slots,), gates.dtype).at[
        token_index_map.reshape(-1)].set(gates.reshape(-1))


# ---------------------------------------------------------------------------
# MoEBlaze SwiGLU layer — custom VJP (Algorithm 1)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _moe_swiglu(residuals: str, backend: str, x, w1, w2, w3, gates,
                eti, off, tim, lens):
    y, _ = _moe_swiglu_fwd(residuals, backend, x, w1, w2, w3, gates,
                           eti, off, tim, lens)
    return y


def _moe_swiglu_fwd(residuals, backend, x, w1, w2, w3, gates,
                    eti, off, tim, lens):
    del off
    L = x.shape[0]
    k = tim.shape[1]
    # On-the-fly gather from the *unpermuted* activations (transient).
    xg = jnp.take(x, eti, axis=0)                     # (L*k, d)
    a = gmm(xg, w1, lens, backend=backend)             # (L*k, h)
    b = gmm(xg, w2, lens, backend=backend)             # (L*k, h)
    y_swi = _silu(a) * b                               # (L*k, h)
    g_slot = _gate_per_slot(gates, tim, L * k)
    p_out = gmm(y_swi, w3, lens, backend=backend)      # (L*k, d) partials
    # Combine: gather each token's k partials and contract with its gates.
    parts = jnp.take(p_out, tim.reshape(-1), axis=0).reshape(L, k, -1)
    y = jnp.einsum("lk,lkd->ld", gates.astype(parts.dtype), parts)
    save_ab = residuals != "x"
    res = (x, w1, w2, w3, gates, eti, tim, lens, g_slot,
           a if save_ab else None, b if save_ab else None,
           y_swi if residuals == "ab_yswi" else None)
    return y, res


def _moe_swiglu_bwd(residuals, backend, res, dy):
    del residuals                   # the residual tuple itself encodes it
    (x, w1, w2, w3, gates, eti, tim, lens, g_slot, a, b, y_swi) = res
    if a is None:
        # Deepest recompute ("x"): re-run the two first-layer grouped GEMMs
        # from the recomputed input gather (Algorithm 1 with lines 9-10
        # replayed in backward).
        xg0 = jnp.take(x, eti, axis=0)
        a = gmm(xg0, w1, lens, backend=backend)
        b = gmm(xg0, w2, lens, backend=backend)
    if y_swi is None:
        y_swi = _silu(a) * b                           # beyond-paper recompute
    # 1. Expert-summation backward: expand (L, d) grads to the slots via the
    #    index metadata (paper §3.2 step 1) — gather, no materialized buffer.
    dyg = jnp.take(dy, eti, axis=0)                    # (L*k, d), unscaled
    # 2. Final-projection grads (Algorithm 1 lines 18-20).
    dw3 = gmm_dw(y_swi * g_slot[:, None].astype(y_swi.dtype), dyg, lens,
                 backend=backend)
    dyu = gmm(dyg, jnp.swapaxes(w3, 1, 2), lens, backend=backend)
    dgates_slot = jnp.sum(y_swi * dyu, axis=-1)        # (L*k,)
    dgates = jnp.take(dgates_slot, tim.reshape(-1)).reshape(gates.shape)
    dgates = dgates.astype(gates.dtype)
    dy_swi = dyu * g_slot[:, None].astype(dyu.dtype)
    # 3. SwiGLU backward with SiLU *recomputed* (Algorithm 1 lines 23-28).
    da = dy_swi * b * _dsilu(a)
    db = dy_swi * _silu(a)
    # 4. First-layer grads; the routed-token gather is recomputed, not saved.
    xg = jnp.take(x, eti, axis=0)
    dw1 = gmm_dw(xg, da, lens, backend=backend)
    dw2 = gmm_dw(xg, db, lens, backend=backend)
    dxg = gmm(da, jnp.swapaxes(w1, 1, 2), lens, backend=backend) + \
        gmm(db, jnp.swapaxes(w2, 1, 2), lens, backend=backend)
    # 5. Token-gradient accumulation (paper §3.2 step 3).
    dx = jnp.zeros_like(x).at[eti].add(dxg.astype(x.dtype))
    return dx, dw1, dw2, dw3, dgates, None, None, None, None


_moe_swiglu.defvjp(_moe_swiglu_fwd, _moe_swiglu_bwd)


# ---------------------------------------------------------------------------
# MoEBlaze plain-MLP layer (SiLU / ReLU / GELU) — paper §6.3 benchmarks
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _moe_mlp(act: str, backend: str, residuals: str,
             x, w1, w3, gates, eti, off, tim, lens):
    y, _ = _moe_mlp_fwd(act, backend, residuals, x, w1, w3, gates,
                        eti, off, tim, lens)
    return y


def _moe_mlp_fwd(act, backend, residuals, x, w1, w3, gates,
                 eti, off, tim, lens):
    del off
    f, _ = _ACTS[act]
    L, k = tim.shape[0], tim.shape[1]
    xg = jnp.take(x, eti, axis=0)
    a = gmm(xg, w1, lens, backend=backend)
    g_slot = _gate_per_slot(gates, tim, L * k)
    p_out = gmm(f(a), w3, lens, backend=backend)
    parts = jnp.take(p_out, tim.reshape(-1), axis=0).reshape(L, k, -1)
    y = jnp.einsum("lk,lkd->ld", gates.astype(parts.dtype), parts)
    # Smart checkpoint: save only the GEMM output `a` (or, under a
    # moe:recompute=ffn_a plan, not even that); act(a) is always recomputed.
    return y, (x, w1, w3, gates, eti, tim, lens, g_slot,
               a if residuals != "x" else None)


def _moe_mlp_bwd(act, backend, residuals, res, dy):
    del residuals
    f, df = _ACTS[act]
    (x, w1, w3, gates, eti, tim, lens, g_slot, a) = res
    if a is None:                   # "x": replay the first-layer grouped GEMM
        a = gmm(jnp.take(x, eti, axis=0), w1, lens, backend=backend)
    fa = f(a)                                          # recompute (paper §5.2)
    dyg = jnp.take(dy, eti, axis=0)
    dw3 = gmm_dw(fa * g_slot[:, None].astype(fa.dtype), dyg, lens,
                 backend=backend)
    dyu = gmm(dyg, jnp.swapaxes(w3, 1, 2), lens, backend=backend)
    dgates_slot = jnp.sum(fa * dyu, axis=-1)
    dgates = jnp.take(dgates_slot, tim.reshape(-1)).reshape(gates.shape)
    dgates = dgates.astype(gates.dtype)
    da = dyu * g_slot[:, None].astype(dyu.dtype) * df(a)
    xg = jnp.take(x, eti, axis=0)
    dw1 = gmm_dw(xg, da, lens, backend=backend)
    dxg = gmm(da, jnp.swapaxes(w1, 1, 2), lens, backend=backend)
    dx = jnp.zeros_like(x).at[eti].add(dxg.astype(x.dtype))
    return dx, dw1, dw3, dgates, None, None, None, None


_moe_mlp.defvjp(_moe_mlp_fwd, _moe_mlp_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


#: custom-VJP residual modes (see module docstring) — the single source of
#: truth lives next to the plan logic in ``repro.core.checkpoint``.
RESIDUAL_MODES = MOE_RESIDUAL_MODES


def moe_ffn_blaze(x: jax.Array, gates: jax.Array, dispatch: Dispatch,
                  w1: jax.Array, w3: jax.Array, w2: jax.Array | None = None,
                  *, activation: str = "swiglu",
                  save_yswi: bool = True,
                  residuals: str | None = None,
                  backend: str | ResolvedBackend | None = None) -> jax.Array:
    """MoEBlaze expert FFN.

    Args:
      x: (L, d) unpermuted token activations.
      gates: (L, k) gate weights for the chosen experts.
      dispatch: index metadata from :func:`repro.core.routing.build_dispatch`.
      w1: (E, d, h) first projection (the SiLU branch for SwiGLU).
      w2: (E, d, h) gate-branch projection (SwiGLU only).
      w3: (E, h, d) down projection.
      activation: "swiglu" | "silu" | "relu" | "gelu".
      save_yswi: deprecated bool alias — paper-faithful (True) saves Y_swi;
        ignored when ``residuals`` is given.
      residuals: custom-VJP residual mode, "ab_yswi" | "ab" | "x" — usually
        derived from the checkpoint plan via
        ``repro.core.checkpoint.moe_residual_mode(cfg)``.  None falls back
        to the ``save_yswi`` alias.
      backend: grouped-GEMM backend — a name ("ragged" | "segment" |
        "pallas"), an upstream ``ResolvedBackend``, or None/"auto" to walk
        the full precedence chain (``use_backend`` context, then
        ``REPRO_GMM_BACKEND``, then auto).
    """
    if residuals is None:
        residuals = "ab_yswi" if save_yswi else "ab"
    if residuals not in RESIDUAL_MODES:
        raise ValueError(f"unknown residual mode {residuals!r}; "
                         f"known: {RESIDUAL_MODES}")
    # Resolve to a concrete name here so the custom-VJP static arg is a
    # stable hashable and the precedence chain is walked at trace time.
    backend = resolve(backend).name
    d = dispatch
    if activation == "swiglu":
        assert w2 is not None
        from repro.core.gmm_backend import get_backend
        if getattr(get_backend(backend), "fused_moe", False):
            # Fused dispatch→GEMM→combine kernel pair: the backward replays
            # the gather and recomputes A/B/SiLU in-kernel, so its residual
            # set (x + weights + gates) is strictly below even the "x" mode —
            # every requested mode is satisfied a fortiori.
            from repro.kernels.ops import moe_ffn_blaze_fused
            return moe_ffn_blaze_fused(x, gates, d, w1, w3, w2)
        return _moe_swiglu(residuals, backend, x, w1, w2, w3, gates,
                           d.expert_token_indices, d.expert_token_offsets,
                           d.token_index_map, d.expert_lengths)
    assert w2 is None or activation == "swiglu"
    return _moe_mlp(activation, backend, residuals, x, w1, w3, gates,
                    d.expert_token_indices, d.expert_token_offsets,
                    d.token_index_map, d.expert_lengths)

"""Pytree checkpointing to sharded ``.npz`` files (no orbax in this
environment).  Keys are flattened tree paths; restore validates structure and
shapes against a template tree."""

from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def save_checkpoint(path: str, step: int, params, opt_state=None):
    os.makedirs(path, exist_ok=True)
    blobs = {"params": params}
    if opt_state is not None:
        blobs["opt"] = opt_state
    manifest = {"step": int(step), "files": []}
    for name, tree in blobs.items():
        flat, _ = _flatten(tree)
        fn = os.path.join(path, f"{name}.npz")
        np.savez(fn, **{k: np.asarray(v) for k, v in flat.items()})
        manifest["files"].append(f"{name}.npz")
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore_checkpoint(path: str, params_template, opt_template=None):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def restore_tree(name, template):
        data = np.load(os.path.join(path, f"{name}.npz"))
        flat, treedef = _flatten(template)
        leaves = []
        for key, tmpl in flat.items():
            arr = data[key]
            if arr.shape != tuple(tmpl.shape):
                raise ValueError(
                    f"checkpoint shape mismatch at {key}: "
                    f"{arr.shape} vs {tuple(tmpl.shape)}")
            leaves.append(arr.astype(tmpl.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)

    params = restore_tree("params", params_template)
    out = [manifest["step"], params]
    if opt_template is not None:
        out.append(restore_tree("opt", opt_template))
    return tuple(out)

"""AdamW + cosine schedule + global-norm clipping, in pure JAX.

Optimizer state is a pytree congruent with the params tree, so any param
sharding (FSDP over ``data`` x ``model``) extends to the moments for free
(ZeRO-style partitioning under pjit).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = peak_lr * (min_ratio + (1 - min_ratio) *
                     0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps) + \
            weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    new_m, new_v, new_p = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(p2)
    unflat = treedef.unflatten
    return unflat(new_p), AdamWState(step=step, mu=unflat(new_m),
                                     nu=unflat(new_v))

"""Training loop: jitted train step (loss -> grads -> clip -> AdamW),
metrics, periodic checkpointing.  Works single-device (examples, smoke) and
under a mesh (launch/train.py passes shardings)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import make_batch_iterator
from repro.models import transformer as T
from repro.train import checkpointing
from repro.train.optimizer import (AdamWState, adamw_update,
                                   clip_by_global_norm, cosine_schedule,
                                   init_adamw)


def make_train_step(cfg, tcfg, *, mesh=None):
    """Returns ``step_fn(params, opt_state, batch) -> (params, opt, metrics)``.

    With ``tcfg.num_microbatches > 1`` the global batch is split along its
    leading axis and gradients are accumulated in f32 across a ``lax.scan``
    (gradient accumulation — bounds activation memory to one microbatch)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: T.train_loss(p, batch, cfg, mesh=mesh),
            has_aux=True)(params)

    def accumulate(params, batch):
        M = tcfg.num_microbatches
        if M <= 1:
            return grads_of(params, batch)
        mb = jax.tree.map(
            lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch)

        def body(acc, one):
            (loss, metrics), g = grads_of(params, one)
            acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / M, acc, g)
            return acc, (loss, metrics)

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, (losses, mets) = jax.lax.scan(body, zero, mb)
        return (losses.mean(),
                jax.tree.map(lambda m: m.mean(), mets)), grads

    def step_fn(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = accumulate(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = cosine_schedule(opt_state.step, peak_lr=tcfg.learning_rate,
                             warmup=tcfg.warmup_steps, total=tcfg.total_steps)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            eps=tcfg.eps, weight_decay=tcfg.weight_decay)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return step_fn


def compiled_step_memory(cfg, tcfg, *, mesh=None) -> dict:
    """Memory/cost hook: abstractly lower + compile one train step and return
    its XLA memory analysis (no arrays allocated, no step executed).  This is
    the per-step memory axis the bench harness (``repro.bench.memory``)
    regresses against."""
    key = jax.random.PRNGKey(tcfg.seed)
    params = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
    opt_state = jax.eval_shape(init_adamw, params)
    sds = jax.ShapeDtypeStruct
    tok = sds((tcfg.batch_size, tcfg.seq_len), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    step_fn = make_train_step(cfg, tcfg, mesh=mesh)
    compiled = jax.jit(step_fn).lower(params, opt_state, batch).compile()
    mem = compiled.memory_analysis()
    return {
        "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "out_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        "compiled": compiled,
    }


def train(cfg, tcfg, *, mesh=None, params=None, log=print,
          batch_iterator=None, step_hook=None):
    """End-to-end training driver.  Returns (params, opt_state, history).

    ``step_hook(step, metrics)`` — if given — fires after every step with the
    raw (device) metrics plus ``step_s``, the step's host wall time; the same
    ``step_s`` lands in ``history`` so callers can track per-step timing
    without wrapping the loop."""
    key = jax.random.PRNGKey(tcfg.seed)
    if params is None:
        params = T.init_params(key, cfg)
    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh=mesh), donate_argnums=(0, 1))
    if batch_iterator is None:
        batch_iterator = make_batch_iterator(
            cfg.vocab_size, tcfg.seq_len, tcfg.batch_size, tcfg.seed)

    history = []
    t0 = time.perf_counter()
    for step in range(tcfg.total_steps):
        batch = {k: jnp.asarray(v) for k, v in next(batch_iterator).items()}
        ts = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step_hook is not None:
            jax.block_until_ready(metrics)
            metrics = dict(metrics, step_s=time.perf_counter() - ts)
            step_hook(step, metrics)
        if step % tcfg.log_every == 0 or step == tcfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m.setdefault("step_s", time.perf_counter() - ts)
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            log(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                f"({m['wall_s']:.1f}s)")
        if tcfg.checkpoint_every and step and step % tcfg.checkpoint_every == 0:
            checkpointing.save_checkpoint(
                f"{tcfg.checkpoint_dir}/step_{step}", step, params, opt_state)
    return params, opt_state, history

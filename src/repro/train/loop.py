"""Training loop: jitted train step (loss -> grads -> clip -> AdamW),
metrics, periodic checkpointing.  Works single-device (examples, smoke) and
under a mesh (launch/train.py passes shardings).

Grouped-GEMM backend selection is context-scoped: ``make_train_step``
resolves once at construction (``tcfg.gmm_backend`` over ``cfg.gmm_backend``
at the config slot of ``repro.core.gmm_backend.resolve``) and bakes the
concrete name into the step — mutating ``REPRO_GMM_BACKEND`` afterwards
cannot retarget an already-made step.  ``train`` re-resolves **per step**, so
an ambient ``use_backend`` scope entered mid-run (e.g. from a ``step_hook``)
flips the very next step; steps are jitted per backend name and cached."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import checkpoint as CK
from repro.core import gmm_backend as GB
from repro.core import memsim
from repro.data.pipeline import make_batch_iterator
from repro.models import transformer as T
from repro.train import checkpointing
from repro.train.optimizer import (AdamWState, adamw_update,
                                   clip_by_global_norm, cosine_schedule,
                                   init_adamw)


def _config_backend(cfg, tcfg) -> str:
    """The config-precedence slot for the train path: the train config's
    choice wins over the model config's (more specific beats more general)."""
    if tcfg.gmm_backend not in (None, "", "auto"):
        return tcfg.gmm_backend
    return cfg.gmm_backend


def _dp_shards(mesh) -> int:
    """Data-parallel shard count of a mesh (activations are batch-sharded
    over these axes, so per-device residuals divide by it)."""
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return max(n, 1)


def make_train_step(cfg, tcfg, *, mesh=None, backend=None,
                    remat_policy=None, hbm_budget=None):
    """Returns ``step_fn(params, opt_state, batch) -> (params, opt, metrics)``.

    The grouped-GEMM backend is resolved HERE, once: ``backend`` (call-site)
    > active ``use_backend`` scope > ``tcfg.gmm_backend`` > ``cfg.gmm_backend``
    > env > auto.  The resolution is exposed as ``step_fn.resolved_backend``
    (a ``ResolvedBackend``) and baked into the traced config, so the step is
    immune to later environment mutation.

    The activation-checkpoint plan follows the same discipline:
    ``remat_policy`` (call-site name/spec/plan) > ``cfg.remat_policy`` >
    default, exposed as ``step_fn.resolved_plan`` (a ``ResolvedPlan``) and
    baked into the traced config as the canonical spec.  ``hbm_budget``
    (bytes, *per device*) engages :meth:`CheckpointPlan.fit` instead: the
    cheapest-recompute registry plan whose estimated residuals fit the
    budget is selected (an explicit ``remat_policy`` becomes the preferred
    candidate).  The estimate is taken at the residual set actually live on
    one device: the global batch divided by the mesh's data-parallel shards
    and by ``tcfg.num_microbatches`` (gradient accumulation bounds the live
    set to one microbatch).

    With ``tcfg.num_microbatches > 1`` the global batch is split along its
    leading axis and gradients are accumulated in f32 across a ``lax.scan``
    (gradient accumulation — bounds activation memory to one microbatch)."""
    resolved = GB.resolve(backend, config=_config_backend(cfg, tcfg))
    n_model = 1 if mesh is None else max(mesh.shape.get("model", 1), 1)
    n_node = 1 if mesh is None else max(mesh.shape.get("node", 1), 1)
    b_live = max(tcfg.batch_size // max(tcfg.num_microbatches, 1)
                 // _dp_shards(mesh), 1)
    moe_mode = None
    if cfg.is_moe:
        # Fail at construction, not at trace time inside shard_map: an
        # invalid (moe_parallel, mesh) pairing — e.g. forced 'ep' with
        # E % n_model != 0 — raises here with a clear message.  'auto'
        # resolves through the roofline cost model at the live per-shard
        # token count, so this resolution matches what moe_sublayer traces.
        # The resolved mode also feeds the budget fit / peak simulation
        # below (a2a capacity buffers only exist under the a2a modes).
        from repro.models.moe_block import resolve_moe_parallel
        moe_mode = resolve_moe_parallel(cfg, mesh, b_live * tcfg.seq_len)
    if hbm_budget is not None:
        prefer = CK.get_plan(remat_policy) if remat_policy is not None \
            else None
        resolved_plan = CK.CheckpointPlan.fit(
            cfg, b_live * tcfg.seq_len, hbm_budget, batch=b_live,
            prefer=prefer, mode=moe_mode, n_model=n_model,
            n_node=n_node).resolved
    else:
        resolved_plan = CK.resolve_plan(remat_policy,
                                        config=cfg.remat_policy)
    cfg = cfg.replace(gmm_backend=resolved.name,
                      remat_policy=resolved_plan.spec)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: T.train_loss(p, batch, cfg, mesh=mesh),
            has_aux=True)(params)

    def accumulate(params, batch):
        M = tcfg.num_microbatches
        if M <= 1:
            return grads_of(params, batch)
        mb = jax.tree.map(
            lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch)

        def body(acc, one):
            (loss, metrics), g = grads_of(params, one)
            acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / M, acc, g)
            return acc, (loss, metrics)

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, (losses, mets) = jax.lax.scan(body, zero, mb)
        return (losses.mean(),
                jax.tree.map(lambda m: m.mean(), mets)), grads

    def step_fn(params, opt_state: AdamWState, batch):
        # Pin trace-time resolution to the construction-time snapshot: an
        # ambient use_backend scope active when jit first traces this step
        # must not outrank the backend this step was made with (the scope is
        # a trace-time no-op once compiled).
        with GB.use_backend(resolved.name):
            (loss, metrics), grads = accumulate(params, batch)
            grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
            lr = cosine_schedule(
                opt_state.step, peak_lr=tcfg.learning_rate,
                warmup=tcfg.warmup_steps, total=tcfg.total_steps)
            params, opt_state = adamw_update(
                grads, opt_state, params, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
                eps=tcfg.eps, weight_decay=tcfg.weight_decay)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
            return params, opt_state, metrics

    step_fn.resolved_backend = resolved
    step_fn.resolved_plan = resolved_plan
    step_fn.peak_sim_bytes = _sim_peak(cfg, tcfg, mesh, resolved_plan.plan)
    return step_fn


def _sim_peak(cfg, tcfg, mesh, plan) -> int:
    """Simulated per-device train-step peak (params + grads + AdamW state +
    the activation timeline) at the live set of one microbatch on one
    data-parallel shard — the same accounting slot the budget fit uses."""
    n_model = 1 if mesh is None else max(mesh.shape.get("model", 1), 1)
    n_node = 1 if mesh is None else max(mesh.shape.get("node", 1), 1)
    b = max(tcfg.batch_size // max(tcfg.num_microbatches, 1)
            // _dp_shards(mesh), 1)
    moe_mode = None
    if cfg.is_moe:
        from repro.models.moe_block import resolve_moe_parallel
        moe_mode = resolve_moe_parallel(cfg, mesh, b * tcfg.seq_len)
    return memsim.simulate_peak(cfg, b * tcfg.seq_len, batch=b, plan=plan,
                                mode=moe_mode, n_model=n_model,
                                n_node=n_node, base="train")


def compiled_step_memory(cfg, tcfg, *, mesh=None, backend=None) -> dict:
    """Memory/cost hook: abstractly lower + compile one train step and return
    its XLA memory analysis (no arrays allocated, no step executed).  This is
    the per-step memory axis the bench harness (``repro.bench.memory``)
    regresses against.  ``gmm_backend`` in the result is the step's resolved
    backend name — stamped from the resolution, not re-read from the env."""
    key = jax.random.PRNGKey(tcfg.seed)
    params = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
    opt_state = jax.eval_shape(init_adamw, params)
    sds = jax.ShapeDtypeStruct
    tok = sds((tcfg.batch_size, tcfg.seq_len), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    step_fn = make_train_step(cfg, tcfg, mesh=mesh, backend=backend)
    compiled = jax.jit(step_fn).lower(params, opt_state, batch).compile()
    mem = compiled.memory_analysis()
    return {
        "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "out_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        "gmm_backend": step_fn.resolved_backend.name,
        "remat_plan": step_fn.resolved_plan.spec,
        "compiled": compiled,
    }


def train(cfg, tcfg, *, mesh=None, params=None, log=print,
          batch_iterator=None, step_hook=None):
    """End-to-end training driver.  Returns (params, opt_state, history).

    ``step_hook(step, metrics)`` — if given — fires after every step with the
    raw (device) metrics plus ``step_s`` (the step's host wall time),
    ``gmm_backend`` (the step's resolved grouped-GEMM backend name),
    ``remat_plan`` (the canonical spec of the step's resolved checkpoint
    plan) and ``peak_sim_bytes`` (the simulated per-device train-step peak
    from :mod:`repro.core.memsim`); the same fields land in ``history`` so
    callers can track per-step timing and provenance without wrapping the
    loop.

    The backend is re-resolved at the top of every step: entering a
    ``use_backend`` scope between steps (e.g. inside ``step_hook``) retargets
    the next step — jitted steps are cached per backend name, so flipping
    back and forth does not recompile."""
    key = jax.random.PRNGKey(tcfg.seed)
    if params is None:
        params = T.init_params(key, cfg)
    opt_state = init_adamw(params)
    resolved_plan = CK.resolve_plan(config=cfg.remat_policy)
    peak_sim_bytes = _sim_peak(cfg, tcfg, mesh, resolved_plan.plan)
    step_fns: dict[str, object] = {}

    def step_fn_for(name: str):
        fn = step_fns.get(name)
        if fn is None:
            fn = jax.jit(make_train_step(cfg, tcfg, mesh=mesh, backend=name),
                         donate_argnums=(0, 1))
            step_fns[name] = fn
        return fn

    if batch_iterator is None:
        batch_iterator = make_batch_iterator(
            cfg.vocab_size, tcfg.seq_len, tcfg.batch_size, tcfg.seed)

    history = []
    t0 = time.perf_counter()
    for step in range(tcfg.total_steps):
        batch = {k: jnp.asarray(v) for k, v in next(batch_iterator).items()}
        resolved = GB.resolve(None, config=_config_backend(cfg, tcfg))
        step_fn = step_fn_for(resolved.name)
        ts = time.perf_counter()
        # (No scope needed here: the backend is pinned at the arg slot via
        # make_train_step(backend=...) and again inside step_fn's own
        # trace-time use_backend scope.)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step_hook is not None:
            jax.block_until_ready(metrics)
            metrics = dict(metrics, step_s=time.perf_counter() - ts,
                           gmm_backend=resolved.name,
                           remat_plan=resolved_plan.spec,
                           peak_sim_bytes=peak_sim_bytes)
            step_hook(step, metrics)
        if step % tcfg.log_every == 0 or step == tcfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if not isinstance(v, str)}
            m["step"] = step
            m.setdefault("step_s", time.perf_counter() - ts)
            m["wall_s"] = time.perf_counter() - t0
            m["gmm_backend"] = resolved.name
            m["remat_plan"] = resolved_plan.spec
            m["peak_sim_bytes"] = peak_sim_bytes
            history.append(m)
            log(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                f"({m['wall_s']:.1f}s)")
        if tcfg.checkpoint_every and step and step % tcfg.checkpoint_every == 0:
            checkpointing.save_checkpoint(
                f"{tcfg.checkpoint_dir}/step_{step}", step, params, opt_state)
    return params, opt_state, history

"""Deterministic synthetic data pipeline: document sampling, sequence
packing, shuffle buffer, and batch iteration.

The corpus is a seeded Zipf-ish token stream with document structure (BOS/EOS
markers, length distribution), packed into fixed-length sequences the way a
production text pipeline would (no padding waste).  For the audio and VLM
architectures the frontends are stubs (per the brief), so the pipeline
synthesizes frame / patch embeddings with matching shapes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    mean_doc_len: int = 180
    bos_id: int = 1
    eos_id: int = 2
    shuffle_buffer: int = 64


class SyntheticCorpus:
    """Seeded document stream with a Zipf unigram distribution and a small
    amount of bigram structure (so models have something learnable)."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # learnable structure: each token prefers a fixed successor
        self.successor = self.rng.permutation(v)

    def documents(self) -> Iterator[np.ndarray]:
        cfg = self.cfg
        while True:
            n = max(4, int(self.rng.exponential(cfg.mean_doc_len)))
            toks = self.rng.choice(cfg.vocab_size, size=n, p=self.unigram)
            # 50% of positions follow the bigram successor rule
            follow = self.rng.random(n) < 0.5
            toks[1:] = np.where(follow[1:], self.successor[toks[:-1]],
                                toks[1:])
            toks[0] = cfg.bos_id
            toks[-1] = cfg.eos_id
            yield toks.astype(np.int32)


class PackedBatches:
    """Greedy sequence packing into (batch, seq_len) token blocks."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.rng = np.random.default_rng(cfg.seed + 1)

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        docs = self.corpus.documents()
        buf: list[np.ndarray] = []
        stream = np.zeros((0,), np.int32)
        while True:
            need = cfg.batch_size * cfg.seq_len
            while stream.size < need + cfg.shuffle_buffer * cfg.mean_doc_len:
                buf.append(next(docs))
                if len(buf) >= cfg.shuffle_buffer:
                    self.rng.shuffle(buf)
                    stream = np.concatenate([stream, *buf])
                    buf = []
            block, stream = stream[:need], stream[need:]
            toks = block.reshape(cfg.batch_size, cfg.seq_len)
            yield {"tokens": toks, "labels": toks.copy()}


def make_batch_iterator(vocab_size: int, seq_len: int, batch_size: int,
                        seed: int = 0) -> Iterator[dict]:
    return iter(PackedBatches(PipelineConfig(
        vocab_size=vocab_size, seq_len=seq_len, batch_size=batch_size,
        seed=seed)))


def synthesize_batch(cfg, batch_size: int, seq_len: int, seed: int = 0):
    """One batch matching an arch's input_kind (used by smoke tests and
    examples; frontends for audio/VLM are stubs per the brief)."""
    rng = np.random.default_rng(seed)
    if cfg.input_kind == "tokens":
        toks = rng.integers(0, cfg.vocab_size, (batch_size, seq_len),
                            dtype=np.int32)
        return {"tokens": toks, "labels": toks.copy()}
    if cfg.input_kind == "frames":
        return {
            "features": rng.standard_normal(
                (batch_size, seq_len, cfg.d_model)).astype(np.float32),
            "labels": rng.integers(0, cfg.vocab_size,
                                   (batch_size, seq_len), dtype=np.int32),
        }
    if cfg.input_kind == "mixed":
        n_img = min(cfg.num_image_tokens, seq_len // 2)
        n_txt = seq_len - n_img
        toks = rng.integers(0, cfg.vocab_size, (batch_size, n_txt),
                            dtype=np.int32)
        return {
            "image_embeds": rng.standard_normal(
                (batch_size, n_img, cfg.d_model)).astype(np.float32),
            "tokens": toks, "labels": toks.copy(),
        }
    raise ValueError(cfg.input_kind)

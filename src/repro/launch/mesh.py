"""Production meshes.  Kept as functions so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init)."""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s  (~ per link)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB per chip

"""Production meshes.  Kept as functions so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init)."""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return make_mesh((data, model), ("data", "model"))


def make_node_mesh(data: int = 1, node: int = 1, model: int = 1):
    """Debug mesh with a factored expert axis: ('data', 'node', 'model').

    The 'node' axis declares the slow (cross-node / DCN) tier of the
    bandwidth hierarchy; 'model' stays the fast intra-node (ICI/NVLink)
    tier.  Expert-parallel modes shard experts over the combined
    ``node x model`` axes, and ``moe_parallel='ep_a2a_hier'`` runs its
    intra-node hop over 'model' and its single cross-node hop over 'node'.
    """
    return make_mesh((data, node, model), ("data", "node", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s  (~ per link)
DCN_BW = 12.5e9                   # B/s  cross-node (per-host data-center NIC)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB per chip


def axis_bandwidth(axis: str) -> float:
    """Bytes/s the collective cost model charges for traffic over ``axis``:
    'node'/'pod' cross the data-center network, everything else rides the
    intra-node interconnect."""
    return DCN_BW if axis in ("node", "pod") else ICI_BW_PER_LINK

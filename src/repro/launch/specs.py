"""ShapeDtypeStruct input specs for every (arch x input-shape) pair — the
shannon/kernels pattern: weak-type-correct, shardable, no device allocation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as T

I32 = jnp.int32
F32 = jnp.float32


def batch_shapes(cfg: ModelConfig, shape: InputShape) -> dict:
    """Training/prefill batch ShapeDtypeStructs for one global batch."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.input_kind == "tokens":
        return {"tokens": sds((B, S), I32), "labels": sds((B, S), I32)}
    if cfg.input_kind == "frames":
        return {"features": sds((B, S, cfg.d_model), F32),
                "labels": sds((B, S), I32)}
    if cfg.input_kind == "mixed":
        n_img = min(cfg.num_image_tokens, S // 2)
        return {"image_embeds": sds((B, n_img, cfg.d_model), F32),
                "tokens": sds((B, S - n_img), I32),
                "labels": sds((B, S - n_img), I32)}
    raise ValueError(cfg.input_kind)


def decode_shapes(cfg: ModelConfig, shape: InputShape) -> dict:
    """Decode-step inputs: one new token + a seq_len-capacity cache."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    return {"tokens": sds((B, 1), I32), "cache": cache,
            "pos": sds((), I32)}


def params_shapes(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def applicable(cfg: ModelConfig, shape: InputShape) -> str | None:
    """Returns None if the pair runs, else the skip reason (DESIGN.md §6)."""
    if shape.kind == "decode":
        if not cfg.causal or cfg.input_kind == "frames":
            return "encoder-only: no autoregressive decode"
        if shape.name == "long_500k":
            sub_quadratic = (
                cfg.arch_type in ("ssm", "hybrid")
                or cfg.sliding_window > 0)
            if not sub_quadratic:
                return "pure full attention: no sub-quadratic variant"
    return None

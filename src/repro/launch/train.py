"""Distributed training launcher.

On a real TPU slice this builds the production mesh, shards params/optimizer
FSDP x TP per `repro.sharding`, and runs the training loop.  On this CPU
container it runs with a debug mesh over host devices (or single device):

    PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
        --reduced --steps 20 --mesh 2x4

    # production (TPU pod):
    python -m repro.launch.train --arch qwen3-moe-30b-a3b --production-mesh
"""

from __future__ import annotations

import argparse
import os
import sys

# Debug meshes on CPU need fake host devices; this must precede jax init.
if "--mesh" in sys.argv and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    _dm = sys.argv[sys.argv.index("--mesh") + 1]
    _n = 1
    for _t in _dm.split("x"):
        _n *= int(_t)
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import make_batch_iterator
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import transformer as T
from repro.train import checkpointing
from repro.train.loop import make_train_step
from repro.train.optimizer import init_adamw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None,
                    help="DxM debug mesh over host devices, e.g. 2x4")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(total_steps=args.steps, batch_size=args.batch,
                       seq_len=args.seq, learning_rate=args.lr,
                       num_microbatches=args.microbatches,
                       log_every=args.log_every,
                       checkpoint_every=args.steps // 2 if args.ckpt_dir else 0,
                       checkpoint_dir=args.ckpt_dir or "/tmp/repro_ckpt")

    mesh = None
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif args.mesh:
        d, m = (int(t) for t in args.mesh.split("x"))
        mesh = make_debug_mesh(d, m)

    params = T.init_params(jax.random.PRNGKey(tcfg.seed), cfg)
    opt = init_adamw(params)
    step_fn = make_train_step(cfg, tcfg, mesh=mesh)

    if mesh is not None:
        pspecs = shd.param_specs(params, mesh)
        shardings = shd.to_shardings(mesh, (pspecs, shd.opt_specs(pspecs)))
        params = jax.device_put(params, shardings[0])
        opt = jax.device_put(opt, shardings[1])
        step = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step = jax.jit(step_fn, donate_argnums=(0, 1))

    it = make_batch_iterator(cfg.vocab_size, tcfg.seq_len, tcfg.batch_size,
                             tcfg.seed)
    ctx = mesh or _nullcontext()
    with ctx:
        for i in range(tcfg.total_steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt, metrics = step(params, opt, batch)
            if i % tcfg.log_every == 0 or i == tcfg.total_steps - 1:
                print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if tcfg.checkpoint_every and i and i % tcfg.checkpoint_every == 0:
                checkpointing.save_checkpoint(
                    f"{tcfg.checkpoint_dir}/step_{i}", i, params, opt)
    print("done")


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()

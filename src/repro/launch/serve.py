"""Serving launcher: loads (or initializes) a model and serves batched
greedy-decode requests through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --prompts 4 --max-new 16 [--ckpt path]
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpointing import restore_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-dtype", default=None, choices=["int8", "model"],
                    help="int8: quantized paged KV pool (~2x fewer bytes)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.causal or cfg.input_kind == "frames":
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to decode")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        _, params = restore_checkpoint(args.ckpt, params)

    eng = ServeEngine(cfg, params, batch_slots=args.prompts,
                      capacity=args.capacity, page_size=args.page_size,
                      kv_dtype=args.kv_dtype)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(
        3, cfg.vocab_size, size=int(rng.integers(2, 9))).astype(np.int32),
        max_new_tokens=args.max_new) for _ in range(args.prompts)]
    for i, r in enumerate(eng.generate(reqs)):
        print(f"req[{i}]: prompt={r.prompt.tolist()} -> {r.out_tokens}")
    print(f"stats: {eng.stats}")


if __name__ == "__main__":
    main()

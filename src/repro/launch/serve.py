"""Serving launcher: loads (or initializes) a model and serves batched
greedy-decode requests through the engine — synchronously, or through the
pipelined async runtime with live token streaming.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --prompts 4 --max-new 16 [--ckpt path] \
        [--stream] [--prefix-cache] [--paged-kernel dense|pallas] [--out f]

Every run emits a JSON run record (stdout, or appended JSONL via ``--out``)
stamping the RESOLVED choices — grouped-GEMM backend, paged-attention
kernel (name + where it was decided), prefix cache, streaming mode — plus
the engine stats, so a perf number can always be traced back to exactly
what served it.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpointing import restore_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-dtype", default=None, choices=["int8", "model"],
                    help="int8: quantized paged KV pool (~2x fewer bytes)")
    ap.add_argument("--stream", action="store_true",
                    help="serve through the pipelined async runtime "
                         "(serve.runtime) and print tokens as they emit")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable copy-on-write prefix sharing: full prompt "
                         "pages of finished requests are cached and mapped "
                         "read-only by later page-aligned-prefix matches")
    ap.add_argument("--paged-kernel", default=None,
                    choices=["dense", "pallas"],
                    help="paged-attention decode implementation (default: "
                         "REPRO_PAGED_ATTN env, else the dense jnp gather; "
                         "pallas walks the page table in-kernel)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--out", default=None, help="append the JSON run record "
                                                "here instead of stdout")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.causal or cfg.input_kind == "frames":
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to decode")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        _, params = restore_checkpoint(args.ckpt, params)

    eng = ServeEngine(cfg, params, batch_slots=args.prompts,
                      capacity=args.capacity, page_size=args.page_size,
                      kv_dtype=args.kv_dtype,
                      prefix_cache=args.prefix_cache,
                      paged_kernel=args.paged_kernel)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(
        3, cfg.vocab_size, size=int(rng.integers(2, 9))).astype(np.int32),
        max_new_tokens=args.max_new) for _ in range(args.prompts)]

    if args.stream:
        from repro.serve.runtime import AsyncServeRuntime
        for i, r in enumerate(reqs):
            r.on_token = (lambda tok, i=i:
                          print(f"req[{i}] token: {tok}", flush=True))
            r.on_finish = (lambda reason, i=i:
                           print(f"req[{i}] finished: {reason}", flush=True))
        with AsyncServeRuntime(eng) as rt:
            rt.run(reqs)
    else:
        eng.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"req[{i}]: prompt={r.prompt.tolist()} -> {r.out_tokens} "
              f"[{r.finish_reason}]")

    rec = {
        "arch": cfg.name,
        "mode": "async-stream" if args.stream else "sync",
        "gmm_backend": eng.backend.name,
        "gmm_backend_source": eng.backend.source,
        "paged_kernel": eng.paged_attn.name,
        "paged_kernel_source": eng.paged_attn.source,
        "prefix_cache": args.prefix_cache,
        "kv_dtype": args.kv_dtype or "model",
        "capacity": args.capacity,
        "page_size": args.page_size,
        "stats": dict(eng.stats),
    }
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    else:
        print(f"run-record: {json.dumps(rec)}")


if __name__ == "__main__":
    main()

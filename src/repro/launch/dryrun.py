"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and report memory/cost/roofline terms.

The XLA host-device override MUST precede any jax import (jax locks the
device count on first init) — hence the first two lines.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out EXPERIMENTS/dryrun.jsonl

``--gmm-backend`` pins the grouped-GEMM backend (repro.core.gmm_backend) for
every MoE lowering in the run — e.g. ``--gmm-backend segment`` probes the
portable path, ``ragged`` the XLA fast path on newer JAX.  ``--moe-parallel``
pins the MoE distribution mode (auto | ep | ep_a2a | tp) for every lowering —
both the weight PartitionSpecs and the shard_map execution path follow it.

``--remat-policy`` pins the activation-checkpoint plan (a registry name or a
``repro.core.checkpoint`` spec like ``"save=ffn_a,ffn_b,qkv"``);
``--hbm-budget BYTES`` (suffixes ``KiB/MiB/GiB`` accepted; *per device*)
engages ``CheckpointPlan.fit`` instead — the cheapest-recompute plan whose
*simulated per-device train-step peak* (params + grads + optimizer state +
the ``repro.core.memsim`` phase timeline: transient recompute spikes, a2a
capacity buffers, optimizer update) fits the budget is selected per
(arch x shape), with an explicit ``--remat-policy`` as the preferred
candidate.  Every record stamps the resolved plan
(``remat_plan``/``remat_plan_source``), the ``remat_fit`` decision table
(one ``source=explicit|config|default`` row when no budget engages the
fit), and the simulated phase timeline
(``peak_sim_bytes``/``peak_sim_phase``/``sim_phases``).
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import sharding as shd                      # noqa: E402
from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config  # noqa: E402
from repro.configs.base import TrainConfig             # noqa: E402
from repro.launch import specs as S                    # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.models import transformer as T              # noqa: E402
from repro.roofline import analyze_compiled            # noqa: E402
from repro.train.loop import make_train_step           # noqa: E402
from repro.train.optimizer import init_adamw           # noqa: E402


def _num_microbatches(shape, mesh, cfg=None) -> int:
    """Gradient accumulation count: smallest power-of-two M (up to one
    sequence per device) that keeps the layer-scan residual carries — the
    dominant train-memory term under full per-layer remat — under ~3.5 GiB
    per device."""
    n_dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_dp *= mesh.shape[a]
    m_cap = max(shape.global_batch // n_dp, 1)
    if cfg is None:
        return min(8, m_cap)
    budget = 3.5 * 2 ** 30
    M = 1
    while M < m_cap:
        tokens_per_dev = shape.global_batch * shape.seq_len / (n_dp * M)
        carry = cfg.num_layers * tokens_per_dev * cfg.d_model * 2
        if carry <= budget and M >= min(8, m_cap):
            break
        M *= 2
    return min(M, m_cap)


def _prefill_chunks(cfg, shape, mesh) -> int:
    """Chunked prefill (vLLM-style) for MoE archs: bound the dense-dispatch
    buffers while keeping each chunk's batch shardable over the data axes."""
    if not cfg.is_moe:
        return 1
    n_dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_dp *= mesh.shape[a]
    return max(1, shape.global_batch // n_dp)


def build_lowerable(arch: str, shape_name: str, mesh, cfg_overrides=None,
                    shape=None, microbatches=None):
    """Returns (fn, example_args, in_shardings) for jit.

    MoE archs lower the GShard dense-dispatch formulation by default: XLA's
    *CPU* decomposition of ragged_dot is dense-per-group (E x temps/FLOPs),
    which is an artifact of this container, not of the TPU target — the
    dense-dispatch graph has the same collectives and fits.  The TPU gmm
    cost is modelled by the 'proxy_gmm' probes (see run_one).
    """
    cfg = get_config(arch)
    if cfg.is_moe and not (cfg_overrides and "moe_impl" in cfg_overrides):
        cfg = cfg.replace(moe_impl="dense")
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = shape or INPUT_SHAPES[shape_name]
    skip = S.applicable(cfg, shape)
    if skip:
        return None, skip, cfg
    pshapes = S.params_shapes(cfg)
    fsdp = not (shape.kind == "decode" and cfg.serve_replicate_weights)
    pspecs = shd.param_specs(pshapes, mesh, fsdp=fsdp,
                             moe_parallel=cfg.moe_parallel)

    if shape.kind == "train":
        M = microbatches if microbatches is not None \
            else _num_microbatches(shape, mesh, cfg)
        tcfg = TrainConfig(num_microbatches=M)
        oshapes = jax.eval_shape(init_adamw, pshapes)
        ospecs = shd.opt_specs(pspecs)
        bshapes = S.batch_shapes(cfg, shape)
        bspecs = shd.batch_specs(cfg, bshapes, mesh)
        fn = make_train_step(cfg, tcfg, mesh=mesh)
        args = (pshapes, oshapes, bshapes)
        in_specs = (pspecs, ospecs, bspecs)
    elif shape.kind == "prefill":
        bshapes = S.batch_shapes(cfg, shape)
        bspecs = shd.batch_specs(cfg, bshapes, mesh)
        Mp = _prefill_chunks(cfg, shape, mesh) if microbatches is None \
            else microbatches

        def fn(params, batch):
            # Prefill emits only the last-position logits (the first sampled
            # token) — materializing (B, S, vocab) would be absurd at 32k.
            # MoE archs chunk the request batch (vLLM-style chunked prefill)
            # to bound the dense-dispatch buffers.
            if Mp > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape(Mp, x.shape[0] // Mp, *x.shape[1:]),
                    batch)

                def body(_, one):
                    lg, aux = T.forward(params, one, cfg, mesh=mesh,
                                        last_only=True)
                    return None, (lg[:, -1, :], aux)

                _, (lg, aux) = jax.lax.scan(body, None, mb)
                return lg.reshape(shape.global_batch, -1), aux.mean()
            logits, aux = T.forward(params, batch, cfg, mesh=mesh,
                                    last_only=True)
            return logits[:, -1, :], aux

        args = (pshapes, bshapes)
        in_specs = (pspecs, bspecs)
    else:  # decode
        # Serving uses bf16 weights (production standard; f32 masters are a
        # training concern) — re-derive param shapes in the serving dtype.
        cfg = cfg.replace(param_dtype="bfloat16")
        pshapes = S.params_shapes(cfg)
        pspecs = shd.param_specs(pshapes, mesh, fsdp=fsdp,
                                 moe_parallel=cfg.moe_parallel)
        ds = S.decode_shapes(cfg, shape)
        cspecs = shd.cache_specs(cfg, ds["cache"], mesh)
        tok_spec = shd.batch_specs(cfg, {"tokens": ds["tokens"]}, mesh)

        def fn(params, cache, tokens, pos):
            return T.decode_step(params, cache, {"tokens": tokens}, pos,
                                 cfg, mesh=mesh)

        args = (pshapes, ds["cache"], ds["tokens"], ds["pos"])
        in_specs = (pspecs, cspecs, tok_spec["tokens"], jax.sharding.PartitionSpec())

    shardings = shd.to_shardings(mesh, in_specs)
    return (fn, args, shardings), None, cfg


def _compile_once(arch, shape_name, mesh, cfg_overrides, shape=None,
                  microbatches=None):
    built, skip, cfg = build_lowerable(arch, shape_name, mesh, cfg_overrides,
                                       shape=shape, microbatches=microbatches)
    if skip:
        return None, skip, cfg
    fn, args, shardings = built
    # Serving always donates the cache (in-place update); without donation
    # XLA double-buffers the multi-GiB cache as a temp.
    donate = (1,) if (shape or INPUT_SHAPES[shape_name]).kind == "decode" \
        else ()
    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    return (compiled, t_lower, t_compile), None, cfg


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            cfg_overrides=None, verbose: bool = True,
            cost_probe: bool = True, microbatches: int | None = None,
            remat_policy: str | None = None,
            hbm_budget: int | None = None) -> dict:
    """Dry-run one (arch x shape x mesh).

    The full scanned model is lowered+compiled (memory analysis, proof of
    lowering).  Because ``cost_analysis`` counts a ``while`` (layer-scan) body
    only once, FLOPs/bytes/collectives are measured from two *unrolled*
    probes (1 and 2 pattern-groups) and extrapolated linearly:
    ``full = B + (G-1)·(C-B)`` — exact for homogeneous layer stacks.
    """
    import dataclasses

    from repro.core import checkpoint as CK
    from repro.core.gmm_backend import resolve
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    # Resolve the checkpoint plan up front (on the overridden config) so
    # every lowering below — the main compile and the cost probes — runs the
    # same baked plan spec.  The budget is *per device*: the fit estimates
    # the residual set live on one device (global batch / data-parallel
    # shards / gradient-accumulation microbatches).
    cfg_overrides = dict(cfg_overrides or {})
    cfg0 = get_config(arch).replace(**cfg_overrides)
    prefer = CK.get_plan(remat_policy) if remat_policy else None
    ishape = INPUT_SHAPES[shape_name]
    n_dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_dp *= mesh.shape[a]
    b_dev = max(ishape.global_batch // max(n_dp, 1), 1)
    if ishape.kind == "train":
        M = microbatches if microbatches is not None \
            else _num_microbatches(ishape, mesh, cfg0)
        b_dev = max(b_dev // M, 1)
    n_model = max(mesh.shape.get("model", 1), 1)
    n_node = max(mesh.shape.get("node", 1), 1)
    moe_mode = None
    if cfg0.is_moe:
        from repro.models.moe_block import resolve_moe_parallel_ex
        decision = resolve_moe_parallel_ex(cfg0, mesh,
                                           b_dev * ishape.seq_len)
        moe_mode = decision.mode
        # The full predicted-cost decision table (mirrors remat_fit): one
        # row per distribution mode with roofline time terms, bytes on the
        # wire, live bytes and the chosen flag — the auto optimizer's
        # provenance, stamped even when the mode was forced.
        rec["moe_parallel"] = decision.mode
        rec["moe_parallel_source"] = decision.source
        rec["moe_parallel_tokens"] = decision.n_tokens
        rec["moe_parallel_decision"] = decision.table_rows()
    if hbm_budget is not None:
        fit = CK.CheckpointPlan.fit(
            cfg0, b_dev * ishape.seq_len, hbm_budget, batch=b_dev,
            prefer=prefer, mode=moe_mode, n_model=n_model, n_node=n_node)
        plan_r = fit.resolved
        rec["remat_fit"] = [dict(dataclasses.asdict(r), source="fit")
                            for r in fit.table]
        rec["hbm_budget"] = fit.budget_bytes
        timeline = fit.timeline
    else:
        from repro.core import memsim
        plan_r = CK.resolve_plan(remat_policy, config=cfg0.remat_policy)
        timeline = memsim.simulate(
            cfg0, b_dev * ishape.seq_len, batch=b_dev, plan=plan_r.plan,
            mode=moe_mode, n_model=n_model, n_node=n_node, base="train")
        # No budget: stamp the decision table anyway (one source=explicit /
        # source=config / source=default row for the resolved plan) so CI
        # assertions over remat_fit never vacuously pass on a missing key.
        src = "explicit" if plan_r.source == "arg" else plan_r.source
        rec["remat_fit"] = [dict(
            spec=plan_r.spec, est_saved_bytes=plan_r.plan.estimate_saved_bytes(
                cfg0, b_dev * ishape.seq_len, batch=b_dev),
            fits=None, chosen=True, sim_peak_bytes=timeline.peak_bytes,
            peak_phase=timeline.peak_phase, source=src)]
    cfg_overrides["remat_policy"] = plan_r.spec
    rec["remat_plan"] = plan_r.spec
    rec["remat_plan_source"] = plan_r.source
    # The simulated per-device phase timeline of the chosen plan: the peak,
    # the phase responsible, and the highest-live phases (memsim table).
    rec["peak_sim_bytes"] = timeline.peak_bytes
    rec["peak_sim_phase"] = timeline.peak_phase
    rec["sim_phases"] = [
        {"phase": p.name, "held_bytes": p.held_bytes,
         "transient_bytes": p.transient_bytes,
         "collective_bytes": p.collective_bytes, "live_bytes": p.live_bytes}
        for p in sorted(timeline.phases, key=lambda p: -p.live_bytes)[:4]]
    out, skip, cfg = _compile_once(arch, shape_name, mesh, cfg_overrides,
                                   microbatches=microbatches)
    # Stamp the backend the lowering actually resolved (cfg at the config
    # slot, use_backend scope above it) — not a re-read of the env var.
    rec["gmm_backend"] = resolve(None, config=cfg.gmm_backend).name
    if skip:
        rec["status"] = f"SKIP({skip})"
        return rec
    compiled, t_lower, t_compile = out
    full = analyze_compiled(compiled, cfg, INPUT_SHAPES[shape_name],
                            n_chips=mesh.devices.size)
    rec.update(status="OK", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1), **full)

    if cost_probe and cfg.num_groups > 1:
        period = cfg.pattern_period
        shape = INPUT_SHAPES[shape_name]
        # The probes must not hide cost inside a second (microbatch) scan:
        # train probes lower ONE microbatch and scale the result by M.
        M = 1
        if shape.kind == "train":
            M = microbatches if microbatches is not None \
                else _num_microbatches(shape, mesh, cfg)
        elif shape.kind == "prefill":
            M = microbatches if microbatches is not None \
                else _prefill_chunks(cfg, shape, mesh)
        pshape = shape
        if M > 1:
            import dataclasses
            pshape = dataclasses.replace(
                shape, global_batch=shape.global_batch // M)
        probes = []
        for g in (1, 2):
            ov = dict(cfg_overrides or {})
            ov.update(num_layers=g * period, scan_layers=False)
            if cfg.is_moe:
                # TPU-gmm cost model (see build_lowerable docstring).
                ov.setdefault("moe_impl", "proxy_gmm")
            pout, pskip, pcfg = _compile_once(
                arch, shape_name, mesh, ov, shape=pshape, microbatches=1)
            assert pskip is None
            probes.append(analyze_compiled(
                pout[0], pcfg, INPUT_SHAPES[shape_name],
                n_chips=mesh.devices.size))
        b, c = probes
        G = cfg.num_groups

        def extrap(key):
            # clamp: XLA occasionally optimizes the 2-group probe harder than
            # the 1-group one, which would extrapolate below zero
            return max(0.0, M * (b[key] + (G - 1) * (c[key] - b[key])))

        from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
        rec["flops_per_dev"] = extrap("flops_per_dev")
        rec["hlo_bytes_per_dev"] = extrap("hlo_bytes_per_dev")
        rec["collective_bytes"] = extrap("collective_bytes")
        rec["collective_counts"] = {
            k: max(0, b["collective_counts"][k] +
                   (G - 1) * (c["collective_counts"][k]
                              - b["collective_counts"][k]))
            for k in b["collective_counts"]}
        rec["t_compute_s"] = rec["flops_per_dev"] / PEAK_FLOPS_BF16
        rec["t_memory_s"] = rec["hlo_bytes_per_dev"] / HBM_BW
        rec["t_collective_s"] = rec["collective_bytes"] / ICI_BW_PER_LINK
        rec["dominant"] = max(
            (("compute", rec["t_compute_s"]), ("memory", rec["t_memory_s"]),
             ("collective", rec["t_collective_s"])), key=lambda kv: kv[1])[0]
        rec["useful_flops_ratio"] = rec["model_flops_global"] / max(
            rec["flops_per_dev"] * mesh.devices.size, 1.0)
        rec["cost_probe"] = "extrapolated(1,2 groups unrolled)"

    if verbose and rec.get("moe_parallel_decision"):
        # Predicted-vs-measured: the cost model's per-mode ranking next to
        # what the compiled HLO actually put on the wire.
        print(f"  moe_parallel={rec['moe_parallel']} "
              f"(source={rec['moe_parallel_source']}, "
              f"ranked at {rec['moe_parallel_tokens']} tokens/dev):")
        for r in rec["moe_parallel_decision"]:
            mark = "*" if r["chosen"] else " "
            why = "" if r["feasible"] else f"  [{r['why']}]"
            print(f"  {mark} {r['mode']:<12}"
                  f" t={r['t_total_s'] * 1e6:9.1f}us"
                  f" (comp {r['t_compute_s'] * 1e6:.1f}"
                  f" mem {r['t_memory_s'] * 1e6:.1f}"
                  f" coll {r['t_collective_s'] * 1e6:.1f})"
                  f" live={r['live_bytes'] / 2**20:8.1f}MiB"
                  f" a2a={r['a2a_bytes'] / 2**20:.2f}MiB"
                  f" psum={r['psum_bytes'] / 2**20:.2f}MiB{why}")
        by_kind = rec.get("collective_bytes_by_kind")
        if by_kind:
            kinds = " ".join(f"{k}={v / 2**20:.1f}MiB"
                             for k, v in sorted(by_kind.items()))
            print(f"    measured (compiled HLO, whole step): {kinds}")
    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']}] "
              f"plan={rec['remat_plan']} "
              f"args={rec['arg_bytes']/2**30:.2f}GiB "
              f"temp={rec['temp_bytes']/2**30:.2f}GiB "
              f"peak={rec['peak_bytes']/2**30:.2f}GiB/dev "
              f"fits={rec['fits_hbm']} | flops/dev={rec['flops_per_dev']:.3e} "
              f"coll={rec['collective_bytes']/2**20:.1f}MiB "
              f"dominant={rec['dominant']}")
        print("  memory_analysis:", compiled.memory_analysis())
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (hillclimbing)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the cost-extrapolation probes (multi-pod pass "
                         "only needs the lowering/memory proof)")
    ap.add_argument("--tag", default=None,
                    help="label recorded with each JSONL row (perf log)")
    ap.add_argument("--gmm-backend", default=None,
                    help="grouped-GEMM backend for MoE lowerings "
                         "(ragged | segment | pallas; default auto)")
    ap.add_argument("--moe-parallel", default=None,
                    choices=["auto", "ep", "ep_a2a", "ep_a2a_hier", "tp"],
                    help="MoE distribution mode override (config field "
                         "moe_parallel; see README 'Distribution modes')")
    ap.add_argument("--remat-policy", default=None,
                    help="activation-checkpoint plan: registry name or spec "
                         "('save=ffn_a,ffn_b,qkv;moe:recompute=ffn_yswi'); "
                         "see README 'Activation checkpoint plans'")
    ap.add_argument("--hbm-budget", default=None,
                    help="per-device train-step peak budget (bytes; "
                         "KiB/MiB/GiB suffixes ok) — budget-fit the "
                         "checkpoint plan per (arch x shape) via "
                         "CheckpointPlan.fit over the simulated per-device "
                         "peak (core.memsim phase timeline); an explicit "
                         "--remat-policy becomes the preferred candidate")
    args = ap.parse_args(argv)
    from repro.core.checkpoint import get_plan, parse_size
    if args.remat_policy:
        get_plan(args.remat_policy)      # validate before any compile work
    hbm_budget = parse_size(args.hbm_budget) if args.hbm_budget else None
    overrides = json.loads(args.override) if args.override else None
    if args.moe_parallel:
        overrides = dict(overrides or {}, moe_parallel=args.moe_parallel)
    # --gmm-backend pins via a use_backend scope around the whole run — a
    # process-local, exception-safe pin (the old os.environ mutation leaked
    # into anything else alive in the process).
    import contextlib

    from repro.core.gmm_backend import use_backend
    backend_scope = (use_backend(args.gmm_backend) if args.gmm_backend
                     else contextlib.nullcontext())

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs.append((args.arch, args.shape))

    ok = True
    with backend_scope:
        for arch, shape in pairs:
            try:
                rec = run_one(arch, shape, multi_pod=args.multi_pod,
                              cfg_overrides=overrides,
                              microbatches=args.microbatches,
                              cost_probe=not args.no_probe,
                              remat_policy=args.remat_policy,
                              hbm_budget=hbm_budget)
                if args.tag:
                    rec["tag"] = args.tag
            except Exception as e:  # noqa: BLE001 — report and continue
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "status": f"FAIL({type(e).__name__}: {e})"}
                ok = False
                print(f"[{arch} x {shape}] FAILED: {e}", file=sys.stderr)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
            else:
                print(json.dumps(rec))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""``core/checkpoint.py`` policy verification (paper §5.2 / Algorithm 1).

Three guarantees, on small transformer stacks:
  * every policy is *semantics-preserving*: gradients match the ``full``
    baseline (remat changes what is saved, never what is computed);
  * the policies *actually change what is saved*, with the strict ordering
    ``none < paper_min < paper < full`` in saved-residual bytes;
  * the static estimator derived from the policy tag sets
    (``estimate_saved_bytes``) tracks the measured saved-residual deltas.
"""

import jax
import numpy as np

from repro.bench.memory import (bench_config, bench_dense_config,
                                residual_bytes)
from repro.core.checkpoint import POLICIES, POLICY_TAGS, estimate_saved_bytes
from repro.models import transformer as T

DENSE = bench_dense_config()
MOE = bench_config().replace(gmm_backend="segment")
ALL_POLICIES = tuple(POLICIES)          # none, full, dots, paper, paper_min


def _grads(cfg, seed=0):
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    loss = lambda p: T.train_loss(p, batch, cfg)[0]
    return jax.jit(jax.grad(loss))(params)


def _assert_tree_close(a, b, atol, ctx):
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   atol=atol, err_msg=ctx)


def test_gradient_parity_all_policies_dense():
    """Every policy reproduces the `full` gradients on a dense SwiGLU stack."""
    base = _grads(DENSE.replace(remat_policy="full"))
    for pol in ALL_POLICIES:
        if pol == "full":
            continue
        g = _grads(DENSE.replace(remat_policy=pol))
        _assert_tree_close(base, g, 1e-5, f"policy={pol}")


def test_gradient_parity_all_policies_moe():
    """Same through the MoE layer's custom VJP (policy remat must compose
    with the hand-written residual set, not corrupt it)."""
    base = _grads(MOE.replace(remat_policy="full"))
    for pol in ALL_POLICIES:
        if pol == "full":
            continue
        g = _grads(MOE.replace(remat_policy=pol))
        _assert_tree_close(base, g, 1e-5, f"policy={pol}")


def test_residual_bytes_strict_ordering():
    """The acceptance ordering: none < paper_min < paper < full, measured via
    saved_residuals on the dense stack (whose FFN carries the full
    A/B/Y_swi tag set)."""
    b = {pol: residual_bytes(DENSE, pol)
         for pol in ("none", "paper_min", "paper", "dots", "full")}
    assert b["none"] < b["paper_min"] < b["paper"] < b["full"], b
    # `dots` (save matmul outputs) also strictly beats the no-remat baseline.
    assert b["dots"] < b["full"], b


def test_residual_bytes_moe_policies_bounded_by_full():
    """On the MoE stack the expert FFN saves via its custom VJP under every
    policy, but the scanned-layer policies still order correctly."""
    b = {pol: residual_bytes(MOE, pol) for pol in ("none", "paper", "full")}
    assert b["none"] < b["paper"] < b["full"], b


def test_static_estimator_matches_measured_deltas():
    """estimate_saved_bytes (shapes + tag sets, no tracing) predicts the
    measured residual growth of each tag policy over `none`."""
    n_tokens = 2 * 32
    base = residual_bytes(DENSE, "none")
    for pol in ("paper_min", "paper"):
        est = estimate_saved_bytes(DENSE, pol, n_tokens)
        delta = residual_bytes(DENSE, pol) - base
        assert est > 0
        np.testing.assert_allclose(est, delta, rtol=0.3,
                                   err_msg=f"policy={pol}")
    assert estimate_saved_bytes(DENSE, "none", n_tokens) == 0
    # ordering is inherent to the tag sets
    assert (estimate_saved_bytes(DENSE, "paper_min", n_tokens)
            < estimate_saved_bytes(DENSE, "paper", n_tokens))
    # non-tag policies are not statically estimable
    assert estimate_saved_bytes(DENSE, "full", n_tokens) is None
    assert estimate_saved_bytes(DENSE, "dots", n_tokens) is None


def test_policy_tags_consistent_with_policies():
    """Every tag-based policy in POLICIES has its tag set exported (the bench
    estimator and the remat policy must never drift apart)."""
    assert set(POLICY_TAGS) <= set(POLICIES)
    assert set(POLICY_TAGS["paper_min"]) < set(POLICY_TAGS["paper"])
    assert POLICY_TAGS["none"] == ()


def test_memory_analysis_temp_ordering():
    """Corroborate via XLA: recompute-everything compiles to no more live
    temp than save-everything on the dense stack."""
    from repro.bench.memory import activation_memory_report
    lo = activation_memory_report(DENSE, "none")
    hi = activation_memory_report(DENSE, "full")
    assert lo["temp_bytes"] <= hi["temp_bytes"], (lo["temp_bytes"],
                                                  hi["temp_bytes"])

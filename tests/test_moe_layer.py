"""MoEBlaze layer correctness: forward/backward vs the dense-dispatch oracle
and the MegaBlocks-style baseline, across activations and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baseline import moe_ffn_dense, moe_ffn_megablocks
from repro.core.moe_layer import moe_ffn_blaze
from repro.core.routing import build_dispatch, top_k_gating


def _setup(seed, L, d, h, E, k, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (L, d), dtype)
    wg = (jax.random.normal(ks[1], (d, E)) * 0.1).astype(dtype)
    w1 = (jax.random.normal(ks[2], (E, d, h)) * 0.1).astype(dtype)
    w2 = (jax.random.normal(ks[3], (E, d, h)) * 0.1).astype(dtype)
    w3 = (jax.random.normal(ks[4], (E, h, d)) * 0.1).astype(dtype)
    return x, wg, w1, w2, w3


def _loss(impl, act, E, k, save_yswi=True):
    def f(x, w1, w2, w3, wg):
        g = top_k_gating(x, wg, k)
        disp = build_dispatch(g.topk_experts, E)
        gates = g.topk_weights.astype(x.dtype)
        w2_ = w2 if act == "swiglu" else None
        if impl == "dense":
            y = moe_ffn_dense(x, g.router_probs, g.topk_experts, gates,
                              w1, w3, w2_, activation=act)
        elif impl == "megablocks":
            y = moe_ffn_megablocks(x, gates, disp, w1, w3, w2_,
                                   activation=act)
        else:
            y = moe_ffn_blaze(x, gates, disp, w1, w3, w2_, activation=act,
                              save_yswi=save_yswi)
        return (y.astype(jnp.float32) ** 2).sum()
    return f


@pytest.mark.parametrize("act", ["swiglu", "silu", "relu", "gelu"])
@pytest.mark.parametrize("impl", ["blaze", "megablocks"])
def test_grads_match_dense_oracle(act, impl):
    L, d, h, E, k = 96, 32, 48, 8, 2
    args = _setup(0, L, d, h, E, k, jnp.float32)
    x, wg, w1, w2, w3 = args
    f = _loss(impl, act, E, k)
    f_ref = _loss("dense", act, E, k)
    v, vr = f(x, w1, w2, w3, wg), f_ref(x, w1, w2, w3, wg)
    np.testing.assert_allclose(v, vr, rtol=1e-4)
    g = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, w1, w2, w3, wg)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3, 4))(x, w1, w2, w3, wg)
    for i, (a, b) in enumerate(zip(g, gr)):
        err = np.abs(np.asarray(a) - np.asarray(b)).max()
        scale = np.abs(np.asarray(b)).max() + 1e-9
        assert err / scale < 2e-3, (i, err, scale)


def test_save_yswi_variants_identical():
    L, d, h, E, k = 64, 16, 32, 4, 2
    x, wg, w1, w2, w3 = _setup(1, L, d, h, E, k, jnp.float32)
    g1 = jax.grad(_loss("blaze", "swiglu", E, k, True),
                  argnums=(0, 1, 2, 3, 4))(x, w1, w2, w3, wg)
    g2 = jax.grad(_loss("blaze", "swiglu", E, k, False),
                  argnums=(0, 1, 2, 3, 4))(x, w1, w2, w3, wg)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    L, d, h, E, k = 64, 32, 64, 4, 2
    x, wg, w1, w2, w3 = _setup(2, L, d, h, E, k, dtype)
    f = _loss("blaze", "swiglu", E, k)
    v = f(x, w1, w2, w3, wg)
    assert np.isfinite(float(v))
    g = jax.grad(f, argnums=(1,))(x, w1, w2, w3, wg)[0]
    assert g.dtype == dtype
    assert np.isfinite(np.asarray(g, np.float32)).all()


def test_extreme_imbalance_dropless():
    """All tokens route to one expert — dropless must handle it exactly."""
    L, d, h, E, k = 64, 16, 24, 8, 2
    x, wg, w1, w2, w3 = _setup(3, L, d, h, E, k, jnp.float32)
    # bias gate so experts 3 and 5 win everywhere
    wg = wg.at[:, 3].add(100.0).at[:, 5].add(99.0)
    f = _loss("blaze", "swiglu", E, k)
    f_ref = _loss("dense", "swiglu", E, k)
    np.testing.assert_allclose(f(x, w1, w2, w3, wg),
                               f_ref(x, w1, w2, w3, wg), rtol=1e-4)


def test_jit_and_vmap_compatible():
    L, d, h, E, k = 32, 16, 24, 4, 2
    x, wg, w1, w2, w3 = _setup(4, L, d, h, E, k, jnp.float32)
    f = jax.jit(_loss("blaze", "swiglu", E, k))
    assert np.isfinite(float(f(x, w1, w2, w3, wg)))

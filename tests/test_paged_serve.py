"""Paged serving engine tests: batched-vs-solo parity (the left-pad
regression), model-level prefill/decode vs full forward, continuous slot
release, page-budget admission, page reuse, int8 cache parity, and
fixed-seed sampling determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import kv_quant as KQ
from repro.serve import paged_cache as PC
from repro.serve.engine import Request, ServeEngine

CFG = get_config("yi_6b").reduced().replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=64, attn_chunk=16)


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _mixed_prompts(vocab, lens=(1, 4, 7, 3)):
    rng = np.random.default_rng(0)
    return [rng.integers(1, vocab, size=L).astype(np.int32) for L in lens]


def _reqs(prompts, max_new=5, eos_id=None, **kw):
    # default eos outside the vocab: runs always reach max_new
    return [Request(prompt=p, max_new_tokens=max_new,
                    eos_id=CFG.vocab_size if eos_id is None else eos_id,
                    **kw)
            for p in prompts]


# ---------------------------------------------------------------------------
# the left-pad regression: batched output must not depend on batch-mates
# ---------------------------------------------------------------------------


def test_batched_matches_solo_mixed_lengths(params):
    """Mixed prompt lengths in one batch give exactly the tokens each
    request gets alone.  The seed engine failed this: left-padding
    teacher-forced token-id-0 keys at VALID positions, so short prompts
    attended to pad garbage whenever batched with longer ones."""
    prompts = _mixed_prompts(CFG.vocab_size)
    eng = ServeEngine(CFG, params, batch_slots=4, capacity=32, page_size=8)
    batched = eng.generate(_reqs(prompts))
    for p, r in zip(prompts, batched):
        solo = ServeEngine(CFG, params, batch_slots=1, capacity=32,
                           page_size=8)
        ref = solo.generate(_reqs([p]))[0]
        assert r.out_tokens == ref.out_tokens, (p.size, r.out_tokens,
                                                ref.out_tokens)


def test_prefill_decode_match_full_forward(params):
    """Model-level: one jitted prefill + per-request-position decode steps
    reproduce the full forward's greedy continuation for every request of a
    right-padded mixed-length batch."""
    lens = np.array([2, 6, 4])
    B, S, max_new, ps = 3, 8, 4, 4
    rng = np.random.default_rng(1)
    toks = np.zeros((B, S), np.int32)
    for b in range(B):
        toks[b, :lens[b]] = rng.integers(1, CFG.vocab_size, lens[b])
    pool = PC.PagePool(32)
    pps = PC.pages_needed(S + max_new, ps)
    pt = np.full((B, pps), PC.TRASH_PAGE, np.int32)
    for b in range(B):
        n = PC.pages_needed(int(lens[b]) + max_new, ps)
        pt[b, :n] = pool.alloc(n)
    cache = T.init_paged_cache(CFG, 32, ps)
    logits, cache = T.prefill(params, jnp.asarray(toks), jnp.asarray(lens),
                              cache, jnp.asarray(pt), CFG)
    cur = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
    seqs = [list(toks[b, :lens[b]]) + [int(cur[b])] for b in range(B)]
    pos = lens.copy()
    for _ in range(max_new - 1):
        logits, cache = T.paged_decode_step(
            params, cache, jnp.asarray(cur[:, None]), jnp.asarray(pos),
            jnp.asarray(pt), CFG)
        cur = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        pos += 1
        for b in range(B):
            seqs[b].append(int(cur[b]))
    for b in range(B):
        ref, _ = T.forward(params, {"tokens": jnp.asarray([seqs[b][:-1]])},
                           CFG)
        ref_greedy = np.argmax(np.asarray(ref[0]), axis=-1)
        assert seqs[b][lens[b]:] == list(ref_greedy[lens[b] - 1:]), b


def test_init_paged_cache_rejects_ssm_patterns():
    ssm_cfg = get_config("xlstm_1_3b").reduced().replace(
        num_layers=2, d_model=64, num_heads=2, vocab_size=64)
    with pytest.raises(ValueError, match="attention block pattern"):
        T.init_paged_cache(ssm_cfg, 8, 4)
    with pytest.raises(ValueError, match="block pattern"):
        ServeEngine(ssm_cfg, {}, batch_slots=1)


def test_write_prefill_tail_past_table_goes_to_trash():
    """S beyond the page table's logical width must spill to the trash page,
    never alias onto the last real page.  Regression: JAX's clamping gather
    sent out-of-range columns to the LAST table column, so a pow2 prefill
    bucket wider than the table scattered pad garbage over the request's own
    final page of valid prompt KV."""
    B, ps, n_pages = 2, 4, 5
    Hkv, Dh = 2, 8
    pt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)   # width 2 -> T = 8
    S = 12                                          # 4 positions past the table
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hkv, Dh))
    v = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, Dh))
    pool = PC.write_prefill(PC.init_paged_kv(n_pages, ps, Hkv, Dh,
                                             jnp.float32), k, v, pt)
    for b in range(B):
        for t in range(8):                          # in-table positions exact
            np.testing.assert_array_equal(
                np.asarray(pool.k[int(pt[b, t // ps]), t % ps]),
                np.asarray(k[b, t]))


def test_engine_nonaligned_capacity_matches_aligned(params):
    """A capacity that is not pow2-aligned to the page grid (48 = 3 pages of
    16, but _pow2(40) = 64) must generate the same tokens as an aligned one.
    Regression: the prefill bucket overshot the page table and the pad tail
    overwrote the prompt's last real page — silent wrong tokens on exactly
    the configs the parity bench never exercised."""
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, CFG.vocab_size, size=40).astype(np.int32)
    mis = ServeEngine(CFG, params, batch_slots=1, capacity=48, page_size=16)
    ali = ServeEngine(CFG, params, batch_slots=1, capacity=64, page_size=16)
    got = mis.generate(_reqs([prompt], max_new=4))[0]
    ref = ali.generate(_reqs([prompt], max_new=4))[0]
    assert got.out_tokens == ref.out_tokens


def test_zero_budget_rejected_and_truncation_accounted(params):
    """max_new_tokens < 1 raises at validation (prefill always samples one
    token, so a 0 budget cannot be honored), and a budget silently bounded
    by capacity is surfaced in stats['truncated_budgets']."""
    eng = ServeEngine(CFG, params, batch_slots=1, capacity=16, page_size=8)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.enqueue(Request(prompt=np.asarray([1, 2], np.int32),
                            max_new_tokens=0))
    prompt = np.arange(1, 13, dtype=np.int32)       # 12 + 64 > capacity 16
    done = eng.generate(_reqs([prompt], max_new=64))[0]
    assert eng.stats["truncated_budgets"] == 1
    assert len(done.out_tokens) == 16 - 12 + 1


# ---------------------------------------------------------------------------
# continuous scheduler
# ---------------------------------------------------------------------------


def test_finished_requests_release_slots(params):
    """Total decode slot-tokens == sum(T_r - 1): a finished request's slot
    stops decoding immediately (the seed engine decoded every slot until the
    LAST request finished — batch x max(T) slot-steps)."""
    prompts = _mixed_prompts(CFG.vocab_size, lens=(2, 3, 5, 2))
    eng = ServeEngine(CFG, params, batch_slots=2, capacity=32, page_size=8)
    reqs = [Request(prompt=p, max_new_tokens=m, eos_id=CFG.vocab_size)
            for p, m in zip(prompts, (1, 3, 7, 2))]
    eng.generate(reqs)
    for r, m in zip(reqs, (1, 3, 7, 2)):
        assert len(r.out_tokens) == m
    assert eng.stats["decode_slot_tokens"] == sum((1, 3, 7, 2)) - len(reqs)
    # with 2 slots the longest request alone lower-bounds the step count
    assert eng.stats["decode_steps"] >= 7 - 1


def test_eos_frees_slot_early(params):
    """A request that samples EOS stops immediately and its tokens end at
    the EOS; the engine keeps serving the others."""
    prompts = _mixed_prompts(CFG.vocab_size, lens=(3, 4))
    eng = ServeEngine(CFG, params, batch_slots=2, capacity=32, page_size=8)
    probe = eng.generate(_reqs(prompts, max_new=8))
    eos = probe[0].out_tokens[2]          # force EOS at the 3rd token
    eng2 = ServeEngine(CFG, params, batch_slots=2, capacity=32, page_size=8)
    reqs = _reqs(prompts, max_new=8, eos_id=int(eos))
    eng2.generate(reqs)
    assert reqs[0].done and reqs[0].out_tokens[-1] == eos
    assert len(reqs[0].out_tokens) <= 3
    assert len(reqs[1].out_tokens) >= len(reqs[0].out_tokens)


def test_admission_order_under_page_budget(params):
    """FIFO admission under a page budget: with pages for only one resident
    request, requests run one at a time in arrival order — every request's
    output equals its solo run, the pool never holds more than one
    request's pages, and the blocked head is accounted."""
    prompts = _mixed_prompts(CFG.vocab_size, lens=(4, 4, 4))
    # each request writes 4 + 3 - 1 = 6 tokens -> 1 page of 8; a pool of 2
    # (1 allocatable past the trash page) admits exactly one at a time even
    # though two slots are free
    eng = ServeEngine(CFG, params, batch_slots=2, capacity=16, page_size=8,
                      num_pages=2)
    for r in _reqs(prompts, max_new=3):
        eng.enqueue(r)
    done = eng.run()
    assert eng.stats["blocked_admissions"] >= 1
    assert eng.stats["peak_pages_used"] == 1
    for p, r in zip(prompts, done):
        solo = ServeEngine(CFG, params, batch_slots=1, capacity=16,
                           page_size=8)
        ref = solo.generate(_reqs([p], max_new=3))[0]
        assert r.out_tokens == ref.out_tokens
    # an impossible request (more pages than the pool will ever have)
    # raises at enqueue, not mid-run
    with pytest.raises(ValueError, match="pages"):
        eng.enqueue(Request(prompt=np.arange(1, 12, dtype=np.int32),
                            max_new_tokens=6, eos_id=CFG.vocab_size))


def test_sampling_deterministic_under_fixed_seed(params):
    """greedy=False samples in-graph with per-(request, token-index)
    fold_in keys: same seed => same tokens, different seed => (almost
    surely) different — and tokens never depend on batching/scheduling."""
    prompts = _mixed_prompts(CFG.vocab_size, lens=(3, 5))
    outs = []
    for seed in (7, 7, 8):
        eng = ServeEngine(CFG, params, batch_slots=2, capacity=32,
                          page_size=8, greedy=False, temperature=1.0,
                          seed=seed)
        rs = eng.generate(_reqs(prompts, max_new=6))
        outs.append(tuple(tuple(r.out_tokens) for r in rs))
    assert outs[0] == outs[1]
    assert outs[0] != outs[2]
    with pytest.raises(ValueError, match="temperature"):
        ServeEngine(CFG, params, greedy=False, temperature=0.0)


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------


def test_page_pool_reuse_after_eviction():
    pool = PC.PagePool(8)
    a = pool.alloc(3)
    assert PC.TRASH_PAGE not in a
    pool.free(a)
    b = pool.alloc(3)
    assert b == a                        # LIFO: freed pages reused first
    assert pool.free_pages == 4
    assert pool.min_free == 4
    pool.free(b)
    with pytest.raises(ValueError, match="double free"):
        pool.free(b[:1])
    with pytest.raises(ValueError, match="invalid page"):
        pool.free([PC.TRASH_PAGE])
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(8)


def test_engine_page_reuse(params):
    """Pages freed by a finished request are immediately reused by the next
    admitted one — the peak page usage of a one-at-a-time run equals ONE
    request's footprint, not the sum."""
    prompts = _mixed_prompts(CFG.vocab_size, lens=(4, 4, 4))
    eng = ServeEngine(CFG, params, batch_slots=1, capacity=16, page_size=8)
    eng.generate(_reqs(prompts, max_new=3))
    assert eng.stats["peak_pages_used"] == PC.pages_needed(4 + 3 - 1, 8)


# ---------------------------------------------------------------------------
# int8 paged cache
# ---------------------------------------------------------------------------


def test_int8_engine_cache_bytes_and_tolerance(params):
    """The int8 paged pool measures ~2x fewer bytes than a same-shape model-
    dtype pool, and the int8 engine's greedy tokens stay close to the f32
    engine's (identical on this config — attention outputs agree to the
    quantization tolerance)."""
    f32_pool = T.init_paged_cache(CFG, 16, 8)
    i8_pool = T.init_paged_cache(CFG, 16, 8, quantized=True)
    assert KQ.cache_bytes(i8_pool) < 0.55 * KQ.cache_bytes(f32_pool)

    prompts = _mixed_prompts(CFG.vocab_size, lens=(3, 6))
    base = ServeEngine(CFG, params, batch_slots=2, capacity=32, page_size=8)
    int8 = ServeEngine(CFG, params, batch_slots=2, capacity=32, page_size=8,
                       kv_dtype="int8")
    b = base.generate(_reqs(prompts, max_new=5))
    q = int8.generate(_reqs(prompts, max_new=5))
    for rb, rq in zip(b, q):
        assert rb.out_tokens == rq.out_tokens


def test_paged_attention_int8_matches_fp():
    """serve.paged_cache.paged_attention against an int8 pool tracks the fp
    pool within the kv_quant tolerance."""
    B, ps, n_pages, Hkv, Hq, Dh = 2, 4, 9, 2, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh))
    k = jax.random.normal(ks[1], (B, 12, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, 12, Hkv, Dh))
    pt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pos = jnp.asarray([11, 7])
    fp = PC.write_prefill(PC.init_paged_kv(n_pages, ps, Hkv, Dh,
                                           jnp.float32), k, v, pt)
    i8 = PC.write_prefill(PC.init_paged_kv(n_pages, ps, Hkv, Dh,
                                           jnp.float32, quantized=True),
                          k, v, pt)
    ref = PC.paged_attention(q, fp, pt, pos)
    out = PC.paged_attention(q, i8, pt, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# serving bench gates
# ---------------------------------------------------------------------------


def test_serving_gate_failures_pairing():
    from repro.bench.record import entry
    from repro.bench.serving import serving_gate_failures

    def fam(par, got, want, i8, bf16, noshare=48, shared=32, pmis=0,
            amis=0):
        return [entry("serving/parity/mismatched_tokens", par,
                      kind="serving"),
                entry("serving/sched/decode_slot_tokens", got,
                      kind="serving"),
                entry("serving/sched/expected_slot_tokens", want,
                      kind="serving"),
                entry("serving/kv/int8_paged_bytes_per_token", i8,
                      kind="serving"),
                entry("serving/kv/bf16_dense_bytes_per_token", bf16,
                      kind="serving"),
                entry("serving/prefix/prefill_tokens_nosharing", noshare,
                      kind="serving"),
                entry("serving/prefix/prefill_tokens_shared", shared,
                      kind="serving", page_size=8),
                entry("serving/prefix/mismatched_tokens", pmis,
                      kind="serving"),
                entry("serving/pipeline/async_sync_mismatches", amis,
                      kind="serving")]

    assert serving_gate_failures([]) == []            # legacy record
    assert serving_gate_failures(fam(0, 16, 16, 100, 200)) == []
    assert any("parity" in f for f in
               serving_gate_failures(fam(2, 16, 16, 100, 200)))
    assert any("slot" in f for f in
               serving_gate_failures(fam(0, 20, 16, 100, 200)))
    assert any("kv bytes" in f for f in
               serving_gate_failures(fam(0, 16, 16, 150, 200)))
    # prefix pair must save >= one full page of prefill tokens ...
    assert any("full page" in f for f in
               serving_gate_failures(fam(0, 16, 16, 100, 200,
                                         noshare=48, shared=41)))
    # ... without changing a single token.
    assert any("COW" in f for f in
               serving_gate_failures(fam(0, 16, 16, 100, 200, pmis=1)))
    assert any("pipeline" in f for f in
               serving_gate_failures(fam(0, 16, 16, 100, 200, amis=3)))
    assert any("incomplete" in f for f in
               serving_gate_failures(fam(0, 16, 16, 100, 200)[:2]))

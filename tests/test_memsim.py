"""Per-phase peak-memory simulator (``core.memsim``) verification.

Covers the PR's acceptance axes:
  * phase-timeline *component* monotonicity across the recompute ladder
    (``none`` >= ``paper`` >= ``full`` backward transients; held residuals
    the other way round) — peaks themselves are NOT monotone, which is the
    whole point of simulating them;
  * a2a capacity-buffer accounting appears only under ``ep_a2a``;
  * ``fit`` with the simulator picks a plan the residual-only accountant
    rejects (regression pinning the transient-peak case);
  * the sim-vs-measured parity gate (``bench.memory.sim_parity_failures``)
    flags out-of-tolerance and missing-counterpart entries.
"""

import jax
import pytest

from repro.bench import record as R
from repro.bench.memory import (SIM_PARITY_TOLERANCE_PCT, bench_config,
                                bench_dense_config, sim_parity_failures)
from repro.core import checkpoint as CK
from repro.core import memsim
from repro.core.checkpoint import CheckpointPlan, fit_candidates, get_plan
from repro.models import transformer as T

DENSE = bench_dense_config()
MOE = bench_config().replace(gmm_backend="segment")
N = 64          # 2 x 32 tokens — the tier-1 batch everywhere else


def _bwd_transients(tl):
    return [p.transient_bytes for p in tl.phases if p.name.startswith("bwd/")]


def _held_at_loss(tl):
    return next(p.held_bytes for p in tl.phases if p.name == "loss")


# ---------------------------------------------------------------------------
# Timeline structure
# ---------------------------------------------------------------------------


def test_param_bytes_matches_init_shapes():
    """The analytic per-device parameter count tracks the real init tree."""
    for cfg in (DENSE, MOE):
        shapes = jax.eval_shape(
            lambda k, c=cfg: T.init_params(k, c), jax.random.PRNGKey(0))
        real = sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(shapes))
        sim = memsim.param_bytes(cfg)
        assert abs(sim - real) / real < 0.01, (cfg.name, sim, real)
    # ep halves only the expert weights
    single = memsim.param_bytes(MOE)
    ep = memsim.param_bytes(MOE, n_model=2)
    experts = (3 * MOE.num_experts * MOE.d_model * MOE.moe_d_ff
               * 4)                                   # f32 params
    assert single - ep == experts * MOE.num_layers // 2


def test_phase_timeline_shape():
    tl = memsim.simulate(MOE, N, batch=2, plan=get_plan("paper"))
    names = [p.name for p in tl.phases]
    L = MOE.num_layers
    assert names[:L] == [f"fwd/{k}[{i}]" for i, k in
                         enumerate(memsim._layer_kinds(MOE))]
    assert names[L] == "loss"
    assert names[L + 1:] == [f"bwd/{k}[{i}]" for i, k in reversed(
        list(enumerate(memsim._layer_kinds(MOE))))]
    assert tl.peak_bytes == tl.base_bytes + max(
        p.live_bytes for p in tl.phases)
    assert tl.peak_phase in names
    # the rendered table names the peak phase and totals
    out = tl.table(limit=3)
    assert tl.peak_phase in out and f"{tl.peak_bytes:,d}" in out


def test_base_modes_nest():
    """acts < grad < train bases; the optimizer phase exists only under
    ``train``; bad base/mode raise."""
    plan = get_plan("paper")
    acts = memsim.simulate(MOE, N, batch=2, plan=plan, base="acts")
    grad = memsim.simulate(MOE, N, batch=2, plan=plan, base="grad")
    train = memsim.simulate(MOE, N, batch=2, plan=plan, base="train")
    assert acts.peak_bytes < grad.peak_bytes < train.peak_bytes
    assert acts.base_bytes == 0
    assert not any(p.name == "optimizer" for p in grad.phases)
    assert any(p.name == "optimizer" for p in train.phases)
    with pytest.raises(ValueError, match="base"):
        memsim.simulate(MOE, N, base="bogus")
    with pytest.raises(ValueError, match="mode"):
        memsim.simulate(MOE, N, mode="bogus")


def test_component_monotonicity_across_recompute_ladder():
    """Backward transient spikes shrink as plans save more (none >= paper >=
    full) while held residuals grow the other way (full >= paper >= none).
    NB the *peaks* are deliberately not monotone — measured ``full`` peaks
    above ``none`` on the bench MoE config — which is exactly why ``fit``
    must rank by the simulated timeline, not either component alone."""
    for cfg in (DENSE, MOE):
        tls = {n: memsim.simulate(cfg, N, batch=2, plan=get_plan(n),
                                  base="acts")
               for n in ("none", "paper", "full")}
        t_none, t_paper, t_full = (sum(_bwd_transients(tls[n]))
                                   for n in ("none", "paper", "full"))
        assert t_none >= t_paper >= t_full, (cfg.name, t_none, t_paper,
                                             t_full)
        h_none, h_paper, h_full = (_held_at_loss(tls[n])
                                   for n in ("none", "paper", "full"))
        assert h_full >= h_paper >= h_none, (cfg.name, h_full, h_paper,
                                             h_none)
        # plan-driven recompute totals follow the ladder and full replays
        # nothing (its custom-VJP residuals persist instead)
        assert (tls["none"].recompute_bytes > tls["paper"].recompute_bytes
                > tls["full"].recompute_bytes == 0)


def test_a2a_buffers_only_under_ep_a2a():
    """Collective (send/recv capacity) bytes appear on MoE phases under
    ``ep_a2a`` and nowhere else — and match the capacity formula."""
    plan = get_plan("paper")
    for mode in ("single", "ep"):
        tl = memsim.simulate(MOE, N, batch=2, plan=plan, mode=mode,
                             n_model=2 if mode == "ep" else 1)
        assert all(p.collective_bytes == 0 for p in tl.phases), mode
    tl = memsim.simulate(MOE, N, batch=2, plan=plan, mode="ep_a2a",
                         n_model=2)
    moe_phases = [p for p in tl.phases if "moe" in p.name]
    assert moe_phases
    rows = memsim._a2a_rows(MOE, N, 2)
    want = 3 * rows * MOE.d_model * 4                 # f32 send/recv/back
    assert all(p.collective_bytes == want for p in moe_phases)
    assert all(p.collective_bytes == 0 for p in tl.phases
               if "moe" not in p.name)
    # dense stacks never carry collective buffers, whatever the mode
    tl_d = memsim.simulate(DENSE, N, batch=2, plan=plan, mode="ep_a2a",
                           n_model=2)
    assert all(p.collective_bytes == 0 for p in tl_d.phases)


def test_chunked_a2a_peak_monotone():
    """Double-buffered chunking never raises the simulated peak when the
    capacity divides the chunk count: the full send/return buffers stay
    live but only two chunk-sized exchange buffers are in flight, so the
    chunked peak is <= the unchunked one (strictly < once chunks > 2)."""
    plan = get_plan("paper")
    un = memsim.simulate(MOE, N, batch=2, plan=plan, mode="ep_a2a",
                         n_model=2)
    rows = memsim._a2a_rows(MOE, N, 2)
    for ch in (2, 4):
        cfg = MOE.replace(moe_a2a_chunks=ch)
        tl = memsim.simulate(cfg, N, batch=2, plan=plan, mode="ep_a2a",
                             n_model=2)
        assert tl.peak_bytes <= un.peak_bytes, ch
        want = (2 * rows + 2 * (rows // ch)) * MOE.d_model * 4
        moe_phases = [p for p in tl.phases if "moe" in p.name]
        assert all(p.collective_bytes == want for p in moe_phases), ch
    four = memsim.simulate(MOE.replace(moe_a2a_chunks=4), N, batch=2,
                           plan=plan, mode="ep_a2a", n_model=2)
    assert four.peak_bytes < un.peak_bytes


def test_hier_buffers_accounted():
    """``ep_a2a_hier`` phases carry the two-hop buffer set — hop-1 rows live
    twice (send + the recv that hop 2 reads from) plus hop-2
    send/recv/return — and hop-2 capacity clamps to the hop-1 row count."""
    plan = get_plan("paper")
    r1, r2 = memsim._a2a_hier_rows(MOE, N, 2, 2)
    assert r2 <= r1 * 2                     # C2 clamped to R1 rows per dest
    tl = memsim.simulate(MOE, N, batch=2, plan=plan, mode="ep_a2a_hier",
                         n_model=2, n_node=2)
    moe_phases = [p for p in tl.phases if "moe" in p.name]
    assert moe_phases
    want = (2 * r1 + 3 * r2) * MOE.d_model * 4
    assert all(p.collective_bytes == want for p in moe_phases)
    assert all(p.collective_bytes == 0 for p in tl.phases
               if "moe" not in p.name)


def test_n_node_divides_expert_params():
    """On a node mesh the expert banks shard over n_node * n_model ways —
    the simulated param base under ep modes shrinks accordingly."""
    flat = memsim.simulate(MOE, N, batch=2, mode="ep", n_model=2)
    node = memsim.simulate(MOE, N, batch=2, mode="ep", n_model=2, n_node=2)
    assert node.base_bytes < flat.base_bytes
    # tp ignores the node tier: node ranks hold identical replicas
    tp_f = memsim.simulate(MOE, N, batch=2, mode="tp", n_model=2)
    tp_n = memsim.simulate(MOE, N, batch=2, mode="tp", n_model=2, n_node=2)
    assert tp_f.base_bytes == tp_n.base_bytes


# ---------------------------------------------------------------------------
# fit: simulator vs residual accountant
# ---------------------------------------------------------------------------


def test_fit_candidates_scoped_specs():
    specs = [p.spec() for p in fit_candidates(MOE)]
    assert "full;moe:recompute=ffn_yswi" in specs
    assert ("full;moe:recompute=ffn_a;moe:recompute=ffn_b"
            ";moe:recompute=ffn_yswi" in specs)
    dense_specs = [p.spec() for p in fit_candidates(DENSE)]
    assert not any("moe:" in s for s in dense_specs)
    assert set(CK.plan_order()) <= set(dense_specs)


def test_fit_sim_rejects_residual_accepted_plan():
    """Regression: the transient-peak case.  At this budget the residual
    accountant accepts ``paper`` (262 KB of residuals fit easily) but the
    simulator knows its backward recompute spike overshoots, and picks the
    cheaper-peak ``none`` instead, naming the responsible phase."""
    budget = 1_400_000
    res = CheckpointPlan.fit(DENSE, N, budget, batch=2, rank="residual")
    assert res.plan.spec() == "paper"
    assert res.rank == "residual" and res.timeline is None
    peak = CheckpointPlan.fit(DENSE, N, budget, batch=2, rank="peak",
                              base="grad")
    assert peak.plan.spec() == "none"
    assert peak.timeline is not None
    assert peak.timeline.peak_bytes <= budget
    chosen = next(r for r in peak.table if r.chosen)
    assert chosen.fits and chosen.peak_phase.startswith("bwd/")
    # the residual-accepted plan is in the table, marked unfit, with the
    # overshooting phase named
    paper_row = next(r for r in peak.table if r.spec == "paper")
    assert not paper_row.fits and paper_row.sim_peak_bytes > budget
    assert paper_row.peak_phase.startswith("bwd/")
    with pytest.raises(ValueError, match="rank"):
        CheckpointPlan.fit(DENSE, N, budget, rank="bogus")


def test_fit_peak_rank_budget_ladder():
    """Under train-base peak ranking the chosen plan's recompute cost is
    monotone non-increasing in budget, and >= 3 budget levels demonstrably
    select different plans (incl. the special plans the residual accountant
    cannot rank)."""
    budgets = (2_150_000, 2_240_000, 2_300_000, 2_900_000)
    fits = [CheckpointPlan.fit(DENSE, N, b, batch=2) for b in budgets]
    picks = [f.plan.spec() for f in fits]
    assert picks == ["none", "dots", "paper", "full"], picks
    recs = [f.timeline.recompute_bytes for f in fits]
    assert recs == sorted(recs, reverse=True), list(zip(budgets, recs))


def test_fit_peak_rank_prefer():
    prefer = get_plan("paper")
    fit = CheckpointPlan.fit(DENSE, N, 2_900_000, batch=2, prefer=prefer)
    assert fit.plan == prefer                   # fits -> preferred wins
    assert fit.table[0].chosen and fit.table[0].spec == "paper"
    fit2 = CheckpointPlan.fit(DENSE, N, 2_150_000, batch=2, prefer=prefer)
    assert not fit2.table[0].fits               # prefer overshoots budget
    assert fit2.plan.spec() == "none"
    assert sum(r.chosen for r in fit2.table) == 1


# ---------------------------------------------------------------------------
# The parity gate
# ---------------------------------------------------------------------------


def _sim_entry(name, value, tol=SIM_PARITY_TOLERANCE_PCT):
    return R.entry(name, value, kind="memory", unit="bytes",
                   tolerance_pct=tol)


def test_sim_parity_failures_gate():
    measured = R.entry("memory/tiny_moe/paper/segment/peak_bytes",
                       1_000_000, kind="memory", unit="bytes")
    ok = [_sim_entry("peak_sim/tiny_moe/paper/single", 1_100_000), measured]
    assert sim_parity_failures(ok) == []
    # out of tolerance (+30% > 20%)
    bad = [_sim_entry("peak_sim/tiny_moe/paper/single", 1_300_000), measured]
    fails = sim_parity_failures(bad)
    assert len(fails) == 1 and "+30.0%" in fails[0]
    # sharded modes pair with their own peak_bytes entries, not segment
    ep = [_sim_entry("peak_sim/tiny_moe/paper/ep", 900_000),
          R.entry("memory/tiny_moe/paper/ep/peak_bytes", 1_000_000,
                  kind="memory", unit="bytes")]
    assert sim_parity_failures(ep) == []
    # a missing measured counterpart is itself a failure
    orphan = [_sim_entry("peak_sim/tiny_moe/paper/ep_a2a", 900_000)]
    fails = sim_parity_failures(orphan)
    assert len(fails) == 1 and "missing" in fails[0]


def test_committed_baseline_carries_sim_entries():
    """The committed BENCH_memory.json must keep the parity-gated entry
    families (every registry plan x {single, ep, ep_a2a} on the bench MoE
    config) — the CI legs gate against exactly these names."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_memory.json")
    entries = {e["name"] for e in json.load(open(path))["entries"]}
    for plan in CK.plan_order():
        for mode in ("single", "ep", "ep_a2a"):
            assert f"peak_sim/tiny_moe/{plan}/{mode}" in entries
        assert f"peak_sim/tiny_dense/{plan}/single" in entries


# ---------------------------------------------------------------------------
# serve mode: paged KV pools + inference activations
# ---------------------------------------------------------------------------


def test_kv_page_bytes_matches_real_pool():
    """The jax-free arithmetic must price the ACTUAL paged pool pytree
    exactly, for both storage layouts."""
    from repro.serve.kv_quant import cache_bytes
    num_pages, ps = 9, 8
    for quantized in (False, True):
        pool = T.init_paged_cache(MOE, num_pages, ps, quantized=quantized)
        # cache_bytes counts every layer's pool; kv_page_bytes is the same
        # arithmetic without building arrays
        assert memsim.kv_page_bytes(MOE, num_pages, ps,
                                    quantized=quantized) \
            == cache_bytes(pool)


def test_kv_bytes_int8_vs_bf16_ratio():
    """int8 + f16 scales vs bf16 dense — the serving bench's >= 1.8x gate,
    held already at the shape-arithmetic level."""
    bf16 = memsim.kv_bytes_per_token(MOE, dtype="bfloat16")
    int8 = memsim.kv_bytes_per_token(MOE, quantized=True)
    assert bf16 / int8 >= 1.8


def test_simulate_serve_phases():
    tl = memsim.simulate_serve(MOE, batch_slots=4, num_pages=33,
                               page_size=16, prefill_tokens=128)
    assert [p.name for p in tl.phases] == ["prefill", "decode"]
    pool = memsim.kv_page_bytes(MOE, 33, 16)
    assert all(p.held_bytes == pool for p in tl.phases)
    assert tl.base_bytes == memsim.param_bytes(MOE)
    # prefill works on 128 tokens, decode on 4 — prefill transients dominate
    pre, dec = tl.phases
    assert pre.transient_bytes > dec.transient_bytes
    assert tl.peak_bytes > tl.base_bytes + pool
    # the quantized pool shrinks held bytes in both phases
    tq = memsim.simulate_serve(MOE, batch_slots=4, num_pages=33,
                               page_size=16, prefill_tokens=128,
                               quantized=True)
    assert tq.phases[0].held_bytes < tl.phases[0].held_bytes

"""Roofline cost-model tests: ``collective_stats`` HLO parsing (the
measurement half of the predicted-vs-measured loop — exercised against both
synthetic HLO text and whatever the installed jax pin actually compiles) and
the ``select_moe_parallel`` collective cost model behind ``moe_parallel=
'auto'``."""

import jax
import jax.numpy as jnp
import pytest

from repro import roofline
from repro.configs import get_config
from repro.launch.mesh import (DCN_BW, ICI_BW_PER_LINK, axis_bandwidth,
                               make_debug_mesh, make_node_mesh)

BASE = get_config("mixtral_8x7b").reduced().replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    num_experts=8, top_k=2, moe_d_ff=198, vocab_size=128, sliding_window=16,
    attn_chunk=16, moe_a2a_capacity=1.0)


# -- collective_stats: HLO text parsing --------------------------------------


def test_collective_stats_basic_kinds():
    hlo = "\n".join([
        "%ar = f32[16,64]{1,0} all-reduce(%x), replica_groups={}",
        "%a2a = bf16[4,8,32]{2,1,0} all-to-all(%y), dimensions={0}",
        "%ag = f32[128]{0} all-gather(%z), dimensions={0}",
        "%add = f32[16,64]{1,0} add(%a, %b)",          # not a collective
    ])
    s = roofline.collective_stats(hlo)
    assert s["bytes"]["all-reduce"] == 16 * 64 * 4
    assert s["bytes"]["all-to-all"] == 4 * 8 * 32 * 2
    assert s["bytes"]["all-gather"] == 128 * 4
    assert s["counts"]["all-reduce"] == 1
    assert s["counts"]["all-to-all"] == 1
    assert s["total_bytes"] == 16 * 64 * 4 + 4 * 8 * 32 * 2 + 128 * 4
    assert s["total_count"] == 3


def test_collective_stats_tuple_result_and_root():
    # Tuple-shaped results (multi-operand all-reduce) sum every element;
    # ROOT-prefixed lines must parse like any other.
    hlo = "\n".join([
        "%ar = (f32[8,4], bf16[16]) all-reduce(%a, %b), to_apply=%sum",
        "ROOT %out = u32[2,2]{1,0} all-to-all(%c)",
    ])
    s = roofline.collective_stats(hlo)
    assert s["bytes"]["all-reduce"] == 8 * 4 * 4 + 16 * 2
    assert s["bytes"]["all-to-all"] == 2 * 2 * 4
    assert s["counts"]["all-to-all"] == 1


def test_collective_stats_ignores_operand_shapes():
    # Operands are %refs without shapes in compiled HLO; a line mentioning a
    # collective by name inside a comment/metadata must not count.
    hlo = "%c = f32[4]{0} add(%a, %b), metadata={op_name=\"all-reduce\"}"
    s = roofline.collective_stats(hlo)
    assert s["total_bytes"] == 0
    assert s["total_count"] == 0


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 host devices")
def test_collective_stats_parses_compiled_hlo_this_pin():
    """The regex must keep matching whatever HLO text the *installed* jax
    pin emits (CI runs this on both pins): compile a psum and an all_to_all
    under shard_map and assert their bytes are extracted."""
    from repro.compat import shard_map
    mesh = make_debug_mesh(1, 8)

    def body(x):
        # x is the local (8, 16) shard here
        y = jax.lax.psum(x, "model")
        z = jax.lax.all_to_all(x, "model", 0, 0)
        return y, z

    x = jnp.zeros((8 * 8, 16), jnp.float32)
    from jax.sharding import PartitionSpec as P
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("model"),),
                          out_specs=(P("model"), P("model")), check=False))
    hlo = f.lower(x).compile().as_text()
    s = roofline.collective_stats(hlo)
    assert s["counts"]["all-reduce"] >= 1, hlo[:2000]
    assert s["bytes"]["all-reduce"] > 0
    assert s["counts"]["all-to-all"] >= 1
    assert s["bytes"]["all-to-all"] > 0


# -- analytic collective costs ----------------------------------------------


def test_psum_cost_ring_formula_and_bandwidth_tiers():
    L, d, it = 128, 64, 4
    b_model, t_model = roofline._psum_cost(L, d, it, (("model", 4),))
    assert b_model == int(2 * 3 / 4 * L * d * it)
    assert t_model == pytest.approx(b_model / ICI_BW_PER_LINK)
    # the 'node' axis crosses the data-center network: same bytes on a
    # same-size axis, strictly more seconds
    b_node, t_node = roofline._psum_cost(L, d, it, (("node", 4),))
    assert b_node == b_model
    assert t_node == pytest.approx(b_node / DCN_BW)
    assert t_node > t_model
    assert axis_bandwidth("node") == DCN_BW
    assert axis_bandwidth("model") == ICI_BW_PER_LINK
    # 1-way axes are free
    assert roofline._psum_cost(L, d, it, (("model", 1),)) == (0, 0.0)


def test_a2a_hop_cost():
    rows, n, d, it = 256, 4, 64, 2
    b, t = roofline._a2a_hop_cost(rows, n, d, it, "model")
    assert b == int(2 * rows * 3 / 4 * d * it)
    assert t == pytest.approx(b / ICI_BW_PER_LINK)
    assert roofline._a2a_hop_cost(rows, 1, d, it, "model") == (0, 0.0)


# -- select_moe_parallel: the auto optimizer ---------------------------------


def _modes(decision):
    return {c.mode: c for c in decision.table}


def test_auto_picks_ep_a2a_where_predicted_faster():
    # h ~ 3d with a tight capacity: the exchange's memory savings beat its
    # wire cost outright (the parallel/* bench family measures this same
    # config).
    mesh = make_debug_mesh(2, 4)
    d = roofline.select_moe_parallel(BASE, mesh, 1024)
    assert d.mode == "ep_a2a"
    assert d.source == "auto"
    row = _modes(d)
    assert row["ep_a2a"].chosen and not row["ep"].chosen
    assert row["ep_a2a"].t_total_s < row["ep"].t_total_s
    assert row["ep_a2a"].a2a_bytes > 0
    assert row["ep"].a2a_bytes == 0
    # tp is out of the ranking: 198 % 4 != 0
    assert not row["tp"].feasible


def test_auto_picks_ep_where_exchange_does_not_pay():
    # h ~ d at capacity 2: the doubled exchange buffers erase the memory
    # win and the wire cost stands alone — replicated EP is predicted
    # faster.
    cfg = BASE.replace(moe_d_ff=66, moe_a2a_capacity=2.0)
    mesh = make_debug_mesh(2, 4)
    d = roofline.select_moe_parallel(cfg, mesh, 1024)
    assert d.mode == "ep"
    row = _modes(d)
    assert row["ep"].t_total_s < row["ep_a2a"].t_total_s


def test_auto_falls_back_to_tp_on_awkward_expert_count():
    cfg = BASE.replace(num_experts=6, moe_d_ff=64)
    d = roofline.select_moe_parallel(cfg, make_debug_mesh(2, 4), 1024)
    assert d.mode == "tp"
    row = _modes(d)
    assert not row["ep"].feasible and "divisible" in row["ep"].why


def test_auto_live_bytes_tiebreak_within_slack():
    # A shape where ep and ep_a2a are within the time slack but the
    # exchange's live set is materially (> 8 MiB) smaller: memory wall
    # breaks the tie.
    cfg = BASE.replace(d_model=128, moe_d_ff=390, moe_a2a_capacity=2.0)
    mesh = make_debug_mesh(2, 4)
    d = roofline.select_moe_parallel(cfg, mesh, 2048)
    row = _modes(d)
    assert row["ep_a2a"].t_total_s <= row["ep"].t_total_s * \
        (1.0 + roofline.AUTO_TIME_SLACK)
    assert row["ep"].live_bytes - row["ep_a2a"].live_bytes \
        > roofline.AUTO_LIVE_EPS
    assert d.mode == "ep_a2a"


def test_auto_prefers_ep_on_tiny_slabs():
    # Decode/test-sized slabs: every mode is within slack and within the
    # live-bytes epsilon — the earliest ep-like mode in MOE_MODE_ORDER wins
    # unless tp is predicted faster outright.
    cfg = BASE.replace(moe_d_ff=66, moe_a2a_capacity=2.0)
    d = roofline.select_moe_parallel(cfg, make_debug_mesh(2, 4), 32)
    assert d.mode == "ep"


def test_hier_selected_on_node_mesh():
    # On a ('data','node','model') mesh with h % n_model != 0 (tp out) and
    # h ~ 6d, the two-hop exchange is predicted faster than replicated EP
    # despite its DCN hop.
    cfg = BASE.replace(moe_d_ff=389)
    mesh = make_node_mesh(2, 2, 2)
    d = roofline.select_moe_parallel(cfg, mesh, 1024)
    row = _modes(d)
    assert not row["ep_a2a"].feasible          # flat a2a refuses node meshes
    assert row["ep_a2a_hier"].feasible
    assert d.mode == "ep_a2a_hier"


def test_forced_mode_keeps_table_provenance():
    cfg = BASE.replace(moe_parallel="ep")
    d = roofline.select_moe_parallel(cfg, make_debug_mesh(2, 4), 1024)
    assert d.mode == "ep" and d.source == "config"
    row = _modes(d)
    assert row["ep"].chosen
    # JSON-ready decision table rows for the dryrun record
    rows = d.table_rows()
    assert all(isinstance(r, dict) and "t_total_s" in r and "chosen" in r
               for r in rows)
    assert sum(r["chosen"] for r in rows) == 1


def test_no_mesh_resolves_single():
    d = roofline.select_moe_parallel(BASE, None, 1024)
    assert d.mode == "single" and d.source == "single"
    assert d.table == ()


def test_chunked_model_never_slower_than_unchunked():
    mesh = make_debug_mesh(2, 4)
    for L in (256, 1024, 4096):
        un = _modes(roofline.select_moe_parallel(BASE, mesh, L))["ep_a2a"]
        ch = _modes(roofline.select_moe_parallel(
            BASE.replace(moe_a2a_chunks=4), mesh, L))["ep_a2a"]
        assert ch.t_total_s <= un.t_total_s + 1e-12

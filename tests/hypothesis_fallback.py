"""Deterministic stand-ins for the small slice of the hypothesis API the
test suite uses, so the suite still collects and runs (as fixed-example
tests) when hypothesis is not installed.

``@given`` runs the wrapped test over a fixed set of examples drawn
deterministically from the strategy specs: boundary values first, then a
seeded LCG fills the rest.  ``settings`` is a no-op decorator.  Install the
real hypothesis (``pip install -e .[test]``) to get randomized property
search + shrinking.
"""

from __future__ import annotations

_N_EXAMPLES = 5


class _Integers:
    def __init__(self, lo, hi):
        self.lo, self.hi = int(lo), int(hi)

    def examples(self, n, phase):
        vals = [self.lo, self.hi, (self.lo + self.hi) // 2]
        x = 123456789 + 7919 * (phase + 1)
        while len(vals) < n:
            x = (1103515245 * x + 12345) % (1 << 31)
            vals.append(self.lo + x % (self.hi - self.lo + 1))
        return vals[:n]


class _SampledFrom:
    def __init__(self, seq):
        self.seq = list(seq)

    def examples(self, n, phase):
        return [self.seq[(i + phase) % len(self.seq)] for i in range(n)]


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)


st = _Strategies()


def settings(*_args, **_kwargs):
    def deco(f):
        return f
    return deco


def given(*specs):
    def deco(f):
        def wrapper():
            cols = [s.examples(_N_EXAMPLES, phase=i)
                    for i, s in enumerate(specs)]
            for example in zip(*cols):
                f(*example)
        # Copy identity WITHOUT functools.wraps: wraps sets __wrapped__, and
        # pytest would then introspect f's own signature and try to resolve
        # the strategy-supplied parameters as fixtures.
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        return wrapper
    return deco

"""Fused dispatch→GEMM→combine path (the ``pallas_fused`` backend):
fwd+grad parity matrix against the unfused layer across backends × dtypes ×
residual modes, the hardened work-item contracts (non-divisible ``bh``,
empty experts, ``n_valid == 0``), the no-materialized-buffer residual
accounting, and the roofline tile selector."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import gmm_backend as GB
from repro.core.moe_layer import RESIDUAL_MODES, moe_ffn_blaze
from repro.core.routing import build_dispatch, top_k_gating
from repro.kernels.gather_gmm import (fused_moe_fwd, gather_gmm,
                                      gather_rows_pallas, gmm_dw_pallas,
                                      largest_divisor_tile, make_work_items)

AVAILABLE = GB.available_backends()
UNFUSED = [b for b in GB.backend_names() if b != "pallas_fused"]


def _param(backends):
    return [pytest.param(b, marks=() if b in AVAILABLE else
                         pytest.mark.skip(reason=f"{b} unavailable on "
                                          f"jax {jax.__version__}"))
            for b in backends]


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=5e-4, rtol=5e-4)


def _setup(seed, L, d, h, E, k, dtype=jnp.float32, biased=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (L, d), dtype)
    w1 = (jax.random.normal(ks[2], (E, d, h)) * 0.1).astype(dtype)
    w2 = (jax.random.normal(ks[3], (E, d, h)) * 0.1).astype(dtype)
    w3 = (jax.random.normal(ks[4], (E, h, d)) * 0.1).astype(dtype)
    if biased:
        # Every token picks experts {1, 2} -> all other groups are empty.
        topk = jnp.tile(jnp.array([[1, 2]], jnp.int32), (L, 1))[:, :k]
        gates = jax.nn.softmax(jax.random.normal(ks[1], (L, k)), -1)
    else:
        wg = jax.random.normal(ks[1], (d, E)).astype(jnp.float32) * 0.1
        g = top_k_gating(x.astype(jnp.float32), wg, k)
        topk, gates = g.topk_experts, g.topk_weights
    disp = build_dispatch(topk.astype(jnp.int32), E)
    return x, w1, w2, w3, gates.astype(dtype), disp


# ---------------------------------------------------------------------------
# Parity matrix: fused vs every unfused backend × dtype × residual mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("residuals", sorted(RESIDUAL_MODES))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("backend", _param(UNFUSED))
def test_fused_vs_unfused_parity(backend, dtype, residuals):
    """The fused kernel pair must be value- and gradient-exact (to dtype
    tolerance) against the unfused layer in *every* residual mode — the
    fused backward recomputes everything in-kernel, so each mode's saved
    set is satisfied a fortiori."""
    L, d, h, E, k = 64, 16, 32, 4, 2
    x, w1, w2, w3, gates, disp = _setup(3, L, d, h, E, k, dtype=dtype)

    def loss(be, res_mode):
        def f(x, w1, w2, w3, gates):
            y = moe_ffn_blaze(x, gates, disp, w1, w3, w2,
                              residuals=res_mode, backend=be)
            return (y.astype(jnp.float32) ** 2).sum()
        return f

    args = (x, w1, w2, w3, gates)
    v_f = loss("pallas_fused", residuals)(*args)
    v_u = loss(backend, residuals)(*args)
    np.testing.assert_allclose(float(v_f), float(v_u), rtol=1e-2
                               if dtype == jnp.bfloat16 else 1e-4)
    g_f = jax.grad(loss("pallas_fused", residuals),
                   argnums=(0, 1, 2, 3, 4))(*args)
    g_u = jax.grad(loss(backend, residuals), argnums=(0, 1, 2, 3, 4))(*args)
    for i, (a, b) in enumerate(zip(g_f, g_u)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **_tol(dtype),
                                   err_msg=f"grad argnum {i} vs {backend}")


def test_fused_parity_empty_experts_and_nondivisible_h():
    """The hardened contracts, through the full layer: skewed routing
    (empty experts) on an FFN width that is NOT a multiple of the 128 tile
    request (bh clamps to a divisor)."""
    L, d, h, E, k = 48, 16, 192, 8, 2
    x, w1, w2, w3, gates, disp = _setup(4, L, d, h, E, k, biased=True)
    assert (np.asarray(disp.expert_lengths) == 0).sum() >= E - 2

    def loss(be):
        def f(x, w1, w2, w3, gates):
            y = moe_ffn_blaze(x, gates, disp, w1, w3, w2, backend=be)
            return (y.astype(jnp.float32) ** 2).sum()
        return f

    args = (x, w1, w2, w3, gates)
    g_f = jax.grad(loss("pallas_fused"), argnums=(0, 1, 2, 3, 4))(*args)
    g_u = jax.grad(loss("segment"), argnums=(0, 1, 2, 3, 4))(*args)
    lens = np.asarray(disp.expert_lengths)
    for i, (a, b) in enumerate(zip(g_f, g_u)):
        assert np.isfinite(np.asarray(a, np.float32)).all(), f"argnum {i}"
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-4,
                                   err_msg=f"grad argnum {i}")
    for dw in g_f[1:3]:          # dw1/dw2 of empty experts: exact zeros
        np.testing.assert_array_equal(np.asarray(dw)[lens == 0], 0.0)


# ---------------------------------------------------------------------------
# Residual accounting: no (L·k, h) / (L·k, d) buffer survives the forward
# ---------------------------------------------------------------------------


def test_fused_saves_no_slot_buffers():
    """The fused path's saved residuals must contain NO ``(L·k, h)`` or
    ``(L·k, d)`` activation — the tentpole's whole point.  The unfused
    pallas path saves several (a, b, y_swi, and the combine input)."""
    L, d, h, E, k = 64, 16, 32, 4, 2
    x, w1, w2, w3, gates, disp = _setup(5, L, d, h, E, k)
    S = L * k

    def count_slot_avals(be):
        def f(x, w1, w2, w3, gates):
            return moe_ffn_blaze(x, gates, disp, w1, w3, w2, backend=be)
        n = 0
        for aval, src in compat.saved_residuals(f, x, w1, w2, w3, gates):
            if "from the argument" in str(src):
                continue
            if getattr(aval, "shape", None) in ((S, h), (S, d)):
                n += 1
        return n

    assert count_slot_avals("pallas_fused") == 0
    assert count_slot_avals("segment") > 0     # the unfused layer does save


# ---------------------------------------------------------------------------
# Work-item contract regressions (the satellites), on the raw kernels
# ---------------------------------------------------------------------------


def test_largest_divisor_tile():
    assert largest_divisor_tile(192, 128) == 96
    assert largest_divisor_tile(128, 128) == 128
    assert largest_divisor_tile(7, 128) == 7
    assert largest_divisor_tile(100, 64) == 50
    assert largest_divisor_tile(13, 8) == 1    # prime: degenerate but valid


def test_gather_gmm_non_divisible_h():
    """Regression: ``assert h % bh == 0`` used to crash any FFN width that
    wasn't a multiple of the 128 tile request."""
    L, d, h, E, k = 40, 16, 192, 4, 2
    x, w1, w2, w3, gates, disp = _setup(6, L, d, h, E, k)
    y = gather_gmm(x, disp.expert_token_indices, disp.expert_token_offsets,
                   w1, w2, bh=128)
    assert y.shape == (L * k, h)
    assert np.isfinite(np.asarray(y)).all()
    ref = gather_gmm(x, disp.expert_token_indices, disp.expert_token_offsets,
                     w1, w2, bh=h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_gmm_dw_pallas_zeros_empty_experts_in_kernel():
    """Regression: blocks of empty experts used to be left uninitialized
    (NaN) by the raw kernel, with only caller-side masking as a workaround.
    The efirst filler items now zero them in-kernel."""
    S, d, h = 64, 16, 24
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    lhs = jax.random.normal(ks[0], (S, d))
    dout = jax.random.normal(ks[1], (S, h))
    off = jnp.asarray([0, 30, 30, 64, 64], jnp.int32)   # experts 1, 3 empty
    dw = np.asarray(gmm_dw_pallas(lhs, dout, off))
    assert np.isfinite(dw).all()
    np.testing.assert_array_equal(dw[1], 0.0)
    np.testing.assert_array_equal(dw[3], 0.0)
    ref = np.asarray(lhs)[:30].T @ np.asarray(dout)[:30]
    np.testing.assert_allclose(dw[0], ref, atol=1e-5, rtol=1e-5)


def test_make_work_items_all_empty():
    """Regression: ``n_valid == 0`` (an ``ep_a2a`` shard whose tokens were
    all dropped) used to produce self-referential filler metadata and leave
    every output block uninitialized.  Now: one ``first`` filler per tile,
    one ``efirst`` filler per expert, all ranges empty."""
    n_tiles, E, bl = 3, 4, 32
    off = jnp.zeros((E + 1,), jnp.int32)
    tile, expert, lo, hi, first, efirst = make_work_items(off, n_tiles, bl, E)
    tile, expert, lo, hi, first, efirst = (
        np.asarray(a) for a in (tile, expert, lo, hi, first, efirst))
    assert tile.shape == (n_tiles + E,)
    np.testing.assert_array_equal(lo, 0)
    np.testing.assert_array_equal(hi, 0)
    # every tile's output block gets exactly one zero-init item ...
    assert sorted(tile[first == 1]) == list(range(n_tiles))
    # ... and every expert's dw block too
    assert sorted(expert[efirst == 1]) == list(range(E))
    # metadata stays in range (no self-referential garbage)
    assert ((tile >= 0) & (tile < n_tiles)).all()
    assert ((expert >= 0) & (expert < E)).all()


def test_kernels_all_empty_dispatch_produce_zeros():
    """The raw kernels on an all-empty dispatch: finite, exact zeros."""
    L, d, h, E = 32, 16, 24, 4
    S = L * 2
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (L, d))
    w1 = jax.random.normal(ks[1], (E, d, h)) * 0.1
    w2 = jax.random.normal(ks[2], (E, d, h)) * 0.1
    w3 = jax.random.normal(ks[3], (E, h, d)) * 0.1
    idx0 = jnp.zeros((S,), jnp.int32)
    off0 = jnp.zeros((E + 1,), jnp.int32)
    y = np.asarray(gather_gmm(x, idx0, off0, w1, w2))
    np.testing.assert_array_equal(y, 0.0)
    dw = np.asarray(gmm_dw_pallas(jnp.zeros((S, d)), jnp.zeros((S, h)), off0))
    np.testing.assert_array_equal(dw, 0.0)
    yf = np.asarray(fused_moe_fwd(x, jnp.zeros((S,)), idx0, off0, w1, w2, w3))
    np.testing.assert_array_equal(yf, 0.0)


# ---------------------------------------------------------------------------
# gather_rows (the a2a send-buffer kernel)
# ---------------------------------------------------------------------------


def test_gather_rows_pallas_and_vjp():
    from repro.kernels.ops import gather_rows
    L, d = 50, 16
    src = jax.random.normal(jax.random.PRNGKey(2), (L, d))
    ids = jnp.asarray([0, 7, -1, 49, 7, -1], jnp.int32)
    out = np.asarray(gather_rows_pallas(src, ids))
    srcn = np.asarray(src)
    np.testing.assert_allclose(out[0], srcn[0])
    np.testing.assert_allclose(out[1], srcn[7])
    np.testing.assert_array_equal(out[2], 0.0)
    np.testing.assert_array_equal(out[5], 0.0)
    # VJP: scatter-add of valid rows (row 7 appears twice -> grad doubles)
    dsrc = jax.grad(lambda s: gather_rows(s, ids).sum())(src)
    expect = np.zeros((L, d))
    for i in np.asarray(ids):
        if i >= 0:
            expect[i] += 1.0
    np.testing.assert_allclose(np.asarray(dsrc), expect)


# ---------------------------------------------------------------------------
# Roofline tile selection
# ---------------------------------------------------------------------------


def test_select_moe_tiles_properties():
    from repro.roofline import select_moe_tiles
    for n_rows, d, h, dbytes in [(256, 64, 128, 4), (8192, 2048, 5632, 2),
                                 (8192, 1024, 4096, 4), (64, 8, 16, 4)]:
        bl, bh = select_moe_tiles(n_rows, d, h, dtype_bytes=dbytes)
        assert bl % 8 == 0 and bh % 8 == 0          # TPU-tileable requests
        assert 128 <= bl <= 512 and 8 <= bh <= 512
        vmem = ((bl * d + 3 * d * bh) * dbytes + bl * d * 4
                + 3 * bl * bh * 4)
        assert vmem <= 8 * 1024 * 1024
    # bigger weights (larger d) should not select *smaller-AI* tiles than
    # the minimum request
    bl_small, bh_small = select_moe_tiles(4096, 128, 512, dtype_bytes=2)
    assert (bl_small, bh_small) >= (128, 128)
    # with num_experts on the CPU backend, bl shrinks for expert-boundary
    # fragmentation (one full tile per boundary item) but stays TPU-tileable
    bl_f, bh_f = select_moe_tiles(256, 64, 128, dtype_bytes=4, num_experts=8)
    assert bl_f % 8 == 0 and 8 <= bl_f <= 512
    assert bl_f * 8 < 2 * 256 or bl_f == 32   # waste bounded or at the floor
    # plenty of rows per expert -> no shrink below the AI-driven request
    bl_big, _ = select_moe_tiles(8192, 64, 128, dtype_bytes=4, num_experts=8)
    assert bl_big >= 128


def test_fused_never_auto_selected():
    name = GB.resolve_backend_name(None)
    assert name not in ("pallas", "pallas_fused")

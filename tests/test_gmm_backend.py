"""Backend-parity suite for the pluggable grouped-GEMM registry
(repro.core.gmm_backend): forward + VJP agreement between ``segment``,
``ragged`` (when the JAX install has it), ``pallas`` and ``pallas_fused``,
across activations and empty-expert group shapes; plus selection semantics.
(The fused layer path gets its dedicated matrix in test_fused_path.py.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmm_backend as GB
from repro.core.moe_layer import moe_ffn_blaze
from repro.core.routing import build_dispatch, top_k_gating

ALL_BACKENDS = GB.backend_names()
AVAILABLE = GB.available_backends()


def _param(backends):
    return [pytest.param(b, marks=() if b in AVAILABLE else
                         pytest.mark.skip(reason=f"{b} unavailable on "
                                          f"jax {jax.__version__}"))
            for b in backends]


def _grouped(seed, S, d, h, E, sizes=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    lhs = jax.random.normal(ks[0], (S, d), jnp.float32)
    rhs = jax.random.normal(ks[1], (E, d, h), jnp.float32) * 0.1
    dout = jax.random.normal(ks[2], (S, h), jnp.float32)
    if sizes is None:
        base = S // E
        sizes = [base] * E
        sizes[0] += S - base * E
    gs = jnp.asarray(sizes, jnp.int32)
    assert int(gs.sum()) == S
    return lhs, rhs, dout, gs


def _dense_gmm(lhs, rhs, gs):
    """O(E·S) numpy oracle."""
    off = np.concatenate([[0], np.cumsum(np.asarray(gs))])
    out = np.zeros((lhs.shape[0], rhs.shape[-1]), np.float32)
    dw = np.zeros(rhs.shape, np.float32)
    return off, out, dw


@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
@pytest.mark.parametrize("sizes", [None, (0, 20, 0, 12, 5), (37, 0, 0, 0, 0)],
                         ids=["balanced", "empty-mid", "one-expert"])
def test_gmm_forward_parity(backend, sizes):
    S, d, h, E = 37, 16, 24, 5
    lhs, rhs, dout, gs = _grouped(0, S, d, h, E, sizes)
    off, ref, refdw = _dense_gmm(lhs, rhs, gs)
    ln, rn, dn = (np.asarray(t) for t in (lhs, rhs, dout))
    for e in range(E):
        seg = slice(off[e], off[e + 1])
        ref[seg] = ln[seg] @ rn[e]
        refdw[e] = ln[seg].T @ dn[seg]
    y = GB.gmm(lhs, rhs, gs, backend=backend)
    dw = GB.gmm_dw(lhs, dout, gs, backend=backend)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), refdw, rtol=1e-4, atol=1e-5)


def _moe_setup(seed, L, d, h, E, k, biased=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (L, d), jnp.float32)
    wg = (jax.random.normal(ks[1], (d, E)) * 0.1)
    w1 = jax.random.normal(ks[2], (E, d, h)) * 0.1
    w2 = jax.random.normal(ks[3], (E, d, h)) * 0.1
    w3 = jax.random.normal(ks[4], (E, h, d)) * 0.1
    if biased:
        # Every token picks experts {1, 2} -> all other groups are empty.
        topk = jnp.tile(jnp.array([[1, 2]], jnp.int32), (L, 1))[:, :k]
        disp = build_dispatch(topk, E)
        gates = jax.nn.softmax(jax.random.normal(ks[1], (L, k)), -1)
        return x, w1, w2, w3, gates, disp
    g = top_k_gating(x, wg, k)
    disp = build_dispatch(g.topk_experts, E)
    gates = g.topk_weights.astype(x.dtype)
    return x, w1, w2, w3, gates, disp


@pytest.mark.parametrize("act", ["swiglu", "silu", "relu", "gelu"])
@pytest.mark.parametrize("backend", _param([b for b in ALL_BACKENDS
                                            if b != "segment"]))
def test_moe_vjp_parity(backend, act):
    """Forward + full VJP (dx, dw1/dw2/dw3, dgates) of moe_ffn_blaze agree
    between every backend and the portable ``segment`` reference."""
    L, d, h, E, k = 64, 16, 32, 4, 2
    x, w1, w2, w3, gates, disp = _moe_setup(3, L, d, h, E, k)
    w2_ = w2 if act == "swiglu" else None

    def loss(be):
        def f(x, w1, w2, w3, gates):
            w2a = w2 if act == "swiglu" else None
            y = moe_ffn_blaze(x, gates, disp, w1, w3, w2a, activation=act,
                              backend=be)
            return (y.astype(jnp.float32) ** 2).sum()
        return f

    args = (x, w1, w2_ if w2_ is not None else w2, w3, gates)
    v = loss(backend)(*args)
    vr = loss("segment")(*args)
    np.testing.assert_allclose(float(v), float(vr), rtol=1e-4)
    g = jax.grad(loss(backend), argnums=(0, 1, 2, 3, 4))(*args)
    gr = jax.grad(loss("segment"), argnums=(0, 1, 2, 3, 4))(*args)
    for i, (a, b) in enumerate(zip(g, gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"grad argnum {i} ({backend})")


@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
def test_moe_vjp_empty_experts(backend):
    """Extreme imbalance: most experts receive zero tokens; every backend
    must produce zero weight-grads for the empty experts and agree with the
    segment reference elsewhere."""
    L, d, h, E, k = 48, 16, 24, 8, 2
    x, w1, w2, w3, gates, disp = _moe_setup(4, L, d, h, E, k, biased=True)

    def f(be):
        def loss(x, w1, w2, w3, gates):
            y = moe_ffn_blaze(x, gates, disp, w1, w3, w2, backend=be)
            return (y.astype(jnp.float32) ** 2).sum()
        return loss

    g = jax.grad(f(backend), argnums=(1, 2, 3))(x, w1, w2, w3, gates)
    gr = jax.grad(f("segment"), argnums=(1, 2, 3))(x, w1, w2, w3, gates)
    lens = np.asarray(disp.expert_lengths)
    assert (lens == 0).sum() >= E - 2          # the routing really is skewed
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    for dw in g[:2]:                           # dw1/dw2 of empty experts == 0
        np.testing.assert_array_equal(
            np.asarray(dw)[lens == 0], 0.0)


@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
def test_gmm_dw_bf16_fp32_accumulation(backend):
    """The contract requires fp32 accumulation: a bf16 dw over an expert
    spanning many row tiles must match the fp32 reference to bf16 rounding.
    Regression: the pallas dw kernel once accumulated cross-tile partials
    in bf16 (max rel err ~9.7 on this input)."""
    S, d, h, E = 512, 64, 64, 1
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    lhs = jax.random.normal(ks[0], (S, d)).astype(jnp.bfloat16)
    dout = jax.random.normal(ks[1], (S, h)).astype(jnp.bfloat16)
    gs = jnp.array([S], jnp.int32)
    ref = np.asarray(lhs, np.float32).T @ np.asarray(dout, np.float32)
    dw = np.asarray(GB.gmm_dw(lhs, dout, gs, backend=backend), np.float32)
    rel = np.abs(dw[0] - ref).max() / np.abs(ref).max()
    assert rel < 1e-2, rel


@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
def test_plain_autodiff_through_megablocks(backend):
    """Every backend must be differentiable by *plain* autodiff (not only
    inside the MoE layer's hand-written VJP): the MegaBlocks-style baseline
    relies on it, as does ``saved_residuals`` in the paper-table benches.
    Regression: the raw pallas_call has no JVP rule and needs its custom-VJP
    wrapper in the registry."""
    from repro.core.baseline import moe_ffn_megablocks
    L, d, h, E, k = 48, 16, 24, 4, 2
    x, w1, w2, w3, gates, disp = _moe_setup(7, L, d, h, E, k)

    def loss(be):
        def f(x, w1, w2, w3):
            y = moe_ffn_megablocks(x, gates, disp, w1, w3, w2, backend=be)
            return (y.astype(jnp.float32) ** 2).sum()
        return f

    g = jax.grad(loss(backend), argnums=(0, 1, 2, 3))(x, w1, w2, w3)
    gr = jax.grad(loss("segment"), argnums=(0, 1, 2, 3))(x, w1, w2, w3)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_segment_matches_moe_dense_oracle():
    """segment-backed blaze layer vs the GShard dense-dispatch oracle —
    ties the backend registry back to the seed suite's ground truth."""
    from repro.core.baseline import moe_ffn_dense
    L, d, h, E, k = 96, 16, 24, 8, 2
    x, w1, w2, w3, gates, disp = _moe_setup(5, L, d, h, E, k)
    y = moe_ffn_blaze(x, gates, disp, w1, w3, w2, backend="segment")
    # rebuild the dense-oracle routing from the same seed / gate weights
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    wg = jax.random.normal(ks[1], (d, E)) * 0.1
    gref = top_k_gating(x, wg, k)
    yd = moe_ffn_dense(x, gref.router_probs, gref.topk_experts, gates,
                       w1, w3, w2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
@pytest.mark.parametrize("S,sizes", [
    (32, (5, 0, 7)),          # dead rows inside the first output tile
    (300, (10, 0, 0)),        # dead rows spanning whole unvisited 128-tiles
    (300, (0, 0, 0)),         # every group empty: all rows dead
], ids=["in-tile", "whole-tiles", "all-empty"])
def test_gmm_trailing_rows_are_exact_zeros(backend, S, sizes):
    """Backend contract regression: rows past the group-size total belong to
    no group and must be *exact zeros* — ``slice_dispatch``'s dead zone (the
    expert-parallel path) combines through them.  The pallas kernel used to
    leave output tiles no work item visits uninitialized (NaN), poisoning
    the EP psum whenever a dead zone spanned a full row tile."""
    d, h = 8, 16
    lhs, rhs, _, gs = _grouped(5, S, d, h, len(sizes), sizes=None)
    gs = jnp.asarray(sizes, jnp.int32)
    total = int(gs.sum())
    y = np.asarray(GB.gmm(lhs, rhs, gs, backend=backend))
    assert np.isfinite(y).all()
    np.testing.assert_array_equal(y[total:], np.zeros((S - total, h)))


@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
def test_gmm_non_divisible_h_parity(backend):
    """Regression: ``gather_gmm`` used to crash at trace time on FFN widths
    that weren't multiples of the 128 tile request (``assert h % bh == 0``);
    ``bh`` now clamps to the largest divisor.  h=192 tiles as bh=96."""
    S, d, h, E = 48, 16, 192, 4
    lhs, rhs, dout, gs = _grouped(9, S, d, h, E)
    y = GB.gmm(lhs, rhs, gs, backend=backend)
    yr = GB.gmm(lhs, rhs, gs, backend="segment")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-5)
    dw = GB.gmm_dw(lhs, dout, gs, backend=backend)
    dwr = GB.gmm_dw(lhs, dout, gs, backend="segment")
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dwr),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
def test_gmm_dw_empty_experts_cross_backend(backend):
    """Empty-expert contract regression: every backend must return *exact
    zeros* (not NaN, not masked-by-the-caller garbage) for the dw blocks of
    experts with no rows.  The pallas kernel used to leave those blocks
    uninitialized and rely on caller-side masking."""
    S, d, h = 64, 16, 24
    lhs, _, dout, _ = _grouped(11, S, d, h, 4)
    gs = jnp.asarray([30, 0, 34, 0], jnp.int32)
    dw = np.asarray(GB.gmm_dw(lhs, dout, gs, backend=backend))
    assert np.isfinite(dw).all()
    np.testing.assert_array_equal(dw[1], 0.0)
    np.testing.assert_array_equal(dw[3], 0.0)
    ref = np.asarray(lhs)[:30].T @ np.asarray(dout)[:30]
    np.testing.assert_allclose(dw[0], ref, rtol=1e-4, atol=1e-5)


# Selection semantics
# ---------------------------------------------------------------------------


def test_auto_default_resolves_to_available():
    name = GB.resolve_backend_name(None)
    assert name in AVAILABLE
    # interpret-mode kernel targets are never auto-selected
    assert name not in ("pallas", "pallas_fused")


def test_env_var_selection(monkeypatch):
    monkeypatch.setenv(GB.ENV_VAR, "segment")
    assert GB.resolve_backend_name(None) == "segment"
    assert GB.get_backend().name == "segment"
    # explicit argument beats the env var
    monkeypatch.setenv(GB.ENV_VAR, "pallas")
    assert GB.resolve_backend_name("segment") == "segment"


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown gmm backend"):
        GB.resolve_backend_name("cuda")


def test_unavailable_backend_raises():
    if "ragged" in AVAILABLE:
        pytest.skip("ragged available on this JAX; nothing to assert")
    with pytest.raises(RuntimeError, match="not available"):
        GB.resolve_backend_name("ragged")


def test_env_var_reaches_moe_layer(monkeypatch):
    """moe_ffn_blaze picks up REPRO_GMM_BACKEND at trace time."""
    monkeypatch.setenv(GB.ENV_VAR, "segment")
    L, d, h, E, k = 32, 8, 16, 4, 2
    x, w1, w2, w3, gates, disp = _moe_setup(6, L, d, h, E, k)
    y_env = moe_ffn_blaze(x, gates, disp, w1, w3, w2)
    monkeypatch.delenv(GB.ENV_VAR)
    y_exp = moe_ffn_blaze(x, gates, disp, w1, w3, w2, backend="segment")
    np.testing.assert_array_equal(np.asarray(y_env), np.asarray(y_exp))

"""Routing / dispatch-structure invariants, incl. hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is an optional test extra; fall back to fixed examples
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.core.routing import (build_dispatch, build_dispatch_sort,
                                load_balance_loss, top_k_gating)


def _random_topk(seed, L, E, k):
    scores = jax.random.normal(jax.random.PRNGKey(seed), (L, E))
    _, topk = jax.lax.top_k(scores, k)
    return topk.astype(jnp.int32)


def test_paper_figure2_example():
    """The worked example from paper §4.1 / Figure 2."""
    topk = jnp.array([[2, 3], [0, 1], [0, 3], [1, 2], [0, 3]], jnp.int32)
    d = build_dispatch(topk, 4)
    np.testing.assert_array_equal(
        d.expert_token_indices, [1, 2, 4, 1, 3, 0, 3, 0, 2, 4])
    np.testing.assert_array_equal(d.expert_token_offsets, [0, 3, 5, 7, 10])
    np.testing.assert_array_equal(
        d.token_expert_indices, [2, 3, 0, 1, 0, 3, 1, 2, 0, 3])
    np.testing.assert_array_equal(d.token_index_map[0], [5, 7])


@pytest.mark.parametrize("L,E,k", [(16, 4, 1), (64, 8, 2), (128, 16, 4),
                                   (33, 5, 3), (256, 128, 8)])
def test_sortfree_equals_sort(L, E, k):
    topk = _random_topk(L + E + k, L, E, k)
    a = build_dispatch(topk, E)
    b = build_dispatch_sort(topk, E)
    for name, (u, v) in zip(a._fields, zip(a, b)):
        np.testing.assert_array_equal(u, v, err_msg=name)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 60), st.integers(2, 16), st.integers(1, 4),
       st.integers(0, 2**31 - 1))
def test_dispatch_invariants(L, E, k, seed):
    """Property: the structures are a consistent permutation — dropless."""
    k = min(k, E)
    topk = _random_topk(seed, L, E, k)
    d = build_dispatch(topk, E)
    eti = np.asarray(d.expert_token_indices)
    off = np.asarray(d.expert_token_offsets)
    tim = np.asarray(d.token_index_map)
    lens = np.asarray(d.expert_lengths)
    # 1. offsets are exclusive prefix sums of lengths; total slots = L*k
    assert off[0] == 0 and off[-1] == L * k
    np.testing.assert_array_equal(np.diff(off), lens)
    # 2. token_index_map is a permutation of [0, L*k)
    assert sorted(tim.reshape(-1).tolist()) == list(range(L * k))
    # 3. inverse relation: eti[tim[l, i]] == l  (every slot finds its token)
    for l in range(L):
        for i in range(k):
            assert eti[tim[l, i]] == l
    # 4. expert segments contain exactly the tokens that chose that expert
    tk = np.asarray(topk)
    for e in range(E):
        seg = eti[off[e]:off[e + 1]]
        chose = sorted(np.where((tk == e).any(axis=1))[0].tolist())
        assert sorted(seg.tolist()) == chose
        # within-expert ordering is by token id (paper Fig. 2)
        assert list(seg) == sorted(seg)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_gating_topk_unique_and_normalized(E, k, seed):
    k = min(k, E)
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 16))
    wg = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, E))
    g = top_k_gating(x, wg, k)
    ids = np.asarray(g.topk_experts)
    assert ((0 <= ids) & (ids < E)).all()
    for row in ids:
        assert len(set(row.tolist())) == k          # unique experts per token
    np.testing.assert_allclose(np.asarray(g.topk_weights).sum(-1), 1.0,
                               rtol=1e-5)


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss == 1 (Switch normalization)."""
    L, E, k = 128, 8, 1
    probs = jnp.full((L, E), 1.0 / E)
    topk = (jnp.arange(L) % E).reshape(L, 1).astype(jnp.int32)
    lb = load_balance_loss(probs, topk, E)
    assert abs(float(lb) - 1.0) < 1e-5

"""Routing / dispatch-structure invariants, incl. hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is an optional test extra; fall back to fixed examples
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.core.routing import (build_dispatch, build_dispatch_sort,
                                load_balance_loss, slice_dispatch,
                                top_k_gating)


def _random_topk(seed, L, E, k):
    scores = jax.random.normal(jax.random.PRNGKey(seed), (L, E))
    _, topk = jax.lax.top_k(scores, k)
    return topk.astype(jnp.int32)


def test_paper_figure2_example():
    """The worked example from paper §4.1 / Figure 2."""
    topk = jnp.array([[2, 3], [0, 1], [0, 3], [1, 2], [0, 3]], jnp.int32)
    d = build_dispatch(topk, 4)
    np.testing.assert_array_equal(
        d.expert_token_indices, [1, 2, 4, 1, 3, 0, 3, 0, 2, 4])
    np.testing.assert_array_equal(d.expert_token_offsets, [0, 3, 5, 7, 10])
    np.testing.assert_array_equal(
        d.token_expert_indices, [2, 3, 0, 1, 0, 3, 1, 2, 0, 3])
    np.testing.assert_array_equal(d.token_index_map[0], [5, 7])


@pytest.mark.parametrize("L,E,k", [(16, 4, 1), (64, 8, 2), (128, 16, 4),
                                   (33, 5, 3), (256, 128, 8)])
def test_sortfree_equals_sort(L, E, k):
    topk = _random_topk(L + E + k, L, E, k)
    a = build_dispatch(topk, E)
    b = build_dispatch_sort(topk, E)
    for name, (u, v) in zip(a._fields, zip(a, b)):
        np.testing.assert_array_equal(u, v, err_msg=name)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 60), st.integers(2, 16), st.integers(1, 4),
       st.integers(0, 2**31 - 1))
def test_dispatch_invariants(L, E, k, seed):
    """Property: the structures are a consistent permutation — dropless."""
    k = min(k, E)
    topk = _random_topk(seed, L, E, k)
    d = build_dispatch(topk, E)
    eti = np.asarray(d.expert_token_indices)
    off = np.asarray(d.expert_token_offsets)
    tim = np.asarray(d.token_index_map)
    lens = np.asarray(d.expert_lengths)
    # 1. offsets are exclusive prefix sums of lengths; total slots = L*k
    assert off[0] == 0 and off[-1] == L * k
    np.testing.assert_array_equal(np.diff(off), lens)
    # 2. token_index_map is a permutation of [0, L*k)
    assert sorted(tim.reshape(-1).tolist()) == list(range(L * k))
    # 3. inverse relation: eti[tim[l, i]] == l  (every slot finds its token)
    for l in range(L):
        for i in range(k):
            assert eti[tim[l, i]] == l
    # 4. expert segments contain exactly the tokens that chose that expert
    tk = np.asarray(topk)
    for e in range(E):
        seg = eti[off[e]:off[e + 1]]
        chose = sorted(np.where((tk == e).any(axis=1))[0].tolist())
        assert sorted(seg.tolist()) == chose
        # within-expert ordering is by token id (paper Fig. 2)
        assert list(seg) == sorted(seg)


def test_slice_dispatch_full_range_is_identity():
    topk = _random_topk(0, 33, 8, 2)
    d = build_dispatch(topk, 8)
    f = slice_dispatch(d, 0, 8)
    np.testing.assert_array_equal(f.expert_token_indices,
                                  d.expert_token_indices)
    np.testing.assert_array_equal(f.expert_token_offsets,
                                  d.expert_token_offsets)
    np.testing.assert_array_equal(f.token_index_map, d.token_index_map)
    np.testing.assert_array_equal(f.expert_lengths, d.expert_lengths)


@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_slice_dispatch_pieces_reassemble_global(n_shards):
    """The sliced pieces are exactly the global build, re-based per shard:
    concatenating each shard's live prefix reproduces ``build_dispatch``
    output, and every slot lands either at its re-based position (local) or
    uniquely in the dead zone (non-local)."""
    L, E, k = 33, 8, 2
    topk = _random_topk(7, L, E, k)
    d = build_dispatch(topk, E)
    E_loc = E // n_shards
    tk = np.asarray(topk)
    pieces = []
    for s in range(n_shards):
        loc = slice_dispatch(d, s * E_loc, (s + 1) * E_loc)
        off = np.asarray(loc.expert_token_offsets)
        lens = np.asarray(loc.expert_lengths)
        # offsets re-based to the local range, lengths = the global slice
        assert off[0] == 0
        np.testing.assert_array_equal(np.diff(off), lens)
        np.testing.assert_array_equal(
            lens, np.asarray(d.expert_lengths)[s * E_loc:(s + 1) * E_loc])
        n_loc = off[-1]
        eti = np.asarray(loc.expert_token_indices)
        tim = np.asarray(loc.token_index_map)
        pieces.append(eti[:n_loc])
        owned = (tk // E_loc) == s
        seen = set()
        for l in range(L):
            for i in range(k):
                if owned[l, i]:
                    # local slots: live prefix, inverse relation intact
                    assert tim[l, i] < n_loc and eti[tim[l, i]] == l
                else:
                    # non-local slots: unique dead-zone positions (a grouped
                    # GEMM yields zeros there -> combine picks up exact 0)
                    assert tim[l, i] >= n_loc
                assert tim[l, i] not in seen
                seen.add(tim[l, i])
    np.testing.assert_array_equal(np.concatenate(pieces),
                                  np.asarray(d.expert_token_indices))


def test_slice_dispatch_traced_bounds_in_jit():
    """Bounds may be traced (the shard_map use) when ``count`` is given."""
    topk = _random_topk(3, 16, 4, 2)
    d = build_dispatch(topk, 4)

    def f(e_lo):
        loc = slice_dispatch(d, e_lo, e_lo + 2, count=2)
        return loc.expert_lengths, loc.expert_token_offsets

    lens, off = jax.jit(f)(jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(lens),
                                  np.asarray(d.expert_lengths[2:4]))
    assert int(off[0]) == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_gating_topk_unique_and_normalized(E, k, seed):
    k = min(k, E)
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 16))
    wg = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, E))
    g = top_k_gating(x, wg, k)
    ids = np.asarray(g.topk_experts)
    assert ((0 <= ids) & (ids < E)).all()
    for row in ids:
        assert len(set(row.tolist())) == k          # unique experts per token
    np.testing.assert_allclose(np.asarray(g.topk_weights).sum(-1), 1.0,
                               rtol=1e-5)


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss == 1 (Switch normalization)."""
    L, E, k = 128, 8, 1
    probs = jnp.full((L, E), 1.0 / E)
    topk = (jnp.arange(L) % E).reshape(L, 1).astype(jnp.int32)
    lb = load_balance_loss(probs, topk, E)
    assert abs(float(lb) - 1.0) < 1e-5

"""Pallas paged-attention kernel: parity against the dense jnp gather
reference across storage dtypes x {sliding window, logit softcap}, the
implementation registry (gmm_backend-style resolution + provenance), and
end-to-end engine parity with ``paged_kernel='pallas'``."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import paged_cache as PC
from repro.serve.engine import Request, ServeEngine

_P, _PS, _HKV, _G, _DH = 13, 8, 2, 2, 16
_TOL = {"float32": 1e-5, "bfloat16": 2e-2, "int8": 3e-2}


def _pool(rng, dtype: str) -> PC.PagedKV:
    shape = (_P, _PS, _HKV, _DH)
    if dtype == "int8":
        return PC.PagedKV(
            k=jnp.asarray(rng.integers(-127, 128, size=shape), jnp.int8),
            v=jnp.asarray(rng.integers(-127, 128, size=shape), jnp.int8),
            k_scale=jnp.asarray(rng.uniform(0.005, 0.03,
                                            size=shape[:-1] + (1,)),
                                jnp.float16),
            v_scale=jnp.asarray(rng.uniform(0.005, 0.03,
                                            size=shape[:-1] + (1,)),
                                jnp.float16))
    dt = jnp.dtype(dtype)
    return PC.PagedKV(k=jnp.asarray(rng.normal(size=shape), dt),
                      v=jnp.asarray(rng.normal(size=shape), dt),
                      k_scale=None, v_scale=None)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (9, 0.0), (0, 30.0),
                                        (7, 20.0)])
def test_pallas_matches_dense(dtype, window, cap):
    """The in-kernel page-table walk reproduces the dense gather reference
    (f32 accumulation, scale-on-scores int8 contract, masking by absolute
    position, window, softcap) on every storage dtype."""
    rng = np.random.default_rng(3)
    pages = _pool(rng, dtype)
    B, pps = 3, 4
    # Distinct physical pages per request, page 0 stays the trash page.
    table = rng.permutation(np.arange(1, _P))[:B * pps].reshape(B, pps)
    table = jnp.asarray(table, jnp.int32)
    positions = jnp.asarray([3, 17, 28], jnp.int32)   # 1, 3, 4 live pages
    qdt = jnp.float32 if dtype == "int8" else jnp.dtype(dtype)
    q = jnp.asarray(rng.normal(size=(B, 1, _HKV * _G, _DH)), qdt)

    ref = PC.paged_attention(q, pages, table, positions,
                             window=window, cap=cap, impl="dense")
    got = PC.paged_attention(q, pages, table, positions,
                             window=window, cap=cap, impl="pallas")
    assert got.shape == ref.shape and got.dtype == ref.dtype
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err <= _TOL[dtype], (dtype, window, cap, err)


def test_pallas_reads_only_live_pages():
    """Pages past a request's position are redirected to the trash page by
    the index map: scribbling garbage on a DEAD page must not change the
    output (the dense reference gathers it but masks; the kernel never even
    needs the bytes to be sane)."""
    rng = np.random.default_rng(4)
    pages = _pool(rng, "float32")
    table = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    positions = jnp.asarray([5], jnp.int32)           # only page 1 is live
    q = jnp.asarray(rng.normal(size=(1, 1, _HKV * _G, _DH)), jnp.float32)
    out = PC.paged_attention(q, pages, table, positions, impl="pallas")
    scribbled = pages._replace(
        k=pages.k.at[3].set(jnp.nan), v=pages.v.at[3].set(jnp.nan))
    out2 = PC.paged_attention(q, scribbled, table, positions, impl="pallas")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_registry_resolution_and_provenance():
    assert PC.paged_attn_names() == ["dense", "pallas"]
    assert "dense" in PC.available_paged_attn()
    r = PC.resolve_paged_attn(None)
    assert (r.name, r.source) == ("dense", "auto")
    r = PC.resolve_paged_attn("pallas")
    assert (r.name, r.source) == ("pallas", "arg")
    assert str(r) == "pallas"
    # idempotent: a ResolvedPagedAttn passes through
    assert PC.resolve_paged_attn(r) is r
    with pytest.raises(ValueError, match="unknown paged-attention impl"):
        PC.resolve_paged_attn("nope")
    old = os.environ.get(PC.PAGED_ATTN_ENV)
    os.environ[PC.PAGED_ATTN_ENV] = "pallas"
    try:
        r = PC.resolve_paged_attn(None)
        assert (r.name, r.source) == ("pallas", "env")
        # explicit argument outranks the env pin
        assert PC.resolve_paged_attn("dense").source == "arg"
    finally:
        if old is None:
            del os.environ[PC.PAGED_ATTN_ENV]
        else:
            os.environ[PC.PAGED_ATTN_ENV] = old


def test_engine_pallas_matches_dense_tokens():
    """Full engine run: the Pallas decode path produces exactly the dense
    path's tokens (greedy argmax absorbs the accumulate-order noise)."""
    cfg = get_config("yi_6b").reduced().replace(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=64, attn_chunk=16)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 64, size=L).astype(np.int32) for L in (3, 6)]

    def run(**kw):
        eng = ServeEngine(cfg, params, batch_slots=2, capacity=32,
                          page_size=8, **kw)
        reqs = [Request(prompt=p, max_new_tokens=4, eos_id=64)
                for p in prompts]
        eng.generate(reqs)
        return eng, [r.out_tokens for r in reqs]

    dense_eng, dense_toks = run()
    assert dense_eng.paged_attn.name == "dense"
    pallas_eng, pallas_toks = run(paged_kernel="pallas")
    assert pallas_eng.paged_attn.name == "pallas"
    assert pallas_toks == dense_toks
    with pytest.raises(ValueError, match="unknown paged-attention impl"):
        ServeEngine(cfg, params, paged_kernel="nope")

"""End-to-end behaviour tests for the MoEBlaze reproduction."""

import jax

from repro.configs import PAPER_CONFS, get_config
from repro.configs.base import TrainConfig
from repro.core.checkpoint import POLICIES
from repro.train.loop import train


def test_moe_training_learns_bigram_structure():
    """A small MoEBlaze model trains end to end and the loss drops."""
    cfg = get_config("mixtral_8x7b").reduced().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        num_experts=4, top_k=2, moe_d_ff=96, vocab_size=128,
        sliding_window=32, attn_chunk=32)
    tcfg = TrainConfig(total_steps=40, batch_size=4, seq_len=64,
                       learning_rate=3e-3, log_every=10)
    _, _, hist = train(cfg, tcfg, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3, hist


def test_paper_conf_registry():
    assert len(PAPER_CONFS) == 7
    c4 = PAPER_CONFS["paper_conf4"]
    assert (c4.d_model, c4.num_experts, c4.top_k) == (2048, 16, 4)
    assert c4.moe_d_ff == 4 * c4.d_model


def test_checkpoint_policy_memory_ordering():
    """More aggressive policies save fewer residual bytes:
    none <= paper_min <= paper <= full."""
    from repro.compat import saved_residual_nbytes
    from repro.core.checkpoint import FFN_A, FFN_B, FFN_YSWI, tag

    L, d, h = 256, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (L, d))
    w1 = jax.random.normal(ks[1], (d, h)) * 0.1
    w2 = jax.random.normal(ks[2], (d, h)) * 0.1

    def layer(x):
        a = tag(x @ w1, FFN_A)
        b = tag(x @ w2, FFN_B)
        y = tag(jax.nn.silu(a) * b, FFN_YSWI)
        return y @ w1.T

    sizes = {}
    for pol in ("none", "paper_min", "paper", "full"):
        f = jax.checkpoint(layer, policy=POLICIES[pol]) \
            if pol != "full" else layer
        sizes[pol] = saved_residual_nbytes(lambda x: f(x).sum(), x)
    assert sizes["none"] <= sizes["paper_min"] <= sizes["paper"] \
        <= sizes["full"]
    # In this single-layer toy, partial-eval may pick an equivalent-size
    # residual set for paper vs paper_min; the strict win shows up at MoE
    # layer level (test_memory_claim_moeblaze_vs_megablocks / benchmarks).
    assert sizes["none"] < sizes["full"]


def test_memory_claim_moeblaze_vs_megablocks():
    """Paper validation at test scale: MoEBlaze saves >=1.8x activation
    memory vs the materialized baseline on a SwiGLU MoE layer."""
    from repro.bench.paper_tables import residual_bytes
    conf = (256, 8, 2, 4, 512)          # d, E, k, B, S (scaled conf2)
    blaze = residual_bytes(conf, "blaze", "swiglu")
    mega = residual_bytes(conf, "megablocks", "swiglu")
    assert mega / blaze >= 1.8, (blaze, mega)
    silu_ratio = (residual_bytes(conf, "megablocks", "silu") /
                  residual_bytes(conf, "blaze", "silu"))
    assert silu_ratio >= 2.5, silu_ratio


def test_dispatch_sortfree_faster_than_sort():
    """The paper's headline dispatch claim, on this backend."""
    from repro.bench.paper_tables import dispatch_build_us
    conf = (512, 16, 4, 8, 1024)
    t_free = dispatch_build_us(conf, "sortfree", iters=3)
    t_sort = dispatch_build_us(conf, "sort", iters=3)
    # sort-based does strictly more passes; allow generous slack for noise
    assert t_free < t_sort * 1.2, (t_free, t_sort)

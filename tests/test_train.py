"""Training-substrate tests: optimizer math, microbatch equivalence, loss
descent, checkpoint roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import PackedBatches, PipelineConfig
from repro.models import transformer as T
from repro.train.checkpointing import restore_checkpoint, save_checkpoint
from repro.train.loop import make_train_step, train
from repro.train.optimizer import (adamw_update, clip_by_global_norm,
                                   cosine_schedule, global_norm, init_adamw)

CFG = get_config("yi_6b").reduced().replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=128)


def _batch(B=4, S=32, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              CFG.vocab_size)
    return {"tokens": toks, "labels": toks}


def test_adamw_matches_reference_step():
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = init_adamw(p)
    newp, st2 = adamw_update(g, st, p, lr=0.1, b1=0.9, b2=0.999,
                             eps=1e-8, weight_decay=0.0)
    # bias-corrected first step: delta == lr * sign-ish formula
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.001 * np.array([0.1, 0.2, -0.3]) ** 2
    mhat, vhat = m / 0.1, v / 0.001
    ref = np.array([1.0, -2.0, 3.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), ref, rtol=1e-5)
    assert int(st2.step) == 1


def test_grad_clipping():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), np.sqrt(90.0), rtol=1e-5)


def test_cosine_schedule_shape():
    lr0 = cosine_schedule(jnp.array(0), peak_lr=1e-3, warmup=10, total=100)
    lr_w = cosine_schedule(jnp.array(10), peak_lr=1e-3, warmup=10, total=100)
    lr_end = cosine_schedule(jnp.array(100), peak_lr=1e-3, warmup=10,
                             total=100)
    assert float(lr0) == 0.0
    np.testing.assert_allclose(float(lr_w), 1e-3, rtol=1e-5)
    np.testing.assert_allclose(float(lr_end), 1e-4, rtol=1e-3)


def test_microbatch_invariance():
    """Gradient accumulation is invariant in the microbatch count: on one
    fixed batch, ``num_microbatches`` in {1, 2, 4} produce the same loss,
    grad norm, and updated params (guards the f32 accumulation path in
    ``train/loop.py``)."""
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    batch = _batch(B=8)
    outs = {}
    for M in (1, 2, 4):
        tcfg = TrainConfig(num_microbatches=M, learning_rate=1e-3)
        step = jax.jit(make_train_step(CFG, tcfg))
        p2, _, metrics = step(params, init_adamw(params), batch)
        outs[M] = (p2, metrics)
    for M in (2, 4):
        # CE/loss means over microbatches of equal size == full-batch mean
        for key in ("ce", "loss", "grad_norm"):
            np.testing.assert_allclose(
                float(outs[1][1][key]), float(outs[M][1][key]), rtol=1e-4,
                err_msg=f"M={M} metric={key}")
        for a, b in zip(jax.tree.leaves(outs[1][0]),
                        jax.tree.leaves(outs[M][0])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, err_msg=f"M={M}")


def test_loss_decreases_end_to_end():
    tcfg = TrainConfig(total_steps=25, batch_size=4, seq_len=64,
                       learning_rate=2e-3, log_every=5)
    _, _, hist = train(CFG, tcfg, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_roundtrip(tmp_path):
    params = T.init_params(jax.random.PRNGKey(1), CFG)
    opt = init_adamw(params)
    save_checkpoint(str(tmp_path / "ck"), 7, params, opt)
    step, p2, o2 = restore_checkpoint(str(tmp_path / "ck"), params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), b)
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_step_hook_reports_backend_and_context_flips_one_step():
    """``step_hook`` metrics carry the step's resolved grouped-GEMM backend,
    and entering a ``use_backend("segment")`` scope between steps flips
    exactly the next step — with loss parity against the uninterrupted auto
    run (backends are numerically interchangeable)."""
    from repro.core import gmm_backend as GB
    moe_cfg = get_config("qwen3_moe_30b_a3b").reduced().replace(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        num_experts=4, top_k=2, moe_d_ff=64, vocab_size=64, dtype="float32")
    auto = GB.resolve(None).name
    tcfg = TrainConfig(total_steps=3, batch_size=2, seq_len=16,
                       learning_rate=1e-3, log_every=1)

    # Reference: plain auto run (same seed -> identical batches).
    _, _, hist_ref = train(moe_cfg, tcfg, log=lambda *_: None)
    assert [h["gmm_backend"] for h in hist_ref] == [auto] * 3

    # Flip step 1 only, via a scope entered/exited inside the step hook.
    scope = GB.use_backend("segment")
    seen = []

    def hook(step, metrics):
        seen.append(metrics["gmm_backend"])
        assert metrics["step_s"] > 0
        if step == 0:
            scope.__enter__()
        elif step == 1:
            scope.__exit__(None, None, None)

    _, _, hist = train(moe_cfg, tcfg, log=lambda *_: None, step_hook=hook)
    assert seen == [auto, "segment", auto]
    for h_ref, h in zip(hist_ref, hist):
        np.testing.assert_allclose(h_ref["loss"], h["loss"], rtol=1e-4,
                                   err_msg=f"step {h['step']}")


def test_data_pipeline_deterministic_and_packed():
    pc = PipelineConfig(vocab_size=64, seq_len=32, batch_size=2, seed=3)
    it1, it2 = iter(PackedBatches(pc)), iter(PackedBatches(pc))
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 32)
    assert b1["tokens"].max() < 64

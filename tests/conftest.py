import os
import sys

# Tests must see 1 device by default (the dry-run sets its own flags in a
# separate process).  A handful of sharding tests ask for 8 host devices via
# the submodule below, so set it once here before jax initializes — 8 devices
# is small enough that single-device tests are unaffected semantically.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

"""Async serving runtime: pipelined scheduling must be TOKEN-IDENTICAL to
the synchronous engine under a fixed seed (greedy and sampled), streaming
callbacks fire in order with exactly one terminal event, queue/buffer
plumbing is bounded and instrumented, and a pipeline crash surfaces as an
``"error"`` terminal event on every in-flight request."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.serve.runtime import (AsyncServeRuntime, TransferBufferPool,
                                 WorkQueue)

CFG = get_config("yi_6b").reduced().replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=64, attn_chunk=16)


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _prompts(lens=(1, 4, 7, 3, 9, 2)):
    rng = np.random.default_rng(0)
    return [rng.integers(1, CFG.vocab_size, size=L).astype(np.int32)
            for L in lens]


def _reqs(prompts, max_new=5, **kw):
    return [Request(prompt=p, max_new_tokens=max_new, eos_id=CFG.vocab_size,
                    **kw) for p in prompts]


def _engine(params, **kw):
    return ServeEngine(CFG, params, batch_slots=2, capacity=32, page_size=8,
                       **kw)


# ---------------------------------------------------------------------------
# plumbing
# ---------------------------------------------------------------------------


def test_workqueue_bounded_and_counted():
    q = WorkQueue("t", maxsize=2)
    q.put(1)
    q.put(2)
    assert q.stats["puts"] == 2 and q.stats["max_depth"] == 2
    assert q.get() == 1 and q.get() == 2
    assert q.get() is None                  # empty: non-blocking None
    assert q.get(timeout=0.01) is None      # empty: timeout None
    assert q.stats["gets"] == 2


def test_transfer_buffer_pool_bounds_staging():
    pool = TransferBufferPool(2, capacity=16)
    a = pool.acquire()
    a.stage(np.arange(5, dtype=np.int32))
    assert a.used == 5 and a.arr[4] == 4
    b = pool.acquire()
    assert pool.stats == {"acquires": 2, "acquire_waits": 0}
    pool.release(a)
    c = pool.acquire()                      # recycled, no new allocation
    assert c is a
    pool.release(b)
    pool.release(c)
    with pytest.raises(ValueError):
        AsyncServeRuntime(object.__new__(ServeEngine), transfer_buffers=0)


# ---------------------------------------------------------------------------
# parity: the pipeline gate
# ---------------------------------------------------------------------------


def test_async_matches_sync_greedy(params):
    sync = _engine(params)
    ref = [r.out_tokens for r in sync.generate(_reqs(_prompts()))]
    eng = _engine(params)
    with AsyncServeRuntime(eng, queue_depth=2, transfer_buffers=2) as rt:
        out = rt.run(_reqs(_prompts()))
    assert [r.out_tokens for r in out] == ref
    assert all(r.finish_reason == "length" for r in out)
    # the pipeline served through the queues it claims to
    assert rt.emit_q.stats["gets"] == rt.emit_q.stats["puts"] > 0


def test_async_matches_sync_sampled(params):
    """Sampling keys are per-(request, token index), so scheduler lag can
    not change sampled tokens either."""
    kw = dict(greedy=False, temperature=0.8, seed=11)
    sync = ServeEngine(CFG, params, batch_slots=2, capacity=32, page_size=8,
                       **kw)
    ref = [r.out_tokens for r in sync.generate(_reqs(_prompts(), max_new=6))]
    eng = ServeEngine(CFG, params, batch_slots=2, capacity=32, page_size=8,
                      **kw)
    with AsyncServeRuntime(eng) as rt:
        out = rt.run(_reqs(_prompts(), max_new=6))
    assert [r.out_tokens for r in out] == ref


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


def test_streaming_order_and_terminal_event(params):
    events = []
    reqs = _reqs(_prompts((4, 7)), max_new=4,
                 on_token=None, on_finish=None)
    for i, r in enumerate(reqs):
        r.on_token = lambda t, i=i: events.append(("tok", i, t))
        r.on_finish = lambda why, i=i: events.append(("fin", i, why))
    eng = _engine(params)
    with AsyncServeRuntime(eng) as rt:
        rt.run(reqs)
    for i, r in enumerate(reqs):
        mine = [e for e in events if e[1] == i]
        # every token callback in emission order, then EXACTLY one terminal
        assert mine == ([("tok", i, t) for t in r.out_tokens]
                        + [("fin", i, "length")])


def test_stream_iterator_and_eos(params):
    eng = _engine(params)
    # learn the first greedy token, then make it the EOS of a second run
    probe = eng.generate(_reqs(_prompts((4,)), max_new=3))[0]
    first = probe.out_tokens[0]
    r = Request(prompt=_prompts((4,))[0], max_new_tokens=5, eos_id=first)
    eng2 = _engine(params)
    with AsyncServeRuntime(eng2) as rt:
        it = rt.stream(r)
        seen = []
        try:
            while True:
                seen.append(next(it))
        except StopIteration as stop:
            reason = stop.value
    assert seen == r.out_tokens == [first]
    assert reason == "eos" and r.finish_reason == "eos"


def test_stream_timeout_raises_timeout_error():
    """A stalled pipeline must surface as TimeoutError (or the pipeline's
    own error), never a raw ``queue.Empty`` leaking from the event queue."""
    from repro.serve.runtime import RequestHandle

    class _Idle:
        def _check_error(self):
            pass

    h = RequestHandle(_reqs(_prompts((2,)))[0], _Idle())
    with pytest.raises(TimeoutError, match="no token or terminal event"):
        next(h.stream(timeout=0.01))

    class _Dead:
        def _check_error(self):
            raise RuntimeError("serving pipeline failed")

    h2 = RequestHandle(_reqs(_prompts((2,)))[0], _Dead())
    with pytest.raises(RuntimeError, match="serving pipeline failed"):
        next(h2.stream(timeout=0.01))


# ---------------------------------------------------------------------------
# failure path
# ---------------------------------------------------------------------------


def test_pipeline_error_surfaces_as_terminal_event(params):
    eng = _engine(params)

    def boom(*a, **k):
        raise RuntimeError("injected device failure")

    # pre-seed the decode jit cache with a failing step: prefill succeeds,
    # the first decode dispatch kills the device thread
    eng._decode_fns[eng.backend.name] = boom
    reqs = _reqs(_prompts((4, 7)), max_new=4)
    rt = AsyncServeRuntime(eng)
    handles = [rt.submit(r) for r in reqs]
    with pytest.raises(RuntimeError, match="serving pipeline failed"):
        for h in handles:
            h.result(timeout=60.0)
    assert all(r.done and r.finish_reason == "error" for r in reqs)
    with pytest.raises(RuntimeError, match="serving pipeline failed"):
        rt.close()
    # a dead runtime refuses new work rather than hanging it
    with pytest.raises(RuntimeError):
        rt.submit(_reqs(_prompts((3,)))[0])

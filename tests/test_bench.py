"""``repro.bench`` harness tests: record schema round-trip, the ``--check``
regression gate (a synthetic regressed record must fail), and a smoke run of
the memory accountant under both the ``segment`` and auto-resolved
grouped-GEMM backends."""

import json

import pytest

from repro.bench import record as R
from repro.bench.cli import main as bench_main
from repro.core import gmm_backend as GB


def _toy_record(**overrides):
    entries = [
        R.entry("toy/a/bytes", 1000.0, kind="residual_bytes", unit="bytes",
                tolerance_pct=20.0, batch=2),
        R.entry("toy/a/time", 123.4, kind="time_us", unit="us"),
        R.entry("toy/b/bytes", 500.0, kind="temp_bytes", unit="bytes",
                tolerance_pct=100.0),
    ]
    rec = R.make_record("kernels", entries, config={"small": True})
    rec.update(overrides)
    return rec


def test_record_roundtrip(tmp_path):
    rec = _toy_record()
    path = R.write_record(rec, str(tmp_path / "r.json"))
    back = R.load_record(path)
    assert back == json.loads(json.dumps(rec))     # JSON-stable
    assert back["schema_version"] == R.SCHEMA_VERSION
    assert back["suite"] == "kernels"
    for key in ("git_sha", "jax_version", "backend"):
        assert key in back["provenance"], key
    assert [e["name"] for e in back["entries"]] == [
        "toy/a/bytes", "toy/a/time", "toy/b/bytes"]


def test_record_rejects_duplicates_and_bad_schema(tmp_path):
    with pytest.raises(ValueError, match="duplicate"):
        R.make_record("kernels", [R.entry("x", 1, kind="k"),
                                  R.entry("x", 2, kind="k")])
    rec = _toy_record(schema_version=99)
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        json.dump(rec, f)
    with pytest.raises(ValueError, match="schema_version"):
        R.load_record(path)


def test_regression_gate_semantics():
    base = _toy_record()
    # identical record: clean
    ok, _ = R.check_records(_toy_record(), base)
    assert ok
    # +50% on a 20%-tolerance entry: regression
    bad = _toy_record()
    bad["entries"][0] = dict(bad["entries"][0], value=1500.0)
    ok, lines = R.check_records(bad, base)
    assert not ok
    assert any("REGRESSION toy/a/bytes" in ln for ln in lines)
    # +50% on the 100%-tolerance entry: allowed
    loose = _toy_record()
    loose["entries"][2] = dict(loose["entries"][2], value=750.0)
    ok, _ = R.check_records(loose, base)
    assert ok
    # wall-clock entries are never gated, even at 100x
    noisy = _toy_record()
    noisy["entries"][1] = dict(noisy["entries"][1], value=12340.0)
    ok, _ = R.check_records(noisy, base)
    assert ok
    # a gated entry disappearing from the current record fails the gate
    missing = _toy_record()
    missing["entries"] = missing["entries"][1:]
    ok, lines = R.check_records(missing, base)
    assert not ok
    assert any("missing" in ln for ln in lines)
    # improvements are fine
    better = _toy_record()
    better["entries"][0] = dict(better["entries"][0], value=100.0)
    ok, _ = R.check_records(better, base)
    assert ok
    # sweep-size mismatch is rejected, not silently compared
    mismatch = _toy_record(config={"small": False})
    ok, lines = R.check_records(mismatch, base)
    assert not ok
    assert any("config mismatch" in ln for ln in lines)


def test_cli_check_exit_codes(tmp_path):
    """`python -m repro.bench --check` exits nonzero when fed a record with a
    >20% regression, zero on a clean one (the acceptance gate)."""
    base_path = R.write_record(_toy_record(), str(tmp_path / "base.json"))
    bad = _toy_record()
    bad["entries"][0] = dict(bad["entries"][0], value=1300.0)   # +30% > 20%
    bad_path = R.write_record(bad, str(tmp_path / "bad.json"))

    assert bench_main(["--check", "--record", base_path,
                       "--baseline", base_path]) == 0
    assert bench_main(["--check", "--record", bad_path,
                       "--baseline", base_path]) == 1
    # missing baseline is a failure, not a silent pass
    assert bench_main(["--check", "--record", bad_path,
                       "--baseline-dir", str(tmp_path)]) == 1


def test_memory_accountant_smoke_segment_and_auto():
    """The activation-memory accountant runs on the tiny config under both
    the portable `segment` backend and whatever auto resolves to, and its
    three accountants agree on basic sanity."""
    from repro.bench.memory import activation_memory_report, bench_config
    cfg = bench_config()
    backends = list(dict.fromkeys(["segment", GB.resolve_backend_name(None)]))
    residuals = {}
    for backend in backends:
        r = activation_memory_report(cfg, "paper", backend=backend)
        assert r["backend"] == backend
        assert r["temp_bytes"] > 0 and r["peak_bytes"] > r["temp_bytes"]
        assert r["residual_bytes"] > 0
        assert r["est_saved_bytes"] is not None and r["est_saved_bytes"] > 0
        residuals[backend] = r["residual_bytes"]
    # autodiff's residual set is a property of the math, not the backend
    assert len(set(residuals.values())) == 1, residuals


def test_ep_residual_entries_dispatch_strictly_below_dense():
    """The tracked expert-parallel pair: the Dispatch-driven EP path must
    save strictly fewer activation-residual bytes than the dense-EP
    formulation it replaced, measured in the same run."""
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 host devices")
    from repro.bench.memory import ep_saved_residual_entries
    entries = ep_saved_residual_entries(small=True)
    vals = {e["name"]: e["value"] for e in entries}
    dense = vals["memory/tiny_moe_ep/ep_dense/residual_bytes"]
    disp = vals["memory/tiny_moe_ep/ep_dispatch/residual_bytes"]
    assert 0 < disp < dense, vals


def test_median_time_us_protocol():
    import jax.numpy as jnp

    from repro.bench.timing import median_time_us
    us = median_time_us(lambda x: x * 2, jnp.ones((8,)), warmup=1, iters=3)
    assert us > 0

"""Per-kernel allclose sweeps against the ref.py pure-jnp oracles
(interpret mode), over shapes and dtypes, plus hypothesis property tests for
the Pallas dispatch builder."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is an optional test extra; fall back to fixed examples
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.core.routing import build_dispatch
from repro.kernels import ref
from repro.kernels.combine import combine
from repro.kernels.dispatch import build_dispatch_pallas
from repro.kernels.fused_swiglu import (fused_swiglu_bwd_w, fused_swiglu_bwd_x,
                                        fused_swiglu_fwd)
from repro.kernels.gather_gmm import gather_gmm


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,d,h", [(128, 128, 128), (256, 64, 384),
                                   (384, 256, 128)])
def test_fused_swiglu_fwd_sweep(L, d, h, dtype):
    ks = jax.random.split(jax.random.PRNGKey(L + d + h), 3)
    x = jax.random.normal(ks[0], (L, d), dtype)
    w1 = (jax.random.normal(ks[1], (d, h)) * 0.05).astype(dtype)
    w2 = (jax.random.normal(ks[2], (d, h)) * 0.05).astype(dtype)
    y, a, b = fused_swiglu_fwd(x, w1, w2, bl=128, bh=128, bk=64)
    yr, ar, br = ref.fused_swiglu_fwd_ref(x, w1, w2)
    for u, v in ((y, yr), (a, ar), (b, br)):
        np.testing.assert_allclose(np.asarray(u, np.float32),
                                   np.asarray(v, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_swiglu_bwd_sweep(dtype):
    L, d, h = 256, 128, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (L, d), dtype)
    w1 = (jax.random.normal(ks[1], (d, h)) * 0.05).astype(dtype)
    w2 = (jax.random.normal(ks[2], (d, h)) * 0.05).astype(dtype)
    _, a, b = fused_swiglu_fwd(x, w1, w2)
    dy = jax.random.normal(ks[3], (L, h), dtype)
    dx = fused_swiglu_bwd_x(dy, a, b, w1, w2)
    np.testing.assert_allclose(
        np.asarray(dx, np.float32),
        np.asarray(ref.fused_swiglu_bwd_x_ref(dy, a, b, w1, w2), np.float32),
        **_tol(dtype))
    dw1, dw2 = fused_swiglu_bwd_w(x, dy, a, b)
    dw1r, dw2r = ref.fused_swiglu_bwd_w_ref(x, dy, a, b)
    np.testing.assert_allclose(np.asarray(dw1, np.float32),
                               np.asarray(dw1r, np.float32),
                               atol=0.3 if dtype == jnp.bfloat16 else 1e-3,
                               rtol=5e-2)
    np.testing.assert_allclose(np.asarray(dw2, np.float32),
                               np.asarray(dw2r, np.float32),
                               atol=0.3 if dtype == jnp.bfloat16 else 1e-3,
                               rtol=5e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,d,h,E,k,bl", [
    (200, 64, 128, 8, 2, 64), (128, 128, 128, 4, 1, 128),
    (97, 64, 128, 16, 4, 32),
])
def test_gather_gmm_sweep(L, d, h, E, k, bl, dtype):
    ks = jax.random.split(jax.random.PRNGKey(L + E), 4)
    x = jax.random.normal(ks[0], (L, d), dtype)
    w1 = (jax.random.normal(ks[1], (E, d, h)) * 0.05).astype(dtype)
    w2 = (jax.random.normal(ks[2], (E, d, h)) * 0.05).astype(dtype)
    scores = jax.random.normal(ks[3], (L, E))
    _, topk = jax.lax.top_k(scores, k)
    disp = build_dispatch(topk.astype(jnp.int32), E)
    y, a, b = gather_gmm(x, disp.expert_token_indices,
                         disp.expert_token_offsets, w1, w2,
                         save_ab=True, bl=bl)
    yr, ar, br = ref.gather_gmm_ref(x, disp.expert_token_indices,
                                    disp.expert_token_offsets, w1, w2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(ar, np.float32), **_tol(dtype))
    # single-GEMM (no epilogue) mode
    y1 = gather_gmm(x, disp.expert_token_indices, disp.expert_token_offsets,
                    w1, epilogue=False, bl=bl)
    np.testing.assert_allclose(
        np.asarray(y1, np.float32),
        np.asarray(ref.gather_gmm_ref(x, disp.expert_token_indices,
                                      disp.expert_token_offsets, w1),
                   np.float32), **_tol(dtype))


@pytest.mark.parametrize("L,k,d,bl", [(100, 2, 64, 64), (256, 4, 128, 128),
                                      (64, 1, 32, 32)])
def test_combine_sweep(L, k, d, bl):
    E = 8
    ks = jax.random.split(jax.random.PRNGKey(L * k), 3)
    scores = jax.random.normal(ks[0], (L, E))
    _, topk = jax.lax.top_k(scores, k)
    disp = build_dispatch(topk.astype(jnp.int32), E)
    p = jax.random.normal(ks[1], (L * k, d))
    gates = jax.random.uniform(ks[2], (L, k))
    y = combine(p, disp.token_index_map, gates, bl=bl, bd=min(d, 64))
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.combine_ref(p, disp.token_index_map,
                                                  gates)), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 100), st.integers(2, 16), st.integers(1, 4),
       st.integers(0, 2**31 - 1), st.sampled_from([32, 64, 256]))
def test_dispatch_pallas_property(L, E, k, seed, bl):
    """Pallas builder == XLA sort-free builder for arbitrary shapes."""
    k = min(k, E)
    scores = jax.random.normal(jax.random.PRNGKey(seed), (L, E))
    _, topk = jax.lax.top_k(scores, k)
    topk = topk.astype(jnp.int32)
    a = build_dispatch_pallas(topk, E, bl=bl)
    b = build_dispatch(topk, E)
    for name, (u, v) in zip(a._fields, zip(a, b)):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                      err_msg=name)


def test_full_pallas_moe_layer_grads():
    from repro.core.moe_layer import moe_ffn_blaze
    from repro.kernels.ops import moe_ffn_blaze_pallas
    L, d, h, E, k = 128, 64, 128, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (L, d))
    w1 = jax.random.normal(ks[1], (E, d, h)) * 0.05
    w2 = jax.random.normal(ks[2], (E, d, h)) * 0.05
    w3 = jax.random.normal(ks[3], (E, h, d)) * 0.05
    scores = jax.random.normal(ks[4], (L, E))
    _, topk = jax.lax.top_k(scores, k)
    disp = build_dispatch(topk.astype(jnp.int32), E)
    gates = jax.nn.softmax(scores, -1)
    gates = jnp.take_along_axis(gates, topk, 1)
    gates = gates / gates.sum(-1, keepdims=True)

    def f_pal(*a):
        return moe_ffn_blaze_pallas(a[0], gates, disp, a[1], a[3], a[2]).sum()

    def f_ref(*a):
        return moe_ffn_blaze(a[0], gates, disp, a[1], a[3], a[2]).sum()

    gp = jax.grad(f_pal, argnums=(0, 1, 2, 3))(x, w1, w2, w3)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, w1, w2, w3)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)

"""CheckpointPlan API verification (the first-class redesign of the
activation-checkpoint surface, paper §5.2 / Algorithm 1).

Covers the acceptance axes:
  * spec parser round-trips (parse -> render -> parse identity) and bad
    specs raise;
  * plan-driven named policies are *equivalent to the legacy string path*:
    gradient parity on dense + MoE stacks and byte-identical saved
    residuals between a name and its explicit spec;
  * scoped (per-block-kind) decisions work: the MoE custom-VJP residual
    modes preserve gradients while strictly shrinking residual bytes, and a
    cross-kind conflict engages per-sublayer remat with unchanged gradients;
  * ``CheckpointPlan.fit`` is budget-monotone and demonstrably changes the
    selected plan across budget levels, and the selection reaches
    ``make_train_step``/``step_hook``.
"""

import jax
import numpy as np
import pytest

from repro.bench.memory import (bench_config, bench_dense_config,
                                residual_bytes)
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import checkpoint as CK
from repro.core.checkpoint import (CheckpointPlan, FFN_A, MOE_GATES,
                                   SSM_STATE, get_plan, parse_plan,
                                   parse_size, resolve_plan)
from repro.models import transformer as T
from repro.train.loop import make_train_step, train

DENSE = bench_dense_config()
MOE = bench_config().replace(gmm_backend="segment")

PAPER_SPEC = "save=ffn_a,ffn_b,ffn_yswi,attn_out,qkv"
PAPER_MIN_SPEC = "save=ffn_a,ffn_b,attn_out,qkv"


def _grads(cfg, seed=0):
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    loss = lambda p: T.train_loss(p, batch, cfg)[0]
    return jax.jit(jax.grad(loss))(params)


def _assert_tree_close(a, b, atol, ctx):
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   atol=atol, err_msg=ctx)


# ---------------------------------------------------------------------------
# Spec parser
# ---------------------------------------------------------------------------


def test_spec_roundtrip_identity():
    for spec in (
        "save=ffn_a,ffn_b,qkv",
        "save=ffn_a,ffn_b,qkv;moe:recompute=ffn_yswi",
        "save=qkv,attn_out;attn_local_ffn:recompute=qkv",
        "moe:recompute=ffn_a,ffn_b",
        "save=",
        "paper;moe:recompute=ffn_yswi",
        "full;moe:recompute=ffn_a,ffn_b",
        "save=ssm_state;ssm:recompute=ssm_state",
    ):
        p1 = parse_plan(spec)
        p2 = parse_plan(p1.spec())
        assert p1 == p2, (spec, p1.spec())


def test_registry_names_roundtrip():
    for name in CK.PLAN_REGISTRY:
        p = parse_plan(name)
        assert p.spec() == name
        assert parse_plan(p.spec()) is p


def test_spec_normalization():
    # tag order is canonicalized, duplicates collapse, later unscoped
    # recompute removes from the save set
    a = parse_plan("save=qkv,ffn_a,ffn_a")
    b = parse_plan("save=ffn_a;save=qkv")
    assert a == b
    assert parse_plan("save=ffn_a,qkv;recompute=qkv") == \
        parse_plan("save=ffn_a")


def test_repeated_override_keeps_last_wins_semantics():
    """Dedupe of identical override triples must keep the LAST occurrence —
    dropping a repeated final directive would resurrect an intervening
    opposite decision."""
    p = parse_plan("moe:save=ffn_yswi;moe:recompute=ffn_yswi;"
                   "moe:save=ffn_yswi")
    assert p.override_for("ffn_yswi", CK.MOE_SCOPE_KINDS) == CK.SAVE
    assert CK.moe_residual_mode(MOE.replace(
        save_yswi=False, remat_policy=p.spec())) == "ab_yswi"
    assert parse_plan(p.spec()) == p


def test_bad_specs_raise():
    for bad in (
        "bogus",                            # not a name, not a spec
        "save=bogus_tag",                   # unknown tag
        "bogus_scope:save=qkv",             # unknown scope
        "zzz*:save=qkv",                    # glob matching no kind
        "moe:keep=qkv",                     # unknown directive
        "paper;save=qkv;full",              # special + default save set
        123,                                # not a string
    ):
        with pytest.raises((ValueError, TypeError)):
            get_plan(bad)


def test_scope_matching():
    assert CK.scope_matches("moe", "attn_moe")
    assert CK.scope_matches("moe", "attn_local_moe")
    assert not CK.scope_matches("moe", "attn_ffn")
    assert CK.scope_matches("*moe", "attn_moe")
    assert CK.scope_matches("ssm", "hymba")
    assert CK.scope_matches("attn_ffn", "attn_ffn")


def test_parse_size():
    assert parse_size("2GiB") == 2 * 2**30
    assert parse_size("1.5MiB") == int(1.5 * 2**20)
    assert parse_size("1000") == 1000
    assert parse_size(4096) == 4096
    with pytest.raises(ValueError):
        parse_size("2 buckets")


# ---------------------------------------------------------------------------
# Plan-vs-legacy equivalence
# ---------------------------------------------------------------------------


def test_plan_spec_equals_named_policy_dense():
    """The explicit spec of 'paper'/'paper_min' and the registry name
    produce identical gradients AND byte-identical saved residuals."""
    base = _grads(DENSE.replace(remat_policy="full"))
    for name, spec in (("paper", PAPER_SPEC), ("paper_min", PAPER_MIN_SPEC)):
        _assert_tree_close(base, _grads(DENSE.replace(remat_policy=name)),
                           1e-5, name)
        _assert_tree_close(base, _grads(DENSE.replace(remat_policy=spec)),
                           1e-5, spec)
        assert residual_bytes(DENSE, name) == residual_bytes(DENSE, spec), \
            (name, spec)


def test_plan_spec_equals_named_policy_moe():
    base = _grads(MOE.replace(remat_policy="full"))
    for name, spec in (("paper", PAPER_SPEC), ("paper_min", PAPER_MIN_SPEC)):
        _assert_tree_close(base, _grads(MOE.replace(remat_policy=spec)),
                           1e-5, spec)
        assert residual_bytes(MOE, name) == residual_bytes(MOE, spec), \
            (name, spec)


def test_policy_tags_derive_from_registry():
    """The deprecated dict views can never drift from the registry."""
    assert CK.POLICY_TAGS["paper"] == CK.PLAN_REGISTRY["paper"].saved
    assert set(CK.POLICY_TAGS) == {
        n for n, p in CK.PLAN_REGISTRY.items() if not p.special}
    assert set(CK.POLICIES) == set(CK.PLAN_REGISTRY)


# ---------------------------------------------------------------------------
# Scoped decisions: the MoE custom-VJP residual modes
# ---------------------------------------------------------------------------


def test_moe_residual_mode_resolution():
    assert CK.moe_residual_mode(MOE) == "ab_yswi"
    # deprecated alias still honoured when the plan leaves it open
    assert CK.moe_residual_mode(MOE.replace(save_yswi=False)) == "ab"
    # explicit moe-scoped decisions override the alias in both directions
    assert CK.moe_residual_mode(
        MOE.replace(remat_policy="moe:recompute=ffn_yswi")) == "ab"
    assert CK.moe_residual_mode(MOE.replace(
        save_yswi=False, remat_policy="moe:save=ffn_yswi")) == "ab_yswi"
    assert CK.moe_residual_mode(
        MOE.replace(remat_policy="moe:recompute=ffn_a,ffn_b")) == "x"
    assert MOE.resolved_save_yswi is True
    assert MOE.replace(
        remat_policy="moe:recompute=ffn_yswi").resolved_save_yswi is False


def test_moe_residual_mode_invalid_combinations_raise():
    with pytest.raises(ValueError, match="coupled"):
        CK.moe_residual_mode(MOE.replace(remat_policy="moe:recompute=ffn_a"))
    with pytest.raises(ValueError, match="Y_swi"):
        CK.moe_residual_mode(MOE.replace(
            remat_policy="moe:recompute=ffn_a,ffn_b;moe:save=ffn_yswi"))


def test_blaze_pallas_rejects_plan_residual_overrides():
    """The fused-Pallas composition has a fixed residual set — a plan that
    scopes a different MoE residual mode must fail loudly, not be silently
    ignored."""
    cfg = MOE.replace(moe_impl="blaze_pallas",
                      remat_policy="moe:recompute=ffn_a,ffn_b")
    with pytest.raises(ValueError, match="blaze_pallas"):
        _grads(cfg)


def test_moe_scoped_plans_gradient_parity_and_residual_ordering():
    """Scoped moe decisions never change the math, and under the
    save-everything stack policy ('full;...' seeds) each deeper recompute
    mode strictly shrinks what autodiff holds for backward."""
    base = _grads(MOE.replace(remat_policy="full"))
    specs = ("full", "full;moe:recompute=ffn_yswi",
             "full;moe:recompute=ffn_a,ffn_b")
    rb = {}
    for spec in specs:
        _assert_tree_close(base, _grads(MOE.replace(remat_policy=spec)),
                           1e-5, spec)
        rb[spec] = residual_bytes(MOE, spec)
    assert rb[specs[2]] < rb[specs[1]] < rb[specs[0]], rb


# ---------------------------------------------------------------------------
# Per-block-kind application
# ---------------------------------------------------------------------------


def test_plan_policies_group_vs_per_kind():
    pat2 = ("attn_local_ffn", "attn_ffn")
    # uniform decisions -> one group-level policy (legacy-identical)
    mode, _ = CK.plan_policies(get_plan("paper"), pat2)
    assert mode == "group"
    mode, _ = CK.plan_policies(get_plan("full"), pat2)
    assert mode == "full"
    # a tag decided differently in two kinds that both materialize it ->
    # per-sublayer policies
    mode, pols = CK.plan_policies(
        get_plan("save=qkv,attn_out;attn_local_ffn:recompute=qkv"), pat2)
    assert mode == "per_kind" and set(pols) == set(pat2)
    # scoping a tag a kind doesn't materialize is NOT a conflict
    mode, _ = CK.plan_policies(
        get_plan("save=qkv;moe:recompute=ffn_yswi"),
        ("attn_ffn", "attn_moe"))
    assert mode == "group"


def test_per_kind_remat_gradient_parity():
    cfg2 = DENSE.replace(block_pattern=("attn_local_ffn", "attn_ffn"),
                         local_global_period=2, num_layers=2,
                         sliding_window=16)
    base = _grads(cfg2.replace(remat_policy="full"))
    spec = "save=qkv,attn_out;attn_local_ffn:recompute=qkv"
    _assert_tree_close(base, _grads(cfg2.replace(remat_policy=spec)),
                       1e-5, spec)


# ---------------------------------------------------------------------------
# Estimator (incl. the SSM_STATE accounting fix)
# ---------------------------------------------------------------------------


def test_estimator_scoped_specs():
    n = 64
    est_paper = CK.estimate_saved_bytes(DENSE, "paper", n)
    # scoping FFN tags out of the (only) kind drops their bytes
    est_noffn = CK.estimate_saved_bytes(
        DENSE, "paper;attn_ffn:recompute=ffn_a,ffn_b,ffn_yswi", n)
    assert 0 < est_noffn < est_paper
    assert est_noffn == CK.estimate_saved_bytes(DENSE, "save=attn_out,qkv", n)
    # specials stay non-estimable, even seeded with overrides
    assert CK.estimate_saved_bytes(DENSE, "full;moe:recompute=ffn_yswi", n) \
        is None


def test_ssm_state_bytes_accounted():
    """`ssm`/`hymba` kinds now contribute SSM_STATE bytes (previously the
    estimator silently reported 0 for SSM/hybrid configs)."""
    hy = get_config("hymba_1_5b").reduced()
    xl = get_config("xlstm_1_3b").reduced()
    for cfg in (hy, xl):
        by_kind = dict(CK.tag_bytes_by_kind(cfg, 2048))
        ssm_kinds = [k for k in cfg.block_pattern
                     if k in ("mlstm", "slstm", "hymba")]
        assert ssm_kinds, cfg.block_pattern
        for k in ssm_kinds:
            assert by_kind[k][SSM_STATE] > 0, (cfg.name, k)
        est = CK.estimate_saved_bytes(cfg, "save=ssm_state", 2048)
        assert est and est > 0
        # and the back-compat summed view agrees
        assert CK.tag_bytes_per_group(cfg, 2048)[SSM_STATE] > 0
    # pure-attention configs still account zero SSM bytes
    assert CK.tag_bytes_per_group(DENSE, 2048)[SSM_STATE] == 0
    # sub-chunk sequences: the scans clamp chunk=min(chunk, S), so every
    # batch row still holds one carry — `batch` floors the snapshot count
    # (B=4 x S=64 tokens is 4 carries, not 1)
    one = CK.tag_bytes_per_group(xl, 256, batch=1)[SSM_STATE]
    four = CK.tag_bytes_per_group(xl, 256, batch=4)[SSM_STATE]
    assert four == 4 * one, (one, four)


def test_kind_tags_cover_canon():
    seen = set()
    for k in CK.BLOCK_KINDS:
        seen |= set(CK.kind_tags(k))
    assert seen == set(CK.CANON_TAGS)
    assert MOE_GATES in CK.kind_tags("attn_moe")
    assert FFN_A not in CK.kind_tags("attn_moe")    # expert FFN is VJP-managed


# ---------------------------------------------------------------------------
# Budget fit
# ---------------------------------------------------------------------------


def test_fit_changes_plan_across_budget_levels():
    """Acceptance (residual accountant, PR 5 semantics — the peak-rank
    ladder lives in test_memsim): fit demonstrably selects different plans
    at >= 3 budget levels, cheapest-recompute fitting plan wins."""
    n = 64
    e_min = CK.estimate_saved_bytes(DENSE, "paper_min", n)
    e_pap = CK.estimate_saved_bytes(DENSE, "paper", n)
    assert 0 < e_min < e_pap
    picks = [CheckpointPlan.fit(DENSE, n, b, rank="residual").plan.spec()
             for b in (0, e_min, e_pap)]
    assert picks == ["none", "paper_min", "paper"], picks


def test_fit_monotonicity():
    """A larger budget never picks a more-recompute (smaller-save) plan —
    under the residual accountant (saved bytes) and the peak-rank default
    (recompute bytes) alike."""
    n = 64
    budgets = [0, 10_000, 100_000, 200_000, 250_000, 300_000, 10**9]
    ests = [CheckpointPlan.fit(DENSE, n, b, rank="residual").plan
            .estimate_saved_bytes(DENSE, n) for b in budgets]
    assert ests == sorted(ests), list(zip(budgets, ests))
    recs = [CheckpointPlan.fit(DENSE, n, b).timeline.recompute_bytes
            for b in budgets]
    assert recs == sorted(recs, reverse=True), list(zip(budgets, recs))


def test_fit_prefer_and_table():
    n = 64
    prefer = get_plan("save=qkv")
    e_pref = prefer.estimate_saved_bytes(DENSE, n)
    fit = CheckpointPlan.fit(DENSE, n, e_pref, prefer=prefer,
                             rank="residual")
    assert fit.plan == prefer                   # fits -> preferred wins
    assert fit.table[0].chosen and fit.table[0].fits
    fit2 = CheckpointPlan.fit(DENSE, n, e_pref - 1, prefer=prefer,
                              rank="residual")
    assert fit2.plan.spec() == "none"           # doesn't fit -> fall through
    assert not fit2.table[0].fits
    assert sum(r.chosen for r in fit2.table) == 1


def test_fit_reaches_train_step_and_step_hook():
    """Acceptance: the fit-selected plan is baked into the step and surfaces
    through step_hook (and history), alongside the simulated peak."""
    tcfg = TrainConfig(total_steps=1, batch_size=2, seq_len=32, log_every=1)
    step = make_train_step(DENSE, tcfg, hbm_budget=2_220_000)
    assert step.resolved_plan.source == "fit"
    assert step.resolved_plan.spec == "paper_min"
    assert step.peak_sim_bytes > 0
    hooked = []
    _, _, hist = train(DENSE.replace(remat_policy=PAPER_SPEC), tcfg,
                       log=lambda *a: None,
                       step_hook=lambda s, m: hooked.append(
                           (m["remat_plan"], m["peak_sim_bytes"])))
    assert hooked == [(PAPER_SPEC, hist[0]["peak_sim_bytes"])]
    assert hist[0]["remat_plan"] == PAPER_SPEC
    assert hist[0]["peak_sim_bytes"] > 0


# ---------------------------------------------------------------------------
# Resolution provenance
# ---------------------------------------------------------------------------


def test_resolve_plan_precedence():
    r = resolve_plan("paper", config="none")
    assert (r.spec, r.source) == ("paper", "arg")
    r = resolve_plan(None, config="paper_min")
    assert (r.spec, r.source) == ("paper_min", "config")
    r = resolve_plan(None, config=None)
    assert (r.spec, r.source) == ("none", "default")
    assert resolve_plan(r) is r                 # already-resolved passthrough
    p = get_plan(PAPER_SPEC)
    assert resolve_plan(p).plan is p


def test_serve_engine_validates_plan_at_construction():
    from repro.serve.engine import ServeEngine
    params = jax.eval_shape(
        lambda k: T.init_params(k, MOE), jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServeEngine(MOE, params, remat_policy="save=bogus")
    with pytest.raises(ValueError, match="coupled"):
        ServeEngine(MOE.replace(remat_policy="moe:recompute=ffn_a"), params)

"""SSM correctness: chunked parallel scans vs naive per-step recurrences,
and decode-step consistency with the training scan."""

import jax
import numpy as np
import pytest

from repro.models.ssm import (mamba_scan, mamba_scan_dual, mlstm_scan,
                              slstm_scan)


# --- naive references ------------------------------------------------------


def naive_mlstm(q, k, v, i_pre, f_pre):
    """Stabilized per-step mLSTM recurrence (xLSTM eqs)."""
    B, S, H, D = q.shape
    q, k, v = (np.asarray(t, np.float64) for t in (q, k, v))
    k = k * D ** -0.5
    lf = np.asarray(jax.nn.log_sigmoid(f_pre), np.float64)
    li = np.asarray(i_pre, np.float64)
    C = np.zeros((B, H, D, D))
    n = np.zeros((B, H, D))
    m = np.full((B, H), -1e30)
    out = np.zeros_like(q)
    for t in range(S):
        m_new = np.maximum(lf[:, t] + m, li[:, t])
        fdec = np.exp(lf[:, t] + m - m_new)
        iin = np.exp(li[:, t] - m_new)
        C = fdec[..., None, None] * C + iin[..., None, None] * \
            np.einsum("bhd,bhe->bhde", k[:, t], v[:, t])
        n = fdec[..., None] * n + iin[..., None] * k[:, t]
        num = np.einsum("bhd,bhde->bhe", q[:, t], C)
        den = np.maximum(np.abs(np.einsum("bhd,bhd->bh", q[:, t], n)),
                         np.exp(-m_new))
        out[:, t] = num / den[..., None]
        m = m_new
    return out


def naive_slstm(z, o_pre, i_pre, f_pre):
    B, S, D = z.shape
    zf = np.tanh(np.asarray(z, np.float64))
    lf = np.asarray(jax.nn.log_sigmoid(f_pre), np.float64)
    li = np.asarray(i_pre, np.float64)
    o = np.asarray(jax.nn.sigmoid(o_pre), np.float64)
    c = np.zeros((B, D))
    n = np.zeros((B, D))
    m = np.full((B, D), -1e30)
    out = np.zeros((B, S, D))
    for t in range(S):
        m_new = np.maximum(lf[:, t] + m, li[:, t])
        a = np.exp(lf[:, t] + m - m_new)
        bi = np.exp(li[:, t] - m_new)
        c = a * c + bi * zf[:, t]
        n = a * n + bi
        out[:, t] = o[:, t] * c / np.maximum(np.abs(n), 1.0)
        m = m_new
    return out


def naive_mamba(u, dt_pre, bmat, cmat, a_log):
    B, S, H, P = u.shape
    N = bmat.shape[-1]
    u = np.asarray(u, np.float64)
    dt = np.asarray(jax.nn.softplus(dt_pre), np.float64)
    bm = np.asarray(bmat, np.float64)
    cm = np.asarray(cmat, np.float64)
    a = -np.exp(np.asarray(a_log, np.float64))
    h = np.zeros((B, H, P, N))
    out = np.zeros((B, S, H, P))
    for t in range(S):
        dec = np.exp(a[None] * dt[:, t])[:, :, None, None]
        h = dec * h + dt[:, t][:, :, None, None] * \
            u[:, t][..., None] * bm[:, t][:, None, None, :]
        out[:, t] = np.einsum("bhpn,bn->bhp", h, cm[:, t])
    return out


# --- tests -----------------------------------------------------------------


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_mlstm_matches_naive(chunk):
    B, S, H, D = 2, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 2.0
    out, _ = mlstm_scan(q, k, v, i_pre, f_pre, chunk=chunk)
    ref = naive_mlstm(q, k, v, i_pre, f_pre)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-4)


def test_mlstm_state_carry_decode():
    """Chunked scan == scan-first-half + carry + scan-second-half."""
    B, S, H, D = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 2.0
    full, _ = mlstm_scan(q, k, v, i_pre, f_pre, chunk=8)
    _, st = mlstm_scan(q[:, :16], k[:, :16], v[:, :16], i_pre[:, :16],
                       f_pre[:, :16], chunk=8)
    second, _ = mlstm_scan(q[:, 16:], k[:, 16:], v[:, 16:], i_pre[:, 16:],
                           f_pre[:, 16:], chunk=8, state=st)
    np.testing.assert_allclose(np.asarray(second), np.asarray(full[:, 16:]),
                               atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 32])
def test_slstm_matches_naive(chunk):
    B, S, D = 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    z, o_pre, i_pre = (jax.random.normal(ks[i], (B, S, D)) for i in range(3))
    f_pre = jax.random.normal(ks[3], (B, S, D)) + 2.0
    out, _ = slstm_scan(z, o_pre, i_pre, f_pre, chunk=chunk)
    ref = naive_slstm(z, o_pre, i_pre, f_pre)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 16])
def test_mamba_matches_naive(chunk):
    B, S, H, P, N = 2, 32, 3, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    u = jax.random.normal(ks[0], (B, S, H, P))
    dt_pre = jax.random.normal(ks[1], (B, S, H))
    bm = jax.random.normal(ks[2], (B, S, N))
    cm = jax.random.normal(ks[3], (B, S, N))
    a_log = jax.random.normal(ks[4], (H,)) * 0.3
    out, _ = mamba_scan(u, dt_pre, bm, cm, a_log, chunk=chunk)
    ref = naive_mamba(u, dt_pre, bm, cm, a_log)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-4)


@pytest.mark.parametrize("chunk", [8, 32])
def test_mamba_dual_matches_naive(chunk):
    """The chunked dual form (§Perf optimization) is numerically identical."""
    B, S, H, P, N = 2, 32, 3, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    u = jax.random.normal(ks[0], (B, S, H, P))
    dt_pre = jax.random.normal(ks[1], (B, S, H))
    bm = jax.random.normal(ks[2], (B, S, N))
    cm = jax.random.normal(ks[3], (B, S, N))
    a_log = jax.random.normal(ks[4], (H,)) * 0.3
    out, h = mamba_scan_dual(u, dt_pre, bm, cm, a_log, chunk=chunk)
    ref = naive_mamba(u, dt_pre, bm, cm, a_log)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-4)
    # carry state matches the state-form scan
    _, h_ref = mamba_scan(u, dt_pre, bm, cm, a_log, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-4)


def test_mamba_decode_steps_match_scan():
    B, S, H, P, N = 1, 16, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    u = jax.random.normal(ks[0], (B, S, H, P))
    dt_pre = jax.random.normal(ks[1], (B, S, H))
    bm = jax.random.normal(ks[2], (B, S, N))
    cm = jax.random.normal(ks[3], (B, S, N))
    a_log = jax.random.normal(ks[4], (H,)) * 0.3
    full, _ = mamba_scan(u, dt_pre, bm, cm, a_log, chunk=8)
    state = None
    for t in range(S):
        y, state = mamba_scan(u[:, t:t + 1], dt_pre[:, t:t + 1],
                              bm[:, t:t + 1], cm[:, t:t + 1], a_log,
                              chunk=1, state=state)
        np.testing.assert_allclose(np.asarray(y[:, 0]),
                                   np.asarray(full[:, t]), atol=1e-4)


def test_gradients_finite():
    B, S, H, D = 1, 32, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 2.0

    def loss(args):
        out, _ = mlstm_scan(*args, chunk=8)
        return (out ** 2).sum()

    g = jax.grad(loss)((q, k, v, i_pre, f_pre))
    for t in g:
        assert np.isfinite(np.asarray(t)).all()

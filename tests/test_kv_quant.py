"""Int8 KV-cache quantization tests (beyond-paper serving optimization)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import KVCache, decode_attention, init_kv_cache
from repro.serve import kv_quant as KQ


def test_quant_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64)) * 3
    q, s = KQ.quantize(x)
    x2 = KQ.dequantize(q, s)
    rel = np.abs(np.asarray(x2 - x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 1e-2, rel
    assert q.dtype == jnp.int8 and s.dtype == jnp.float16


@pytest.mark.parametrize("window", [0, 16])
def test_quant_decode_matches_fp(window):
    """Attention against the int8 cache tracks the fp cache closely."""
    B, C, Hq, Hkv, Dh, S = 2, 32, 4, 2, 16, 24
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh))
    kv_k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    kv_v = jax.random.normal(ks[2], (B, S, Hkv, Dh))

    fp = init_kv_cache(B, C, Hkv, Dh, jnp.float32)
    qc = KQ.init_quant_cache(B, C, Hkv, Dh)
    for t in range(S):
        fp = KVCache(fp.k.at[:, t].set(kv_k[:, t]),
                     fp.v.at[:, t].set(kv_v[:, t]),
                     fp.slot_pos.at[t].set(t))
        qc = KQ.append(qc, kv_k[:, t], kv_v[:, t], jnp.array(t))
    pos = jnp.array(S - 1)
    ref = decode_attention(q, fp.k, fp.v, fp.slot_pos, pos, window=window)
    out = KQ.decode_attention_quant(q, qc, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-2, rtol=3e-2)


def test_quant_cache_halves_bytes():
    B, C, Hkv, Dh = 2, 128, 4, 64
    fp = init_kv_cache(B, C, Hkv, Dh, jnp.bfloat16)
    qc = KQ.init_quant_cache(B, C, Hkv, Dh)
    fp_b = KQ.cache_bytes(fp)
    qc_b = KQ.cache_bytes(qc)
    # int8 + f16 scales ≈ (1 + 2/Dh) bytes/elt vs 2 bytes/elt for bf16
    assert qc_b < 0.55 * fp_b, (qc_b, fp_b)


def test_rolling_quant_cache():
    """Rolling (windowed) quantized cache keeps only the last W positions."""
    B, W, Hkv, Dh = 1, 8, 1, 8
    qc = KQ.init_quant_cache(B, W, Hkv, Dh)
    for t in range(20):
        k = jnp.full((B, Hkv, Dh), float(t))
        qc = KQ.append(qc, k, k, jnp.array(t))
    pos = np.asarray(qc.slot_pos)
    assert sorted(pos.tolist()) == list(range(12, 20))

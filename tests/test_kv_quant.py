"""Int8 KV-cache quantization tests (beyond-paper serving optimization)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import KVCache, decode_attention, init_kv_cache
from repro.serve import kv_quant as KQ


def test_quant_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64)) * 3
    q, s = KQ.quantize(x)
    x2 = KQ.dequantize(q, s)
    rel = np.abs(np.asarray(x2 - x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 1e-2, rel
    assert q.dtype == jnp.int8 and s.dtype == jnp.float16


def test_dequantize_honors_dtype():
    """Regression: dequantize used to always return f32 whatever ``dtype``
    said."""
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 3, 16))
    q, s = KQ.quantize(x)
    assert KQ.dequantize(q, s).dtype == jnp.float32
    assert KQ.dequantize(q, s, jnp.bfloat16).dtype == jnp.bfloat16
    assert KQ.dequantize(q, s, jnp.float16).dtype == jnp.float16


@pytest.mark.parametrize("window", [0, 16])
def test_quant_decode_matches_fp(window):
    """Attention against the int8 cache tracks the fp cache closely."""
    B, C, Hq, Hkv, Dh, S = 2, 32, 4, 2, 16, 24
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh))
    kv_k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    kv_v = jax.random.normal(ks[2], (B, S, Hkv, Dh))

    fp = init_kv_cache(B, C, Hkv, Dh, jnp.float32)
    qc = KQ.init_quant_cache(B, C, Hkv, Dh)
    for t in range(S):
        fp = KVCache(fp.k.at[:, t].set(kv_k[:, t]),
                     fp.v.at[:, t].set(kv_v[:, t]),
                     fp.slot_pos.at[:, t].set(t))
        qc = KQ.append(qc, kv_k[:, t], kv_v[:, t], jnp.array(t))
    pos = jnp.array(S - 1)
    ref = decode_attention(q, fp.k, fp.v, fp.slot_pos, pos, window=window)
    out = KQ.decode_attention_quant(q, qc, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-2, rtol=3e-2)


def test_quant_cache_halves_bytes():
    B, C, Hkv, Dh = 2, 128, 4, 64
    fp = init_kv_cache(B, C, Hkv, Dh, jnp.bfloat16)
    qc = KQ.init_quant_cache(B, C, Hkv, Dh)
    fp_b = KQ.cache_bytes(fp)
    qc_b = KQ.cache_bytes(qc)
    # int8 + f16 scales ≈ (1 + 2/Dh) bytes/elt vs 2 bytes/elt for bf16
    assert qc_b < 0.55 * fp_b, (qc_b, fp_b)


def test_rolling_quant_cache():
    """Rolling (windowed) quantized cache keeps only the last W positions."""
    B, W, Hkv, Dh = 1, 8, 1, 8
    qc = KQ.init_quant_cache(B, W, Hkv, Dh)
    for t in range(20):
        k = jnp.full((B, Hkv, Dh), float(t))
        qc = KQ.append(qc, k, k, jnp.array(t))
    pos = np.asarray(qc.slot_pos)
    assert pos.shape == (B, W)
    assert sorted(pos[0].tolist()) == list(range(12, 20))


def test_quant_per_request_positions():
    """(B,) per-request append + attend: each row equals its solo run (the
    shared-(C,) slot_pos bug made this impossible — one request's rolling
    overwrite clobbered every request's position bookkeeping)."""
    B, C, Hq, Hkv, Dh = 2, 16, 2, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh))
    kv_k = jax.random.normal(ks[1], (B, C, Hkv, Dh))
    kv_v = jax.random.normal(ks[2], (B, C, Hkv, Dh))
    lens = [5, 11]                       # request b attends lens[b] tokens
    qc = KQ.init_quant_cache(B, C, Hkv, Dh)
    for t in range(C):
        qc = KQ.append(qc, kv_k[:, t], kv_v[:, t], jnp.array(t))
    # per-request attend positions: slots past a request's own length carry
    # slot_pos > pos and must be masked for that request only
    out = KQ.decode_attention_quant(q, qc, jnp.array([L - 1 for L in lens]))
    for b, L in enumerate(lens):
        solo = KQ.init_quant_cache(1, C, Hkv, Dh)
        for t in range(L):
            solo = KQ.append(solo, kv_k[b:b + 1, t], kv_v[b:b + 1, t],
                             jnp.array(t))
        ref = KQ.decode_attention_quant(q[b:b + 1], solo, jnp.array(L - 1))
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref[0]),
                                   atol=1e-5, err_msg=f"b={b}")
    # per-request APPEND positions land in per-request slots
    stag = KQ.init_quant_cache(B, C, Hkv, Dh)
    stag = KQ.append(stag, kv_k[:, 0], kv_v[:, 0], jnp.array([2, 7]))
    sp = np.asarray(stag.slot_pos)
    assert sp[0, 2] == 2 and sp[1, 7] == 7
    assert sp[0, 7] == -1 and sp[1, 2] == -1

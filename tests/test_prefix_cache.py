"""Copy-on-write prefix sharing: refcounted page pool invariants, the
prefix trie (insert/lookup/evict), and engine-level sharing — a request
with a page-aligned shared prefix prefills only its suffix yet produces
EXACTLY the tokens of a no-sharing run (the COW correctness bar)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import paged_cache as PC
from repro.serve.engine import Request, ServeEngine

CFG = get_config("yi_6b").reduced().replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=64, attn_chunk=16)

_PS = 8


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _req(prompt, max_new=6, **kw):
    return Request(prompt=prompt, max_new_tokens=max_new,
                   eos_id=CFG.vocab_size, **kw)


# ---------------------------------------------------------------------------
# refcounted pool
# ---------------------------------------------------------------------------


def test_pool_refcounts():
    pool = PC.PagePool(6)
    a, b = pool.alloc(2)
    assert pool.refcount(a) == 1
    assert pool.share(a) == 2
    assert pool.free_pages == 3
    # first release drops the share, page stays allocated
    assert pool.release(a) == 1
    assert pool.free_pages == 3
    # second release actually frees it
    assert pool.release(a) == 0
    assert pool.free_pages == 4
    with pytest.raises(ValueError, match="double free"):
        pool.release(a)
    with pytest.raises(ValueError, match="invalid page"):
        pool.share(PC.TRASH_PAGE)
    # batch free validates the WHOLE batch before mutating (O(1) set guard)
    with pytest.raises(ValueError):
        pool.free([b, a])
    assert pool.refcount(b) == 1      # rejected batch freed nothing
    pool.free([b])


def test_pool_batch_free_validates_duplicates():
    """A batch freeing the same page more times than its refcount must
    reject the WHOLE batch up front — not drive the count negative after
    the page already rejoined the free list."""
    pool = PC.PagePool(6)
    a, b = pool.alloc(2)
    with pytest.raises(ValueError, match="double free"):
        pool.free([a, b, a])               # a has refcount 1, freed twice
    assert pool.refcount(a) == 1 and pool.refcount(b) == 1
    assert pool.free_pages == 3            # rejected batch freed nothing
    # k occurrences against refcount >= k is a legitimate multi-release
    pool.share(a)
    pool.free([a, a, b])
    assert pool.free_pages == 5


def test_pool_shared_page_survives_owner_free():
    """The serving pattern: owner finishes and frees while a sharer still
    maps the page — the page must not re-enter the free list early."""
    pool = PC.PagePool(4)
    (p,) = pool.alloc(1)
    pool.share(p)
    pool.release(p)                    # owner's drop
    assert p not in pool.alloc(2)      # still pinned by the sharer
    pool.release(p)
    assert pool.free_pages == 1


# ---------------------------------------------------------------------------
# page keys + trie
# ---------------------------------------------------------------------------


def test_page_keys_full_pages_only():
    p = np.arange(13, dtype=np.int32)
    keys = PC.page_keys(p, _PS)
    assert len(keys) == 1 and keys[0] == p[:8].tobytes()
    assert len(PC.page_keys(np.arange(16, dtype=np.int32), _PS)) == 2
    assert len(PC.page_keys(np.arange(7, dtype=np.int32), _PS)) == 0


def test_trie_insert_lookup_adopt():
    pool = PC.PagePool(8)
    cache = PC.PrefixCache()
    prompt = np.arange(24, dtype=np.int32)
    keys = PC.page_keys(prompt, _PS)          # 3 full pages
    pages = pool.alloc(3)
    assert cache.lookup(keys) == []
    adopted = cache.insert(keys, pages)
    assert adopted == set(pages) and len(cache) == 3
    assert cache.lookup(keys) == pages
    # a shorter prefix matches its chain head; a diverging prompt misses
    assert cache.lookup(keys[:2]) == pages[:2]
    other = np.arange(100, 124, dtype=np.int32)
    assert cache.lookup(PC.page_keys(other, _PS)) == []
    # re-inserting the same content adopts nothing (caller keeps its refs)
    dup = pool.alloc(3)
    assert cache.insert(keys, dup) == set()
    pool.free(dup)


def test_trie_evict_lru_leaves_only():
    pool = PC.PagePool(8)
    cache = PC.PrefixCache()
    prompt = np.arange(24, dtype=np.int32)
    keys = PC.page_keys(prompt, _PS)
    pages = pool.alloc(3)
    cache.insert(keys, pages)
    # a sharer still holds the leaf: nothing evictable beyond it
    pool.share(pages[2])
    assert cache.evict(pool, 3) == 0          # leaf pinned, parents blocked
    pool.release(pages[2])
    # leaves evict before their parents, deepest first
    assert cache.evict(pool, 1) == 1
    assert cache.lookup(keys) == pages[:2]
    assert cache.evict(pool, 5) == 2
    assert len(cache) == 0
    assert pool.free_pages == 7


# ---------------------------------------------------------------------------
# engine-level sharing
# ---------------------------------------------------------------------------


def test_prefix_sharing_tokens_and_accounting(params):
    """The acceptance scenario: a 2-page prompt served repeatedly through a
    prefix_cache engine.  The sharer maps both pages, prefills only its
    suffix (or a single re-fed token + COW fork when fully covered), and
    every run's tokens EXACTLY match the no-sharing engine's."""
    rng = np.random.default_rng(5)
    base = rng.integers(1, CFG.vocab_size, size=2 * _PS).astype(np.int32)
    ext = np.concatenate(
        [base, rng.integers(1, CFG.vocab_size, size=5).astype(np.int32)])

    def solo(prompt):
        eng = ServeEngine(CFG, params, batch_slots=1, capacity=32,
                          page_size=_PS)
        eng.generate([_req(prompt)])[0]
        return eng

    ref_base = solo(base)
    ref_ext = solo(ext)
    solo_pt = ref_base.stats["prefill_tokens"]

    eng = ServeEngine(CFG, params, batch_slots=1, capacity=32, page_size=_PS,
                      prefix_cache=True)
    a = eng.generate([_req(base)])[0]       # miss: full prefill, donates
    b = eng.generate([_req(ext)])[0]        # hit: 2 pages shared, 5-tok suffix
    c = eng.generate([_req(base)])[0]       # fully covered: refeed + COW fork
    ref_base_r = ref_base.generate([_req(base)])[0]  # fresh no-sharing run
    assert a.out_tokens == ref_base_r.out_tokens
    assert b.out_tokens == ref_ext.generate([_req(ext)])[0].out_tokens
    assert c.out_tokens == ref_base_r.out_tokens

    st = eng.stats
    assert st["prefix_misses"] == 1
    assert st["prefix_hits"] == 2
    assert st["shared_pages_mapped"] == 4
    assert st["cow_forks"] == 1             # only the fully-covered rerun
    # pair cost vs 2x solo: saved at least one full page of prefill
    pair_pt = 2 * _PS + 5 + 1               # miss + suffix + refeed token
    assert st["prefill_tokens"] == pair_pt
    assert 2 * solo_pt - (2 * _PS + 1) >= _PS   # the bench gate's shape


def test_admit_matching_chain_under_pool_exhaustion(params):
    """Regression: admission whose PROMPT MATCHES the cached chain while
    the pool is exhausted.  Eviction used to run before share(), so the
    LRU pass could free the very pages the request was about to map and
    share() died with 'double free'.  Now the chain is pinned first; when
    nothing else is evictable the engine trades sharing for capacity
    (cannibalizes the chain) instead of crashing or spinning forever."""
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, CFG.vocab_size, size=16).astype(np.int32)
    # 3 allocatable pages: the request needs all of them (16+2-1 tokens),
    # so after the first run donates 2 pages to the cache only 1 is free.
    eng = ServeEngine(CFG, params, batch_slots=1, capacity=32, page_size=_PS,
                      num_pages=4, prefix_cache=True)
    a = eng.generate([_req(prompt, max_new=2)])[0]
    assert len(eng._prefix) == 2 and eng._pool.free_pages == 1
    b = eng.generate([_req(prompt, max_new=2)])[0]   # pre-fix: ValueError
    assert b.out_tokens == a.out_tokens
    assert eng.stats["prefix_evictions"] >= 1


def test_eviction_spares_the_looked_up_chain(params):
    """When OTHER cached pages can cover the deficit, eviction must take
    them and leave the chain the admitting request matched mapped — the
    hit still counts and sharing still happens."""
    rng = np.random.default_rng(8)
    p1 = rng.integers(1, CFG.vocab_size, size=16).astype(np.int32)
    p2 = rng.integers(1, CFG.vocab_size, size=16).astype(np.int32)
    eng = ServeEngine(CFG, params, batch_slots=1, capacity=32, page_size=_PS,
                      num_pages=6, prefix_cache=True)
    a = eng.generate([_req(p1, max_new=2)])[0]       # caches p1's 2 pages
    eng.generate([_req(p2, max_new=2)])              # caches p2's 2 pages
    assert eng._pool.free_pages == 1
    hits0 = eng.stats["prefix_hits"]
    c = eng.generate([_req(p1, max_new=2)])[0]       # match p1 under pressure
    assert c.out_tokens == a.out_tokens
    assert eng.stats["prefix_hits"] == hits0 + 1     # sharing survived
    assert eng.stats["prefix_evictions"] >= 1        # p2's chain gave way


def test_prefix_eviction_under_page_pressure(params):
    """A cached chain gives way when admission needs its pages: the engine
    evicts LRU leaves instead of blocking, and tokens stay correct."""
    rng = np.random.default_rng(6)
    p1 = rng.integers(1, CFG.vocab_size, size=16).astype(np.int32)
    p2 = rng.integers(1, CFG.vocab_size, size=17).astype(np.int32)
    # minimum pool: 1 trash + pages for one request (capacity 32 / ps 8)
    eng = ServeEngine(CFG, params, batch_slots=1, capacity=32, page_size=_PS,
                      num_pages=5, prefix_cache=True)
    eng.generate([_req(p1)])           # finishes, donates 2 pages
    assert len(eng._prefix) == 2
    r2 = eng.generate([_req(p2)])[0]   # needs 3 private pages -> evicts
    assert eng.stats["prefix_evictions"] >= 1
    ref = ServeEngine(CFG, params, batch_slots=1, capacity=32,
                      page_size=_PS).generate([_req(p2)])[0]
    assert r2.out_tokens == ref.out_tokens

"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step on CPU with correct output
shapes and no NaNs, plus a decode step where applicable."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import synthesize_batch
from repro.models import transformer as T

B, S = 2, 64


def _batch(cfg):
    return {k: jnp.asarray(v)
            for k, v in synthesize_batch(cfg, B, S, seed=0).items()}


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            params = T.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: T.forward(p, b, cfg))(params, batch)
    n_img = batch["image_embeds"].shape[1] if cfg.input_kind == "mixed" else 0
    exp_seq = (batch.get("tokens", batch.get("features"))).shape[1] + n_img
    assert logits.shape == (B, exp_seq, cfg.vocab_size), logits.shape
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: T.train_loss(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert_xlarge"])
def test_decode_step(arch, arch_setup):
    cfg, params = arch_setup(arch)
    cache = T.init_cache(cfg, B, 128)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t: T.decode_step(p, c, {"tokens": t}, jnp.array(3),
                                      cfg))(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_encoder_only_has_no_decode():
    cfg = get_config("hubert_xlarge").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        T.decode_step(params, None, {"tokens": jnp.ones((1, 1), jnp.int32)},
                      jnp.array(0), cfg)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expected = {
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 0, 151936),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "gemma2_27b": (46, 4608, 32, 16, 36864, 256000),
        "mixtral_8x7b": (32, 4096, 32, 8, 0, 32000),
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
    }
    for arch, (L, d, H, kv, ff, V) in expected.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, kv, ff, V), arch
    assert get_config("qwen3_moe_30b_a3b").num_experts == 128
    assert get_config("qwen3_moe_30b_a3b").top_k == 8
    assert get_config("mixtral_8x7b").num_experts == 8
    assert get_config("mixtral_8x7b").top_k == 2
    assert get_config("hymba_1_5b").ssm_state == 16

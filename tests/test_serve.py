"""Serving-engine tests: decode equals full forward; batched generation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

CFG = get_config("yi_6b").reduced().replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=64, attn_chunk=16)


def test_decode_matches_forward_logits():
    """Token-by-token decode reproduces the full-forward last logits."""
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              CFG.vocab_size)
    full_logits, _ = T.forward(params, {"tokens": toks}, CFG)
    cache = T.init_cache(CFG, 2, 32)
    for t in range(16):
        logits, cache = T.decode_step(params, cache,
                                      {"tokens": toks[:, t:t + 1]},
                                      jnp.array(t), CFG)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, t]),
                                   atol=2e-3, rtol=1e-3)


def test_engine_batched_generation():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, batch_slots=3, capacity=64)
    reqs = [Request(prompt=np.array([1, 4, 9], np.int32), max_new_tokens=6),
            Request(prompt=np.array([1, 7], np.int32), max_new_tokens=4),
            Request(prompt=np.array([1], np.int32), max_new_tokens=5)]
    out = eng.generate(reqs)
    for r in out:
        assert 1 <= len(r.out_tokens) <= r.max_new_tokens
        assert all(0 <= t < CFG.vocab_size for t in r.out_tokens)


def test_engine_greedy_deterministic():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, batch_slots=1, capacity=64)
    outs = []
    for _ in range(2):
        r = eng.generate([Request(prompt=np.array([1, 2, 3], np.int32),
                                  max_new_tokens=5)])[0]
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]

"""Serving-engine tests: decode equals full forward; batched generation;
context-scoped grouped-GEMM backend selection (engine default, per-Request
override, enqueue-time validation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import gmm_backend as GB
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

CFG = get_config("yi_6b").reduced().replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=64, attn_chunk=16)

# A config with grouped GEMMs in the decode path, so backend choice is real.
MOE_CFG = get_config("qwen3_moe_30b_a3b").reduced().replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    num_experts=4, top_k=2, moe_d_ff=64, vocab_size=64, dtype="float32",
    attn_chunk=16)


def _two_backends():
    """Two distinct available backends (the fast pair when ragged exists)."""
    av = GB.available_backends()
    if "ragged" in av:
        return "ragged", "segment"
    return "segment", "pallas"


def test_decode_matches_forward_logits():
    """Token-by-token decode reproduces the full-forward last logits."""
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              CFG.vocab_size)
    full_logits, _ = T.forward(params, {"tokens": toks}, CFG)
    cache = T.init_cache(CFG, 2, 32)
    for t in range(16):
        logits, cache = T.decode_step(params, cache,
                                      {"tokens": toks[:, t:t + 1]},
                                      jnp.array(t), CFG)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, t]),
                                   atol=2e-3, rtol=1e-3)


def test_engine_batched_generation():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, batch_slots=3, capacity=64)
    reqs = [Request(prompt=np.array([1, 4, 9], np.int32), max_new_tokens=6),
            Request(prompt=np.array([1, 7], np.int32), max_new_tokens=4),
            Request(prompt=np.array([1], np.int32), max_new_tokens=5)]
    out = eng.generate(reqs)
    for r in out:
        assert 1 <= len(r.out_tokens) <= r.max_new_tokens
        assert all(0 <= t < CFG.vocab_size for t in r.out_tokens)


def test_engine_greedy_deterministic():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, batch_slots=1, capacity=64)
    outs = []
    for _ in range(2):
        r = eng.generate([Request(prompt=np.array([1, 2, 3], np.int32),
                                  max_new_tokens=5)])[0]
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Context-scoped backend selection
# ---------------------------------------------------------------------------


def _gen_tokens(eng, prompt=(1, 2, 3), max_new=4, **req_kw):
    r = eng.generate([Request(prompt=np.array(prompt, np.int32),
                              max_new_tokens=max_new, **req_kw)])[0]
    return tuple(r.out_tokens)


def test_two_engines_different_backends_identical_tokens():
    """Two engines in ONE process, same params, different grouped-GEMM
    backends: each holds its own resolution (per-run, not per-process — the
    MegaBlocks/Megatron-Core property) and greedy tokens agree exactly."""
    b1, b2 = _two_backends()
    params = T.init_params(jax.random.PRNGKey(0), MOE_CFG)
    eng1 = ServeEngine(MOE_CFG, params, batch_slots=1, capacity=16,
                       gmm_backend=b1)
    eng2 = ServeEngine(MOE_CFG, params, batch_slots=1, capacity=16,
                       gmm_backend=b2)
    assert eng1.backend.name == b1 and eng2.backend.name == b2
    assert eng1.backend.jax_version == jax.__version__
    t1, t2 = _gen_tokens(eng1), _gen_tokens(eng2)
    assert t1 == t2
    # Each engine jitted its own backend's decode — no shared specialization.
    assert set(eng1._decode_fns) == {b1}
    assert set(eng2._decode_fns) == {b2}


def test_request_override_beats_engine_default():
    """A per-Request ``gmm_backend`` outranks the engine default (call-site
    slot of the precedence chain) and produces the same greedy tokens."""
    b_default, b_override = _two_backends()
    params = T.init_params(jax.random.PRNGKey(0), MOE_CFG)
    eng = ServeEngine(MOE_CFG, params, batch_slots=2, capacity=16,
                      gmm_backend=b_default)

    req = Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4,
                  gmm_backend=b_override)
    assert eng.resolve_request(req).name == b_override
    assert eng.resolve_request(req).source == "arg"

    base = _gen_tokens(eng)                         # engine default
    over = _gen_tokens(eng, gmm_backend=b_override)
    assert base == over
    assert b_override in eng._decode_fns            # override really ran

    # Mixed batch: slots grouped by resolved backend, both decode fine.
    reqs = [Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=3),
            Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=3,
                    gmm_backend=b_override)]
    out = eng.generate(reqs)
    assert tuple(out[0].out_tokens) == tuple(out[1].out_tokens)


def test_unknown_backend_raises_at_enqueue_not_mid_generate():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, batch_slots=2, capacity=16)

    with pytest.raises(ValueError, match="unknown gmm backend"):
        eng.enqueue(Request(prompt=np.array([1], np.int32),
                            gmm_backend="cuda"))
    assert eng.pending == []                        # nothing was admitted

    if "ragged" not in GB.available_backends():
        with pytest.raises(RuntimeError, match="not available"):
            eng.enqueue(Request(prompt=np.array([1], np.int32),
                                gmm_backend="ragged"))

    # generate() also validates every slot before any decode work
    good = Request(prompt=np.array([1, 2], np.int32), max_new_tokens=2)
    bad = Request(prompt=np.array([1, 2], np.int32), gmm_backend="cuda")
    with pytest.raises(ValueError, match="unknown gmm backend"):
        eng.generate([good, bad])
    assert good.out_tokens == []                    # no tokens in flight


def test_engine_queue_drains_in_slot_batches():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, batch_slots=2, capacity=32)
    for i in range(3):
        eng.enqueue(Request(prompt=np.array([1 + i, 2], np.int32),
                            max_new_tokens=3))
    done = eng.run()
    assert eng.pending == []
    assert len(done) == 3
    for r in done:
        assert 1 <= len(r.out_tokens) <= 3


def test_engine_construction_snapshots_config_backend():
    """ModelConfig.gmm_backend feeds the engine's config slot; the explicit
    engine argument beats it."""
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG.replace(gmm_backend="segment"), params,
                      batch_slots=1, capacity=16)
    assert eng.backend.name == "segment"
    assert eng.backend.source == "config"
    eng2 = ServeEngine(CFG.replace(gmm_backend="segment"), params,
                       batch_slots=1, capacity=16, gmm_backend="pallas")
    assert eng2.backend.name == "pallas"
    assert eng2.backend.source == "arg"

"""Flash/chunked attention vs naive softmax reference, all variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (KVCache, decode_attention,
                                    flash_attention, init_kv_cache)


def naive_attention(q, k, v, *, causal=True, window=0, cap=0.0, q_offset=0):
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.reshape(B, Sq, Hkv, G, Dh).astype(jnp.float32) * Dh ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    if cap:
        s = cap * jnp.tanh(s / cap)
    qp = q_offset + jnp.arange(Sq)
    kp = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def _qkv(seed, B, Sq, Skv, Hq, Hkv, Dh, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, Sq, Hq, Dh), dtype),
            jax.random.normal(ks[1], (B, Skv, Hkv, Dh), dtype),
            jax.random.normal(ks[2], (B, Skv, Hkv, Dh), dtype))


@pytest.mark.parametrize("causal,window,cap,block_skip", [
    (True, 0, 0.0, False), (True, 0, 0.0, True),
    (True, 64, 0.0, True), (True, 32, 50.0, True),
    (False, 0, 0.0, False), (True, 0, 30.0, False),
])
def test_flash_matches_naive(causal, window, cap, block_skip):
    q, k, v = _qkv(0, 2, 256, 256, 8, 4, 32)
    out = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                          chunk=64, block_skip=block_skip)
    ref = naive_attention(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_gqa_grouping():
    q, k, v = _qkv(1, 1, 128, 128, 16, 2, 64)
    out = flash_attention(q, k, v, chunk=32)
    ref = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_matches_full_forward():
    """Filling a cache token-by-token gives the same final-row attention as
    the full parallel forward."""
    B, S, Hq, Hkv, Dh = 2, 48, 4, 2, 16
    q, k, v = _qkv(2, B, S, S, Hq, Hkv, Dh)
    full = naive_attention(q, k, v, causal=True)
    cache = init_kv_cache(B, S, Hkv, Dh, jnp.float32)
    for t in range(S):
        kc = cache.k.at[:, t].set(k[:, t])
        vc = cache.v.at[:, t].set(v[:, t])
        sp = cache.slot_pos.at[:, t].set(t)
        cache = KVCache(kc, vc, sp)
        out_t = decode_attention(q[:, t:t + 1], cache.k, cache.v,
                                 cache.slot_pos, jnp.array(t))
        np.testing.assert_allclose(np.asarray(out_t[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-5)


def test_decode_rolling_buffer_window():
    """A rolling cache of size W must equal full attention with window W."""
    B, S, Hq, Hkv, Dh, W = 1, 64, 2, 1, 8, 16
    q, k, v = _qkv(3, B, S, S, Hq, Hkv, Dh)
    full = naive_attention(q, k, v, causal=True, window=W)
    cache = init_kv_cache(B, W, Hkv, Dh, jnp.float32)
    for t in range(S):
        slot = t % W
        cache = KVCache(cache.k.at[:, slot].set(k[:, t]),
                        cache.v.at[:, slot].set(v[:, t]),
                        cache.slot_pos.at[:, slot].set(t))
        out_t = decode_attention(q[:, t:t + 1], cache.k, cache.v,
                                 cache.slot_pos, jnp.array(t), window=W)
        np.testing.assert_allclose(np.asarray(out_t[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-5,
                                   err_msg=f"t={t}")


def test_decode_per_request_positions():
    """(B,) per-request positions: each request's row must equal a solo
    decode at its own position — the serving engine's mixed-length case."""
    B, S, Hq, Hkv, Dh = 3, 32, 4, 2, 16
    q, k, v = _qkv(5, B, S, S, Hq, Hkv, Dh)
    cache = init_kv_cache(B, S, Hkv, Dh, jnp.float32)
    for t in range(S):
        cache = KVCache(cache.k.at[:, t].set(k[:, t]),
                        cache.v.at[:, t].set(v[:, t]),
                        cache.slot_pos.at[:, t].set(t))
    pos = jnp.array([5, 17, 31])
    out = decode_attention(q[:, :1], cache.k, cache.v, cache.slot_pos, pos)
    for b in range(B):
        solo = decode_attention(q[b:b + 1, :1], cache.k[b:b + 1],
                                cache.v[b:b + 1], cache.slot_pos[b:b + 1],
                                jnp.array(int(pos[b])))
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(solo[0]),
                                   atol=2e-5, err_msg=f"b={b}")


def test_prefix_continuation_q_offset():
    """Attending with q_offset (e.g. chunked prefill) matches the full run."""
    q, k, v = _qkv(4, 1, 128, 128, 4, 4, 32)
    full = flash_attention(q, k, v, chunk=32)
    part = flash_attention(q[:, 64:], k, v, chunk=32, q_offset=64)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, 64:]),
                               atol=2e-5)

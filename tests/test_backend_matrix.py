"""Cross-backend parity test matrix (the proof behind context-scoped backend
resolution): every backend reported by ``available_backends()`` must agree —
forward AND gradients — with the portable ``segment`` oracle through every
MoE entry point ({moe_layer, baseline, moe_block}) in both f32 and bf16.

Backends that the running JAX lacks (``ragged`` on 0.4.37, which ships
``ragged_dot`` but not ``ragged_dot_general``) appear as *skips*, not
absences, so the matrix shape is identical on every CI leg.  Shape variety
(ragged group boundaries, empty experts, k=1 vs k=2) comes from
hypothesis-drawn examples — ``tests/hypothesis_fallback.py`` keeps those
deterministic when hypothesis is not installed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is an optional test extra; fall back to fixed examples
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.configs.base import ModelConfig
from repro.core import gmm_backend as GB
from repro.core.baseline import moe_ffn_megablocks
from repro.core.moe_layer import moe_ffn_blaze
from repro.core.routing import build_dispatch, top_k_gating
from repro.models.moe_block import init_moe_params, moe_sublayer

ALL_BACKENDS = GB.backend_names()
AVAILABLE = GB.available_backends()

LAYERS = ("moe_layer", "baseline", "moe_block")
DTYPES = ("float32", "bfloat16")

# bf16 outputs are rounded to 8 mantissa bits at every gmm boundary, and the
# backends may order their fp32 reductions differently before that rounding.
_TOL = {"float32": dict(rtol=1e-4, atol=1e-5),
        "bfloat16": dict(rtol=5e-2, atol=5e-2)}


def _param(backends):
    return [pytest.param(b, marks=() if b in AVAILABLE else
                         pytest.mark.skip(reason=f"{b} unavailable on "
                                          f"jax {jax.__version__}"))
            for b in backends]


def _moe_cfg(dtype="float32", E=4, k=2) -> ModelConfig:
    return ModelConfig(
        name="matrix_moe", arch_type="moe", num_layers=2, d_model=16,
        num_heads=2, num_kv_heads=2, head_dim=8, vocab_size=64,
        num_experts=E, top_k=k, moe_d_ff=32, dtype=dtype,
        param_dtype=dtype, aux_loss_weight=0.01, z_loss_weight=1e-3)


def _inputs(seed, L, d, h, E, k, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    dt = jnp.dtype(dtype)
    x = jax.random.normal(ks[0], (L, d), jnp.float32).astype(dt)
    wg = jax.random.normal(ks[1], (d, E), jnp.float32) * 0.1
    w1 = (jax.random.normal(ks[2], (E, d, h)) * 0.1).astype(dt)
    w2 = (jax.random.normal(ks[3], (E, d, h)) * 0.1).astype(dt)
    w3 = (jax.random.normal(ks[4], (E, h, d)) * 0.1).astype(dt)
    g = top_k_gating(x.astype(jnp.float32), wg, k)
    disp = build_dispatch(g.topk_experts, E)
    gates = g.topk_weights.astype(dt)
    return x, w1, w2, w3, gates, disp


def _layer_loss(layer, dtype, seed=11, L=40, E=4, k=2):
    """(loss_fn(backend), args) for one matrix cell.  The loss closes over
    the layer entry point; args are the differentiable leaves."""
    d, h = 16, 32
    if layer == "moe_block":
        cfg = _moe_cfg(dtype, E, k)
        params = init_moe_params(jax.random.PRNGKey(seed), cfg, cfg.d_model)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (1, L, cfg.d_model),
                              jnp.float32).astype(jnp.dtype(dtype))

        def loss_fn(backend):
            def f(x, params):
                y, aux = moe_sublayer(
                    x, params, cfg.replace(gmm_backend=backend))
                return (y.astype(jnp.float32) ** 2).sum() + aux
            return f

        return loss_fn, (x, params)

    x, w1, w2, w3, gates, disp = _inputs(seed, L, d, h, E, k, dtype)
    entry = moe_ffn_blaze if layer == "moe_layer" else moe_ffn_megablocks

    def loss_fn(backend):
        def f(x, w1, w2, w3, gates):
            y = entry(x, gates, disp, w1, w3, w2, backend=backend)
            return (y.astype(jnp.float32) ** 2).sum()
        return f

    return loss_fn, (x, w1, w2, w3, gates)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("layer", LAYERS)
@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
def test_forward_and_grad_parity(backend, layer, dtype):
    """The matrix cell: value and every input/parameter gradient of ``layer``
    under ``backend`` match the ``segment`` oracle at ``dtype`` tolerance."""
    loss_fn, args = _layer_loss(layer, dtype)
    tol = dict(_TOL[dtype])
    if backend == "pallas_fused" and dtype == "bfloat16":
        # The fused kernels keep the SiLU/gating chains in f32 where the
        # bf16 oracle rounds every elementwise op, so the fused grads land
        # *closer* to the f32 truth than the oracle itself does (measured
        # per-leaf max abs error on this cell: 0.10-0.16 fused vs
        # 0.08-0.29 segment, grads O(25)).  The fused-vs-oracle gap is
        # therefore bounded by the oracle's own bf16 noise, up to ~2x.
        tol["atol"] = 3e-1

    v = loss_fn(backend)(*args)
    vr = loss_fn("segment")(*args)
    np.testing.assert_allclose(float(v), float(vr), rtol=tol["rtol"],
                               err_msg=f"fwd {layer}/{backend}/{dtype}")

    argnums = tuple(range(len(args)))
    g = jax.grad(loss_fn(backend), argnums=argnums)(*args)
    gr = jax.grad(loss_fn("segment"), argnums=argnums)(*args)
    for i, (a, b) in enumerate(zip(jax.tree.leaves(g), jax.tree.leaves(gr))):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), **tol,
            err_msg=f"grad leaf {i} ({layer}/{backend}/{dtype})")


@settings(max_examples=5, deadline=None)
@given(st.integers(17, 49), st.sampled_from([2, 4, 8]), st.integers(1, 2))
def test_forward_parity_drawn_shapes(L, E, k):
    """Forward parity across every available backend and every layer entry
    point at hypothesis-drawn (L, E, k) — odd lengths, ragged group
    boundaries, k=1 routing.  Gradients are covered by the fixed-shape
    matrix above; keeping the drawn sweep forward-only keeps the
    interpret-mode pallas cells fast."""
    for layer in LAYERS:
        loss_fn, args = _layer_loss(layer, "float32", seed=100 + L,
                                    L=L, E=E, k=k)
        ref = float(loss_fn("segment")(*args))
        for backend in AVAILABLE:
            got = float(loss_fn(backend)(*args))
            np.testing.assert_allclose(
                got, ref, rtol=1e-4,
                err_msg=f"{layer}/{backend} at L={L} E={E} k={k}")


@pytest.mark.parametrize("backend", _param(ALL_BACKENDS))
def test_gmm_primitive_parity_bf16(backend):
    """The raw gmm/gmm_dw primitives at bf16 with a ragged (empty-group)
    split: fp32 accumulation means every backend lands within bf16 rounding
    of the f32 segment oracle."""
    S, d, h, E = 64, 16, 24, 5
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    lhs = jax.random.normal(ks[0], (S, d)).astype(jnp.bfloat16)
    rhs = (jax.random.normal(ks[1], (E, d, h)) * 0.1).astype(jnp.bfloat16)
    dout = jax.random.normal(ks[2], (S, h)).astype(jnp.bfloat16)
    gs = jnp.asarray([20, 0, 24, 0, 20], jnp.int32)

    seg = GB.get_backend("segment")
    ref_y = np.asarray(seg.gmm(lhs.astype(jnp.float32),
                               rhs.astype(jnp.float32), gs))
    ref_dw = np.asarray(seg.gmm_dw(lhs.astype(jnp.float32),
                                   dout.astype(jnp.float32), gs))
    y = np.asarray(GB.gmm(lhs, rhs, gs, backend=backend), np.float32)
    dw = np.asarray(GB.gmm_dw(lhs, dout, gs, backend=backend), np.float32)
    np.testing.assert_allclose(y, ref_y, rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(dw, ref_dw, rtol=5e-2, atol=5e-2)

"""Distribution tests on an 8-host-device mesh (set in conftest): sharded
train steps match single-device numerics, specs respect divisibility, and the
MoE shard_map path equals the unsharded layer."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs import get_config
from repro.configs.base import InputShape, TrainConfig
from repro.launch import specs as S
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.models.moe_block import moe_sublayer
from repro.train.loop import make_train_step
from repro.train.optimizer import init_adamw

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices")

MOE_CFG = get_config("mixtral_8x7b").reduced().replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    num_experts=4, top_k=2, moe_d_ff=64, vocab_size=128, sliding_window=16,
    attn_chunk=16)


def test_param_specs_divisibility():
    mesh = make_debug_mesh(2, 4)
    cfg = get_config("hymba_1_5b")          # 25 heads, awkward dims
    pspecs = shd.param_specs(S.params_shapes(cfg), mesh)
    pshapes = S.params_shapes(cfg)
    for spec, shape in zip(jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(pshapes)):
        for dim, ax in zip(shape.shape, spec):
            if ax is None:
                continue
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= mesh.shape[a]
            assert dim % n == 0, (shape.shape, spec)


def test_moe_shard_map_matches_single_device():
    mesh = make_debug_mesh(2, 4)
    cfg = MOE_CFG
    key = jax.random.PRNGKey(0)
    from repro.models.moe_block import init_moe_params
    p = init_moe_params(key, cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y_ref, aux_ref = moe_sublayer(x, p, cfg, mesh=None)
    with mesh:
        y_sh, aux_sh = jax.jit(
            lambda x, p: moe_sublayer(x, p, cfg, mesh=mesh,
                                      dp_axes=("data",)))(x, p)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sh),
                               atol=2e-5)
    # the load-balance aux is computed per data shard and averaged — a local
    # estimator (standard practice), not bit-equal to the global statistic
    np.testing.assert_allclose(float(aux_ref), float(aux_sh), rtol=0.05)


def test_sharded_train_step_matches_single_device():
    mesh = make_debug_mesh(2, 4)
    cfg = MOE_CFG
    tcfg = TrainConfig(num_microbatches=2, learning_rate=1e-3)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    toks = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    p1, _, m1 = jax.jit(make_train_step(cfg, tcfg, mesh=None))(
        params, opt, batch)

    pspecs = shd.param_specs(params, mesh)
    shardings = shd.to_shardings(
        mesh, (pspecs, shd.opt_specs(pspecs),
               shd.batch_specs(cfg, batch, mesh)))
    with mesh:
        p2, _, m2 = jax.jit(make_train_step(cfg, tcfg, mesh=mesh),
                            in_shardings=shardings)(params, opt, batch)
    # The train loss folds in the load-balance aux, which is computed as a
    # per-data-shard estimator under the mesh (see the shard_map test above)
    # — so the sharded loss is not bit-equal, only close.
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=5e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_decode_cache_specs_long_context():
    """long_500k-style cache: batch=1 unshardable -> sequence axis sharded."""
    mesh = make_debug_mesh(2, 4)
    cfg = MOE_CFG.replace(sliding_window=0)
    cache_shapes = jax.eval_shape(lambda: T.init_cache(cfg, 1, 1024))
    cspecs = shd.cache_specs(cfg, cache_shapes, mesh)
    kv_spec = jax.tree.leaves(
        cspecs, is_leaf=lambda x: isinstance(x, P))[0]
    flat = [ax for ax in kv_spec if ax]
    assert flat, "expected some sharded axis on the KV cache"


def test_dryrun_small_mesh_end_to_end():
    """The dryrun builder lowers + compiles on a small mesh (fast proxy for
    the 512-device run)."""
    from repro.launch.dryrun import build_lowerable
    mesh = make_debug_mesh(2, 4)
    shape = InputShape("tiny_train", 64, 8, "train")
    built, skip, cfg = build_lowerable(
        "mixtral_8x7b", "tiny_train", mesh,
        dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
             head_dim=16, num_experts=4, top_k=2, moe_d_ff=64,
             vocab_size=128, sliding_window=16, attn_chunk=16),
        shape=shape, microbatches=2)
    assert skip is None
    fn, args, shardings = built
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
    assert compiled.memory_analysis().temp_size_in_bytes > 0

"""Distribution tests on an 8-host-device mesh (set in conftest; CI pins the
same count via XLA_FLAGS): sharded train steps match single-device numerics,
specs respect divisibility, and the MoE distribution modes ({ep, ep_a2a, tp}
x grouped-GEMM backends x dtypes) match the unsharded oracle forward and
backward through the one Dispatch-driven path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs import get_config
from repro.configs.base import InputShape, TrainConfig
from repro.core import gmm_backend as GB
from repro.launch import specs as S
from repro.launch.mesh import make_debug_mesh, make_node_mesh
from repro.models import transformer as T
from repro.models.moe_block import (init_moe_params, moe_sublayer,
                                    resolve_moe_parallel)
from repro.train.loop import make_train_step, train
from repro.train.optimizer import init_adamw

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 host devices")

MOE_CFG = get_config("mixtral_8x7b").reduced().replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    num_experts=4, top_k=2, moe_d_ff=64, vocab_size=128, sliding_window=16,
    attn_chunk=16)


def test_param_specs_divisibility():
    mesh = make_debug_mesh(2, 4)
    cfg = get_config("hymba_1_5b")          # 25 heads, awkward dims
    pspecs = shd.param_specs(S.params_shapes(cfg), mesh)
    pshapes = S.params_shapes(cfg)
    for spec, shape in zip(jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(pshapes)):
        for dim, ax in zip(shape.shape, spec):
            if ax is None:
                continue
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= mesh.shape[a]
            assert dim % n == 0, (shape.shape, spec)


def test_moe_shard_map_matches_single_device():
    mesh = make_debug_mesh(2, 4)
    cfg = MOE_CFG
    key = jax.random.PRNGKey(0)
    from repro.models.moe_block import init_moe_params
    p = init_moe_params(key, cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y_ref, aux_ref = moe_sublayer(x, p, cfg, mesh=None)
    with mesh:
        y_sh, aux_sh = jax.jit(
            lambda x, p: moe_sublayer(x, p, cfg, mesh=mesh,
                                      dp_axes=("data",)))(x, p)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sh),
                               atol=2e-5)
    # the load-balance aux is computed per data shard and averaged — a local
    # estimator (standard practice), not bit-equal to the global statistic
    np.testing.assert_allclose(float(aux_ref), float(aux_sh), rtol=0.05)


# -- the {mode x backend x dtype} parity matrix ------------------------------

# bf16 rounds to 8 mantissa bits at every gmm boundary and the modes order
# their fp32 reductions differently (psum of per-device partials).
_TOL = {"float32": dict(rtol=1e-4, atol=1e-5),
        "bfloat16": dict(rtol=5e-2, atol=5e-2)}


def _backend_params():
    avail = GB.available_backends()
    return [pytest.param(b, marks=() if b in avail else
                         pytest.mark.skip(reason=f"{b} unavailable on "
                                          f"jax {jax.__version__}"))
            for b in GB.backend_names()]


def _matrix_case(dtype, backend, mode):
    cfg = MOE_CFG.replace(dtype=dtype, param_dtype=dtype,
                          gmm_backend=backend, moe_parallel=mode,
                          moe_a2a_capacity=8.0)  # capacity >= worst case
    p = init_moe_params(jax.random.PRNGKey(3), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 16, cfg.d_model),
                          jnp.float32).astype(jnp.dtype(dtype))
    return cfg, p, x


def _y_loss(cfg, mesh):
    # Grads flow through y only: the load-balance aux under a data-sharded
    # mesh is a per-shard estimator (see the aux comments below), which
    # would drown the per-mode comparison in estimator noise.
    def f(x, p):
        y, _ = moe_sublayer(x, p, cfg, mesh=mesh, dp_axes=("data",))
        return (y.astype(jnp.float32) ** 2).mean()
    return f


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("backend", _backend_params())
@pytest.mark.parametrize("mode", ["ep", "ep_a2a", "tp"])
def test_moe_parallel_parity_matrix(mode, backend, dtype):
    """Every distribution mode, under every available grouped-GEMM backend,
    at f32 and bf16, matches the unsharded oracle — forward AND gradients —
    through the one Dispatch-driven path."""
    mesh = make_debug_mesh(2, 4)
    cfg, p, x = _matrix_case(dtype, backend, mode)
    tol = _TOL[dtype]

    y_ref, _ = moe_sublayer(x, p, cfg.replace(moe_parallel="auto"), mesh=None)
    with mesh:
        y, _ = jax.jit(lambda x, p: moe_sublayer(
            x, p, cfg, mesh=mesh, dp_axes=("data",)))(x, p)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol,
                               err_msg=f"fwd {mode}/{backend}/{dtype}")

    g_ref = jax.grad(_y_loss(cfg.replace(moe_parallel="auto"), None),
                     argnums=(0, 1))(x, p)
    with mesh:
        g = jax.jit(jax.grad(_y_loss(cfg, mesh), argnums=(0, 1)))(x, p)
    for i, (a, b) in enumerate(zip(jax.tree.leaves(g), jax.tree.leaves(g_ref))):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), **tol,
            err_msg=f"grad leaf {i} ({mode}/{backend}/{dtype})")


def test_ep_a2a_overflow_accounted():
    """Tight ep_a2a capacity drops slots and *reports* it: the overflow stat
    is positive, while ample capacity reports exactly 0."""
    mesh = make_debug_mesh(2, 4)
    cfg, p, x = _matrix_case("float32", "segment", "ep_a2a")
    with mesh:
        _, _, ample = jax.jit(lambda x, p: moe_sublayer(
            x, p, cfg, mesh=mesh, dp_axes=("data",), with_stats=True))(x, p)
        tight_cfg = cfg.replace(moe_a2a_capacity=0.25)
        _, _, tight = jax.jit(lambda x, p: moe_sublayer(
            x, p, tight_cfg, mesh=mesh, dp_axes=("data",),
            with_stats=True))(x, p)
    assert float(ample["a2a_overflow"]) == 0.0
    assert float(tight["a2a_overflow"]) > 0.0


def test_forced_ep_invalid_expert_count_raises():
    """Forced expert parallelism with E % n_model != 0 must raise (the old
    path computed E_loc = E // n_model and silently dropped experts)."""
    mesh = make_debug_mesh(2, 4)
    bad = MOE_CFG.replace(num_experts=6, moe_parallel="ep")
    with pytest.raises(ValueError, match="divisible"):
        resolve_moe_parallel(bad, mesh)
    with pytest.raises(ValueError, match="divisible"):
        make_train_step(bad, TrainConfig(), mesh=mesh)
    from repro.serve.engine import ServeEngine
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(bad.replace(moe_parallel="ep_a2a"), params={}, mesh=mesh)
    # auto never raises: it falls back to TP for awkward expert counts
    assert resolve_moe_parallel(bad.replace(moe_parallel="auto"),
                                mesh) == "tp"


def test_serve_engine_degrades_ep_a2a_to_ep():
    """Valid ep_a2a configs serve as plain EP: single-token decode slabs
    rarely divide the model axis, and EP is the same math on the same
    expert-sharded weight layout — the fallback must happen at construction,
    never as a mid-generate trace error."""
    from repro.serve.engine import ServeEngine
    mesh = make_debug_mesh(2, 4)
    eng = ServeEngine(MOE_CFG.replace(moe_parallel="ep_a2a"), params={},
                      mesh=mesh)
    assert eng.cfg.moe_parallel == "ep"


def test_ep_a2a_indivisible_tokens_raises():
    mesh = make_debug_mesh(2, 4)
    cfg, p, _ = _matrix_case("float32", "segment", "ep_a2a")
    x = jnp.zeros((4, 15, cfg.d_model))       # 2*15 tokens/device % 4 != 0
    with pytest.raises(ValueError, match="tokens/device"):
        moe_sublayer(x, p, cfg, mesh=mesh, dp_axes=("data",))


# -- context-scoped backend resolution reaches the distributed path ----------


def test_ep_path_honors_context_scoped_backend(monkeypatch):
    """Regression: the old dense EP body bypassed the gmm_backend resolver —
    ``use_backend`` had no effect under a mesh.  A recording backend pinned
    via the context scope must now carry every grouped GEMM of the EP body."""
    calls = []

    class Spy(GB.SegmentBackend):
        name = "spy"

        @staticmethod
        def gmm(lhs, rhs, group_sizes):
            calls.append("gmm")
            return GB.SegmentBackend.gmm(lhs, rhs, group_sizes)

        @staticmethod
        def gmm_dw(lhs, dout, group_sizes):
            calls.append("gmm_dw")
            return GB.SegmentBackend.gmm_dw(lhs, dout, group_sizes)

    monkeypatch.setitem(GB._REGISTRY, "spy", Spy)
    mesh = make_debug_mesh(2, 4)
    for mode in ("ep", "ep_a2a"):
        cfg, p, x = _matrix_case("float32", "auto", mode)
        calls.clear()
        with mesh, GB.use_backend("spy"):
            y, _ = jax.jit(lambda x, p: moe_sublayer(
                x, p, cfg, mesh=mesh, dp_axes=("data",)))(x, p)
        assert calls, f"{mode} body bypassed the context-scoped backend"
        y_ref, _ = moe_sublayer(x, p, cfg, mesh=None)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-5, err_msg=mode)


def test_step_hook_reports_resolved_backend_under_mesh():
    """``step_hook`` metrics carry the resolved grouped-GEMM backend when the
    step runs expert-parallel under an 8-virtual-device mesh, and a context
    scope retargets it — TrainConfig/use_backend now reach the EP path."""
    mesh = make_debug_mesh(2, 4)
    cfg = MOE_CFG.replace(moe_parallel="ep")
    tcfg = TrainConfig(total_steps=2, batch_size=4, seq_len=16,
                       learning_rate=1e-3, log_every=1)
    seen = []

    def hook(step, metrics):
        seen.append(metrics["gmm_backend"])
        assert "moe_overflow" in metrics

    with mesh, GB.use_backend("segment"):
        train(cfg, tcfg, mesh=mesh, log=lambda *_: None, step_hook=hook)
    assert seen == ["segment", "segment"]


def test_sharded_train_step_matches_single_device():
    mesh = make_debug_mesh(2, 4)
    cfg = MOE_CFG
    tcfg = TrainConfig(num_microbatches=2, learning_rate=1e-3)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    toks = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    p1, _, m1 = jax.jit(make_train_step(cfg, tcfg, mesh=None))(
        params, opt, batch)

    pspecs = shd.param_specs(params, mesh)
    shardings = shd.to_shardings(
        mesh, (pspecs, shd.opt_specs(pspecs),
               shd.batch_specs(cfg, batch, mesh)))
    with mesh:
        p2, _, m2 = jax.jit(make_train_step(cfg, tcfg, mesh=mesh),
                            in_shardings=shardings)(params, opt, batch)
    # The train loss folds in the load-balance aux, which is computed as a
    # per-data-shard estimator under the mesh (see the shard_map test above)
    # — so the sharded loss is not bit-equal, only close.
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=5e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_decode_cache_specs_long_context():
    """long_500k-style cache: batch=1 unshardable -> sequence axis sharded."""
    mesh = make_debug_mesh(2, 4)
    cfg = MOE_CFG.replace(sliding_window=0)
    cache_shapes = jax.eval_shape(lambda: T.init_cache(cfg, 1, 1024))
    cspecs = shd.cache_specs(cfg, cache_shapes, mesh)
    kv_spec = jax.tree.leaves(
        cspecs, is_leaf=lambda x: isinstance(x, P))[0]
    flat = [ax for ax in kv_spec if ax]
    assert flat, "expected some sharded axis on the KV cache"


# -- hierarchical two-hop exchange on node meshes ----------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("backend", _backend_params())
def test_moe_hier_parity_matrix(backend, dtype):
    """The two-hop ep_a2a_hier path on a ('data','node','model') mesh matches
    the unsharded oracle forward AND backward, under every available
    grouped-GEMM backend at f32 and bf16."""
    mesh = make_node_mesh(2, 2, 2)
    cfg, p, x = _matrix_case(dtype, backend, "ep_a2a_hier")
    tol = _TOL[dtype]

    y_ref, _ = moe_sublayer(x, p, cfg.replace(moe_parallel="auto"), mesh=None)
    with mesh:
        y, _ = jax.jit(lambda x, p: moe_sublayer(
            x, p, cfg, mesh=mesh, dp_axes=("data",)))(x, p)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol,
                               err_msg=f"fwd hier/{backend}/{dtype}")

    g_ref = jax.grad(_y_loss(cfg.replace(moe_parallel="auto"), None),
                     argnums=(0, 1))(x, p)
    with mesh:
        g = jax.jit(jax.grad(_y_loss(cfg, mesh), argnums=(0, 1)))(x, p)
    for i, (a, b) in enumerate(zip(jax.tree.leaves(g), jax.tree.leaves(g_ref))):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), **tol,
            err_msg=f"grad leaf {i} (hier/{backend}/{dtype})")


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("backend", _backend_params())
def test_moe_chunked_a2a_parity(backend, dtype):
    """Double-buffered chunked ep_a2a (moe_a2a_chunks=2, chunk i's exchange
    overlapping chunk i-1's grouped GEMM) is numerically the same layer."""
    mesh = make_debug_mesh(2, 4)
    cfg, p, x = _matrix_case(dtype, backend, "ep_a2a")
    cfg = cfg.replace(moe_a2a_chunks=2)
    tol = _TOL[dtype]

    y_ref, _ = moe_sublayer(x, p, cfg.replace(moe_parallel="auto"), mesh=None)
    with mesh:
        y, _ = jax.jit(lambda x, p: moe_sublayer(
            x, p, cfg, mesh=mesh, dp_axes=("data",)))(x, p)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol,
                               err_msg=f"fwd chunked/{backend}/{dtype}")

    g_ref = jax.grad(_y_loss(cfg.replace(moe_parallel="auto"), None),
                     argnums=(0, 1))(x, p)
    with mesh:
        g = jax.jit(jax.grad(_y_loss(cfg, mesh), argnums=(0, 1)))(x, p)
    for i, (a, b) in enumerate(zip(jax.tree.leaves(g), jax.tree.leaves(g_ref))):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), **tol,
            err_msg=f"grad leaf {i} (chunked/{backend}/{dtype})")


def test_hier_overflow_accounted():
    """Two-hop capacity drops (either hop) surface in the a2a_overflow stat;
    ample capacity reports exactly 0."""
    mesh = make_node_mesh(2, 2, 2)
    cfg, p, x = _matrix_case("float32", "segment", "ep_a2a_hier")
    with mesh:
        _, _, ample = jax.jit(lambda x, p: moe_sublayer(
            x, p, cfg, mesh=mesh, dp_axes=("data",), with_stats=True))(x, p)
        tight_cfg = cfg.replace(moe_a2a_capacity=0.25)
        _, _, tight = jax.jit(lambda x, p: moe_sublayer(
            x, p, tight_cfg, mesh=mesh, dp_axes=("data",),
            with_stats=True))(x, p)
    assert float(ample["a2a_overflow"]) == 0.0
    assert float(tight["a2a_overflow"]) > 0.0


def test_hier_indivisible_tokens_raises():
    mesh = make_node_mesh(2, 2, 2)
    cfg, p, _ = _matrix_case("float32", "segment", "ep_a2a_hier")
    x = jnp.zeros((4, 15, cfg.d_model))      # 30 tokens/device % 4 != 0
    with pytest.raises(ValueError, match="tokens/device"):
        moe_sublayer(x, p, cfg, mesh=mesh, dp_axes=("data",))


def test_node_mesh_mode_validation_raises_at_resolve():
    """Bad mode x mesh factorizations fail at resolve_moe_parallel, never
    mid-trace: flat ep_a2a on a node mesh, hier on a flat mesh, expert count
    not divisible by the combined (node x model) axes."""
    node = make_node_mesh(2, 2, 2)
    flat = make_debug_mesh(2, 4)
    with pytest.raises(ValueError, match="'node' axis"):
        resolve_moe_parallel(MOE_CFG.replace(moe_parallel="ep_a2a"), node)
    with pytest.raises(ValueError, match="'node' axis"):
        resolve_moe_parallel(
            MOE_CFG.replace(moe_parallel="ep_a2a_hier"), flat)
    with pytest.raises(ValueError, match="divisible"):
        resolve_moe_parallel(
            MOE_CFG.replace(num_experts=6, moe_parallel="ep_a2a_hier"), node)


def test_param_specs_node_axis_expert_dim():
    """A mesh with a 'node' tier factors the expert-bank dim over
    ('node', 'model') — matching the gdev = node_i * n_model + lane_i
    flattening in the hier body."""
    mesh = make_node_mesh(2, 2, 2)
    pspecs = shd.param_specs(S.params_shapes(MOE_CFG), mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=lambda s: isinstance(s, P))
    moe_specs = [s for path, s in flat
                 if any(str(getattr(k, "key", "")) in ("w1", "w2", "w3")
                        for k in path)]
    assert moe_specs, "no MoE expert leaves found in param specs"
    for s in moe_specs:
        assert ("node", "model") in tuple(s), s


def test_auto_resolution_follows_cost_model():
    """`auto` is an optimizer, not an alias: on the same 8-device mesh it
    picks ep_a2a where the collective cost model predicts the exchange wins
    (h ~ 3d, tight capacity) and ep where it predicts it loses (h ~ d,
    capacity 2 doubles the wire bytes)."""
    mesh = make_debug_mesh(2, 4)
    wins = MOE_CFG.replace(num_experts=8, moe_d_ff=198,
                           moe_a2a_capacity=1.0)
    assert resolve_moe_parallel(wins, mesh, 1024) == "ep_a2a"
    loses = MOE_CFG.replace(num_experts=8, moe_d_ff=66,
                            moe_a2a_capacity=2.0)
    assert resolve_moe_parallel(loses, mesh, 1024) == "ep"
    # provenance mirrors ResolvedBackend: auto decisions carry the table
    from repro.models.moe_block import resolve_moe_parallel_ex
    dec = resolve_moe_parallel_ex(wins, mesh, 1024)
    assert dec.source == "auto" and len(dec.table) >= 3


def test_auto_resolves_hier_on_node_mesh():
    """On a node mesh where tp is infeasible (odd h) and the model predicts
    the two-hop exchange beats replicated EP, auto lands on ep_a2a_hier —
    and the resulting layer runs."""
    mesh = make_node_mesh(2, 2, 2)
    cfg = MOE_CFG.replace(num_experts=8, moe_d_ff=389, moe_a2a_capacity=1.0)
    n_tok = 4 * 16 // 2
    assert resolve_moe_parallel(cfg, mesh, n_tok * 2) == "ep_a2a_hier"
    p = init_moe_params(jax.random.PRNGKey(3), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 16, cfg.d_model))
    y_ref, _ = moe_sublayer(x, p, cfg.replace(moe_a2a_capacity=8.0),
                            mesh=None)
    with mesh:
        y, _ = jax.jit(lambda x, p: moe_sublayer(
            x, p, cfg.replace(moe_a2a_capacity=8.0), mesh=mesh,
            dp_axes=("data",)))(x, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)


def test_dryrun_small_mesh_end_to_end():
    """The dryrun builder lowers + compiles on a small mesh (fast proxy for
    the 512-device run)."""
    from repro.launch.dryrun import build_lowerable
    mesh = make_debug_mesh(2, 4)
    shape = InputShape("tiny_train", 64, 8, "train")
    built, skip, cfg = build_lowerable(
        "mixtral_8x7b", "tiny_train", mesh,
        dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
             head_dim=16, num_experts=4, top_k=2, moe_d_ff=64,
             vocab_size=128, sliding_window=16, attn_chunk=16),
        shape=shape, microbatches=2)
    assert skip is None
    fn, args, shardings = built
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
    assert compiled.memory_analysis().temp_size_in_bytes > 0

"""Resolution-subsystem tests: the precedence chain (call-site arg >
``use_backend`` context > config field > ``$REPRO_GMM_BACKEND`` > auto),
``ResolvedBackend`` provenance, and the mid-process environment-mutation
regression — an already-constructed ``ServeEngine`` / train step resolved its
backend once, at construction, and NOTHING that happens to the env var
afterwards may retarget it (the latent bug: ``ops.py``/``ref.py`` used to
consult ``os.environ`` at call time, so a mid-process mutation silently
flipped backends under live objects)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import gmm_backend as GB
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import make_train_step

MOE_CFG = get_config("qwen3_moe_30b_a3b").reduced().replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    num_experts=4, top_k=2, moe_d_ff=64, vocab_size=64, dtype="float32",
    attn_chunk=16)

AUTO = GB.resolve(None).name


# ---------------------------------------------------------------------------
# Precedence chain
# ---------------------------------------------------------------------------


def test_precedence_arg_beats_everything(monkeypatch):
    monkeypatch.setenv(GB.ENV_VAR, "pallas")
    with GB.use_backend("pallas"):
        rb = GB.resolve("segment", config="pallas")
    assert (rb.name, rb.source) == ("segment", "arg")


def test_precedence_context_beats_config_and_env(monkeypatch):
    monkeypatch.setenv(GB.ENV_VAR, "pallas")
    with GB.use_backend("segment"):
        rb = GB.resolve(None, config="pallas")
    assert (rb.name, rb.source) == ("segment", "context")


def test_precedence_config_beats_env(monkeypatch):
    monkeypatch.setenv(GB.ENV_VAR, "pallas")
    rb = GB.resolve(None, config="segment")
    assert (rb.name, rb.source) == ("segment", "config")


def test_precedence_env_beats_auto(monkeypatch):
    monkeypatch.setenv(GB.ENV_VAR, "pallas")
    rb = GB.resolve(None)
    assert (rb.name, rb.source) == ("pallas", "env")
    monkeypatch.delenv(GB.ENV_VAR)
    assert GB.resolve(None).source == "auto"


def test_auto_config_is_transparent(monkeypatch):
    """"auto"/""/None at any slot falls through to the next one."""
    monkeypatch.delenv(GB.ENV_VAR, raising=False)   # empty the env slot too
    assert GB.resolve("auto", config="auto").source == "auto"
    with GB.use_backend("auto"):          # transparent scope
        assert GB.resolve(None).source == "auto"
    with GB.use_backend(None):
        assert GB.resolve(None).source == "auto"
    # Regression: a transparent scope must not MASK an enclosing pin — a
    # helper forwarding `with use_backend(maybe_none):` keeps its caller's.
    with GB.use_backend("segment"):
        with GB.use_backend(None):
            assert GB.resolve(None).name == "segment"
        with GB.use_backend("auto"):
            assert GB.resolve(None).source == "context"


def test_nested_scopes_innermost_wins():
    with GB.use_backend("segment"):
        with GB.use_backend("pallas"):
            assert GB.resolve(None).name == "pallas"
        assert GB.resolve(None).name == "segment"
    assert GB.active_backend() is None


def test_use_backend_validates_eagerly():
    with pytest.raises(ValueError, match="unknown gmm backend"):
        with GB.use_backend("cuda"):
            pytest.fail("scope must not be entered")  # pragma: no cover
    assert GB.active_backend() is None                # nothing leaked


def test_resolved_backend_provenance_and_passthrough():
    rb = GB.resolve("segment")
    assert rb.jax_version == jax.__version__
    assert str(rb) == "segment"
    assert GB.resolve(rb) is rb                       # no re-resolution
    assert GB.resolve_backend_name(rb) == "segment"
    assert GB.get_backend(rb).name == "segment"
    # frozen + hashable: usable as a jit static argument / dict key
    assert {rb: 1}[GB.resolve(rb)] == 1
    with pytest.raises(AttributeError):
        rb.name = "pallas"


def test_resolution_is_trace_time_inside_jit():
    """A use_backend scope active while a jit traces is baked into the
    computation; calling the compiled function outside the scope does not
    re-resolve."""
    from repro.core.moe_layer import moe_ffn_blaze
    from repro.core.routing import build_dispatch, top_k_gating
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (16, 8), jnp.float32)
    wg = jax.random.normal(ks[1], (8, 4)) * 0.1
    w1 = jax.random.normal(ks[2], (4, 8, 16)) * 0.1
    w2 = jax.random.normal(ks[3], (4, 8, 16)) * 0.1
    w3 = jax.random.normal(ks[4], (4, 16, 8)) * 0.1
    g = top_k_gating(x, wg, 2)
    disp = build_dispatch(g.topk_experts, 4)
    gates = g.topk_weights.astype(x.dtype)

    fn = jax.jit(lambda x: moe_ffn_blaze(x, gates, disp, w1, w3, w2))
    with GB.use_backend("segment"):
        y_in = fn(x)                                  # traced under the scope
    y_out = fn(x)                                     # cached — same program
    np.testing.assert_array_equal(np.asarray(y_in), np.asarray(y_out))


# ---------------------------------------------------------------------------
# Mid-process env mutation cannot retarget constructed objects (regression)
# ---------------------------------------------------------------------------


def _tokens(eng, seed=0):
    req = Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4)
    return tuple(eng.generate([req])[0].out_tokens)


def test_env_mutation_cannot_retarget_constructed_engine(monkeypatch):
    params = T.init_params(jax.random.PRNGKey(0), MOE_CFG)
    eng = ServeEngine(MOE_CFG, params, batch_slots=1, capacity=16)
    assert eng.backend.name == AUTO
    before = _tokens(eng)

    # A *valid but different* backend in the env: the engine's snapshot and
    # its tokens must not move.
    monkeypatch.setenv(GB.ENV_VAR, "pallas")
    assert eng.backend.name == AUTO
    assert _tokens(eng) == before

    # An *invalid* value: if anything in the hot path re-read the env var it
    # would raise — generation must stay oblivious.
    monkeypatch.setenv(GB.ENV_VAR, "cuda")
    assert _tokens(eng) == before


def test_env_mutation_before_first_trace_does_not_leak(monkeypatch):
    """The engine resolves at construction; even when the first jit trace
    happens AFTER the env var was mutated, the construction-time snapshot
    (not the env) is what gets traced."""
    params = T.init_params(jax.random.PRNGKey(0), MOE_CFG)
    eng_ref = ServeEngine(MOE_CFG, params, batch_slots=1, capacity=16)
    before = _tokens(eng_ref)                         # traced under clean env

    eng = ServeEngine(MOE_CFG, params, batch_slots=1, capacity=16)
    monkeypatch.setenv(GB.ENV_VAR, "cuda")            # would raise if read
    assert _tokens(eng) == before                     # first trace is here


def test_env_mutation_cannot_retarget_constructed_step(monkeypatch):
    tcfg = TrainConfig(batch_size=2, seq_len=16, num_microbatches=1)
    step = make_train_step(MOE_CFG, tcfg)
    assert step.resolved_backend.name == AUTO

    params = T.init_params(jax.random.PRNGKey(0), MOE_CFG)
    from repro.train.optimizer import init_adamw
    opt = init_adamw(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              MOE_CFG.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    monkeypatch.setenv(GB.ENV_VAR, "cuda")            # would raise if read
    # The step was made before the mutation; tracing it now must use the
    # construction-time resolution, not the (invalid) env value.
    p2, _, metrics = jax.jit(step)(params, opt, batch)
    assert step.resolved_backend.name == AUTO
    assert np.isfinite(float(metrics["loss"]))

    # Parity with a clean-env step pinned to the SAME backend (under the
    # env-slot CI leg, a plain auto step2 could resolve differently —
    # e.g. ragged on latest JAX — and exact param equality across distinct
    # backends does not hold).
    monkeypatch.delenv(GB.ENV_VAR)
    step2 = make_train_step(MOE_CFG, tcfg,
                            backend=step.resolved_backend.name)
    p2b, _, m2 = jax.jit(step2)(params, opt, batch)
    np.testing.assert_allclose(float(metrics["loss"]), float(m2["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p2b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ambient_scope_cannot_retarget_constructed_step(monkeypatch):
    """Regression: a use_backend scope active when jit FIRST TRACES an
    already-made step must not outrank the step's construction-time
    resolution — step_fn pins its own scope at trace time, so the program
    that compiles matches what ``step_fn.resolved_backend`` (and BENCH
    provenance) reports."""
    tcfg = TrainConfig(batch_size=2, seq_len=8)
    step = make_train_step(MOE_CFG, tcfg, backend="segment")

    seen = []
    orig = GB.resolve

    def spy(backend=None, *, config=None):
        rb = orig(backend, config=config)
        seen.append(rb.name)
        return rb

    monkeypatch.setattr(GB, "resolve", spy)
    params = T.init_params(jax.random.PRNGKey(0), MOE_CFG)
    from repro.train.optimizer import init_adamw
    opt = jax.eval_shape(init_adamw, params)
    pshapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    toks = jax.ShapeDtypeStruct((2, 8), jnp.int32)
    with GB.use_backend("pallas"):        # hostile ambient scope
        jax.jit(step).lower(pshapes, opt, {"tokens": toks, "labels": toks})
    assert seen and set(seen) == {"segment"}


def test_make_train_step_config_slots():
    """tcfg.gmm_backend wins over cfg.gmm_backend at the config slot; the
    explicit backend= argument wins over both."""
    tcfg = TrainConfig(batch_size=2, seq_len=8, gmm_backend="segment")
    step = make_train_step(MOE_CFG.replace(gmm_backend="pallas"), tcfg)
    assert step.resolved_backend.name == "segment"
    assert step.resolved_backend.source == "config"

    step = make_train_step(MOE_CFG, TrainConfig(batch_size=2, seq_len=8),
                           backend="segment")
    assert step.resolved_backend.source == "arg"

    with pytest.raises(ValueError, match="unknown gmm backend"):
        make_train_step(MOE_CFG, tcfg, backend="cuda")

"""Pallas flash-attention kernel sweeps vs the pure-jnp reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import flash_attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,Dh,causal,window,cap", [
    (2, 256, 4, 2, 32, True, 0, 0.0),
    (1, 128, 8, 8, 64, True, 64, 0.0),
    (2, 256, 4, 4, 32, False, 0, 0.0),
    (1, 128, 2, 2, 32, True, 0, 50.0),
    (1, 128, 8, 4, 128, True, 32, 0.0),
])
def test_flash_pallas_sweep(B, S, Hq, Hkv, Dh, causal, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + Hq), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 cap=cap, bq=64, bk=64)
    ref = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                          chunk=64, block_skip=False)
    tol = 3e-2 if dtype == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_full_model_pallas_path_matches_xla():
    """use_pallas=True routes attention + FFN through the Pallas kernels
    (flash fwd + fused SwiGLU with custom VJPs); loss and grads must match
    the XLA path."""
    from repro.configs import get_config
    from repro.data.pipeline import synthesize_batch
    from repro.models import transformer as T

    cfg = get_config("yi_6b").reduced().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256, attn_chunk=64, use_pallas=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v)
             for k, v in synthesize_batch(cfg, 2, 128).items()}
    (l1, _), g1 = jax.value_and_grad(
        lambda p: T.train_loss(p, batch, cfg), has_aux=True)(params)
    (l2, _), g2 = jax.value_and_grad(
        lambda p: T.train_loss(p, batch, cfg.replace(use_pallas=False)),
        has_aux=True)(params)
    assert abs(float(l1) - float(l2)) < 1e-3
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_flash_pallas_block_shape_invariance():
    """Different (bq, bk) tilings give identical results."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    outs = [flash_attention_pallas(q, k, v, bq=bq, bk=bk)
            for bq, bk in ((64, 64), (128, 64), (64, 128), (256, 256))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5)

"""Back-compat shim — the paper-table benchmarks moved into the importable
harness at ``repro.bench.paper_tables`` (single source of truth; run them via
``benchmarks/run.py`` or ``python -m repro.bench``)."""

from repro.bench.paper_tables import (IMPLS, dispatch_build_us,
                                      residual_bytes, run, step_time_us,
                                      temp_bytes)

__all__ = ["IMPLS", "dispatch_build_us", "residual_bytes", "run",
           "step_time_us", "temp_bytes"]

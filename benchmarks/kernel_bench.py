"""Back-compat shim — the kernel benchmarks moved into the importable harness
at ``repro.bench.timing`` (tracked via ``BENCH_kernels.json``; run them via
``python -m repro.bench --suite kernels``)."""

from repro.bench.timing import (gmm_backend_entries, hlo_cost, kernels_suite,
                                legacy_rows, median_time_us,
                                pallas_kernel_entries,
                                swiglu_traffic_entries)

__all__ = ["gmm_backend_entries", "hlo_cost", "kernels_suite", "legacy_rows",
           "median_time_us", "pallas_kernel_entries",
           "swiglu_traffic_entries", "run"]


def run(print_fn=print, *, quick: bool = False):
    """Legacy CSV-row interface over the record-entry suite."""
    rows = legacy_rows(kernels_suite(small=quick))
    for r in rows:
        print_fn(f"{r[0]}: {r[1]:.1f}us {r[2]}")
    return rows

"""Kernel-level benchmarks: fused vs unfused SwiGLU (HLO bytes/ops from
cost analysis — the memory-traffic claim of paper §5.2), gather-GMM vs
materialized gather+GMM, and the grouped-GEMM backend axis (every available
``repro.core.gmm_backend`` backend on the same routed workload)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return float(c.get("flops", 0)), float(c.get("bytes accessed", 0))


def swiglu_traffic(L=4096, d=1024, h=4096, dtype=jnp.bfloat16):
    """HLO bytes for fused-policy SwiGLU fwd+bwd (recompute SiLU) vs naive
    autodiff (saves every elementwise intermediate)."""
    sds = jax.ShapeDtypeStruct
    x, w1, w2 = sds((L, d), dtype), sds((d, h), dtype), sds((d, h), dtype)

    def naive(x, w1, w2):
        return (jax.nn.silu(x @ w1) * (x @ w2)).astype(jnp.float32).sum()

    from repro.core.checkpoint import POLICIES
    from repro.core.checkpoint import tag, FFN_A, FFN_B

    def paper_ckpt(x, w1, w2):
        def inner(x):
            a = tag(x @ w1, FFN_A)
            b = tag(x @ w2, FFN_B)
            return jax.nn.silu(a) * b
        y = jax.checkpoint(inner, policy=POLICIES["paper_min"])(x)
        return y.astype(jnp.float32).sum()

    rows = []
    for name, f in (("naive", naive), ("paper_ckpt", paper_ckpt)):
        fl, by = _cost(jax.grad(f, argnums=(0, 1, 2)), x, w1, w2)
        rows.append((f"swiglu_traffic_{name}", 0.0,
                     f"flops={fl:.3e};bytes={by:.3e}"))
    return rows


def pallas_kernel_time(L=1024, d=256, h=512, iters=3):
    """Wall time of the Pallas kernels in interpret mode (correctness-path
    cost only — interpret mode is not representative of TPU speed)."""
    from repro.kernels.fused_swiglu import fused_swiglu_fwd
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (L, d), jnp.float32)
    w1 = jax.random.normal(key, (d, h), jnp.float32) * 0.05
    w2 = jax.random.normal(key, (d, h), jnp.float32) * 0.05
    out = fused_swiglu_fwd(x, w1, w2)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fused_swiglu_fwd(x, w1, w2)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    return [("pallas_fused_swiglu_interpret", us, f"L={L},d={d},h={h}")]


def gmm_backend_bench(S=2048, d=256, h=512, E=8, iters=3, *,
                      include_pallas=False):
    """Compare every available grouped-GEMM backend on one routed workload:
    wall time (fwd + dw) and the jitted forward's HLO flops/bytes.

    ``pallas`` runs in interpret mode on CPU — wall time there measures the
    interpreter, not the kernel, so it is opt-in.
    """
    from repro.core import gmm_backend as GB
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    lhs = jax.random.normal(ks[0], (S, d), jnp.float32)
    rhs = jax.random.normal(ks[1], (E, d, h), jnp.float32) * 0.05
    dout = jax.random.normal(ks[2], (S, h), jnp.float32)
    base = S // E
    gs = jnp.asarray([base] * (E - 1) + [S - base * (E - 1)], jnp.int32)

    rows = []
    for name in GB.available_backends():
        if name == "pallas" and not include_pallas:
            continue

        def fwd(lhs, rhs, gs, _name=name):
            return GB.gmm(lhs, rhs, gs, backend=_name)

        def dw(lhs, dout, gs, _name=name):
            return GB.gmm_dw(lhs, dout, gs, backend=_name)

        fl, by = _cost(fwd, lhs, rhs, gs)
        jf, jd = jax.jit(fwd), jax.jit(dw)
        jax.block_until_ready((jf(lhs, rhs, gs), jd(lhs, dout, gs)))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = (jf(lhs, rhs, gs), jd(lhs, dout, gs))
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append((f"gmm_backend_{name}", us,
                     f"S={S},d={d},h={h},E={E};flops={fl:.3e};bytes={by:.3e}"))
    return rows


def run(print_fn=print, *, quick: bool = False):
    rows = []
    rows += swiglu_traffic(L=1024 if quick else 4096)
    rows += pallas_kernel_time(L=256 if quick else 1024)
    rows += gmm_backend_bench(S=512 if quick else 2048,
                              include_pallas=quick)
    for r in rows:
        print_fn(f"{r[0]}: {r[1]:.1f}us {r[2]}")
    return rows

"""Legacy CSV benchmark entry point — a thin CLI over ``repro.bench``.

Emits ``name,us_per_call,derived`` CSV (stdout) plus human-readable logs.

  paper        — Figures 3-6 analogues (``repro.bench.paper_tables``).
  kernels      — §5.2 traffic + backend/kernel timings (``repro.bench.timing``).
  roofline     — summarizes EXPERIMENTS/dryrun.jsonl if present.

``--quick`` runs a reduced sweep (used by CI/tests).  For tracked,
regression-gated records use ``python -m repro.bench`` instead (see README
§Benchmark harness).
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _log(msg):
    print(f"# {msg}", file=sys.stderr)


def roofline_rows(path="EXPERIMENTS/dryrun.jsonl"):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("status") != "OK":
                continue
            rows.append((
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
                f"t_comp={r['t_compute_s']:.3g}s;t_mem={r['t_memory_s']:.3g}s;"
                f"t_coll={r['t_collective_s']:.3g}s;dom={r['dominant']};"
                f"fits={r['fits_hbm']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=[None, "paper", "kernels", "roofline"])
    args = ap.parse_args()

    rows = []
    if args.only in (None, "paper"):
        from repro.bench import paper_tables
        _log("== paper tables (Figures 3-6 analogues) ==")
        rows += paper_tables.run(print_fn=_log, quick=args.quick)
    if args.only in (None, "kernels"):
        from repro.bench.timing import kernels_suite, legacy_rows
        _log("== kernel benchmarks ==")
        for r in legacy_rows(kernels_suite(small=args.quick)):
            _log(f"{r[0]}: {r[1]:.1f}us {r[2]}")
            rows.append(r)
    if args.only in (None, "roofline"):
        rows += roofline_rows()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
